"""Thread-safe metrics registry (ISSUE 10 tentpole, part 2).

Counters, gauges, and bounded-reservoir histograms (p50/p95/p99),
registered by the admission service, the trace store, the daemon, the
fleet scheduler, and the fault harness, and exported in Prometheus
text-exposition format and JSON through the daemon's ``metrics`` kind.

Two design constraints shape this module:

* **Single source of truth.** The service's ``stats()``/``health()``
  dicts and the daemon's ``metrics`` kind all read the same registry
  objects, so the three wire shapes can never drift apart. Legacy
  dict-shaped counters (``FleetScheduler.counters``,
  ``rung_counts``) are served by :class:`CounterDict`, a mapping
  facade over per-key labeled counters — ``counters[k] += 1`` and
  ``summary.update(**sched.counters)`` keep working bit-for-bit.
* **Determinism.** The repo pins bit-identical replays everywhere, so
  the histogram reservoir is a deterministic bounded ring (newest N
  observations), never a random sample; count/sum/min/max stay exact
  over the full stream.

Zero dependencies beyond the standard library.
"""
from __future__ import annotations

import threading
from collections import deque


def _fmt_labels(labels: dict | None) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return "{" + inner + "}"


class Counter:
    """Monotonic counter. ``set`` exists only for the
    :class:`CounterDict` facade (read-modify-write under its lock)."""

    kind = "counter"
    __slots__ = ("name", "help", "labels", "_lock", "_value")

    def __init__(self, name: str, help: str = "",
                 labels: dict | None = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, v: int) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """A value that can go up and down (e.g. requests in flight)."""

    kind = "gauge"
    __slots__ = ("name", "help", "labels", "_lock", "_value")

    def __init__(self, name: str, help: str = "",
                 labels: dict | None = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self._lock = threading.Lock()
        self._value = 0

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    def inc(self, n=1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n=1) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self):
        with self._lock:
            return self._value


class Histogram:
    """Bounded-reservoir histogram. The reservoir is a deterministic
    ring of the newest ``reservoir`` observations (no random sampling —
    the repo pins bit-identical results); count/sum/min/max are exact
    over everything ever observed, and p50/p95/p99 come from the
    sorted reservoir snapshot."""

    kind = "histogram"
    QUANTILES = (0.5, 0.95, 0.99)
    __slots__ = ("name", "help", "labels", "reservoir", "_lock",
                 "_ring", "_count", "_sum", "_min", "_max")

    def __init__(self, name: str, help: str = "",
                 labels: dict | None = None, reservoir: int = 1024):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.reservoir = reservoir
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=reservoir)
        self._count = 0
        self._sum = 0.0
        self._min = None
        self._max = None

    def observe(self, v: float) -> None:
        with self._lock:
            self._ring.append(v)
            self._count += 1
            self._sum += v
            if self._min is None or v < self._min:
                self._min = v
            if self._max is None or v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    @property
    def max(self):
        with self._lock:
            return self._max

    def percentile(self, q: float):
        with self._lock:
            data = sorted(self._ring)
        if not data:
            return None
        idx = min(len(data) - 1, int(q * len(data)))
        return data[idx]

    def snapshot(self) -> dict:
        with self._lock:
            data = sorted(self._ring)
            out = {"count": self._count, "sum": self._sum,
                   "min": self._min, "max": self._max,
                   "mean": self._sum / self._count if self._count
                   else 0.0}
        for q in self.QUANTILES:
            out[f"p{int(q * 100)}"] = (
                data[min(len(data) - 1, int(q * len(data)))]
                if data else None)
        return out


class MetricsRegistry:
    """Get-or-create registry of named (and optionally labeled)
    metrics, plus pull-time *collectors* for subsystems that keep
    their own counters (trace cache, store, decision log, faults)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict = {}
        self._collectors: dict = {}

    def _get(self, cls, name: str, help: str, labels: dict | None,
             **kwargs):
        key = (name, tuple(sorted((labels or {}).items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, help, labels, **kwargs)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "",
                labels: dict | None = None) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: dict | None = None) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: dict | None = None,
                  reservoir: int = 1024) -> Histogram:
        return self._get(Histogram, name, help, labels,
                         reservoir=reservoir)

    def register_collector(self, name: str, fn) -> None:
        """``fn()`` returns a flat ``{series_name: number}`` dict
        gathered at export time. Re-registering a name replaces it."""
        with self._lock:
            self._collectors[name] = fn

    def metrics(self) -> list:
        with self._lock:
            return list(self._metrics.values())

    def _collect(self) -> dict:
        with self._lock:
            collectors = list(self._collectors.items())
        def _emit(out, series, v):
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                out[series] = v
            elif isinstance(v, dict):
                # flatten one nesting level (cache stats carry a
                # nested store/quarantine dict)
                for k2, v2 in v.items():
                    if isinstance(v2, (int, float)) and not \
                            isinstance(v2, bool):
                        out[f"{series}_{k2}"] = v2

        out = {}
        for name, fn in collectors:
            try:
                for k, v in (fn() or {}).items():
                    _emit(out, f"{name}_{k}", v)
            except Exception:
                # a broken collector must never take down an export
                out[f"{name}_collect_errors"] = 1
        return out

    def to_json(self) -> dict:
        counters, gauges, histograms = {}, {}, {}
        for m in self.metrics():
            series = m.name + _fmt_labels(m.labels)
            if m.kind == "counter":
                counters[series] = m.value
            elif m.kind == "gauge":
                gauges[series] = m.value
            else:
                histograms[series] = m.snapshot()
        return {"counters": counters, "gauges": gauges,
                "histograms": histograms, "collected": self._collect()}

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4).
        Histograms are exported summary-style (quantile series plus
        ``_count``/``_sum``)."""
        lines = []
        seen_type = set()
        for m in self.metrics():
            if m.name not in seen_type:
                seen_type.add(m.name)
                if m.help:
                    lines.append(f"# HELP {m.name} {m.help}")
                ptype = ("summary" if m.kind == "histogram"
                         else m.kind)
                lines.append(f"# TYPE {m.name} {ptype}")
            if m.kind == "histogram":
                snap = m.snapshot()
                for q in m.QUANTILES:
                    v = snap[f"p{int(q * 100)}"]
                    if v is None:
                        continue
                    labels = dict(m.labels)
                    labels["quantile"] = repr(q)
                    lines.append(
                        f"{m.name}{_fmt_labels(labels)} {v}")
                lines.append(
                    f"{m.name}_count{_fmt_labels(m.labels)} "
                    f"{snap['count']}")
                lines.append(
                    f"{m.name}_sum{_fmt_labels(m.labels)} "
                    f"{snap['sum']}")
            else:
                lines.append(
                    f"{m.name}{_fmt_labels(m.labels)} {m.value}")
        for series, v in sorted(self._collect().items()):
            lines.append(f"{series} {v}")
        return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict:
    """Parse Prometheus text exposition back into
    ``{series_with_labels: float}`` — the round-trip check used by
    ``benchmarks/report.py --check`` and the obs tests."""
    out = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        series, _, value = line.rpartition(" ")
        out[series] = float(value)
    return out


class CounterDict:
    """Mapping facade over per-key labeled registry counters.

    Replaces hand-rolled ``{key: int}`` counter dicts
    (``FleetScheduler.counters``, the service rung counts) so the same
    numbers flow to legacy summaries *and* the metrics export:
    ``d[k] += 1``, ``dict(d)``, ``summary.update(**d)``, and equality
    against a plain dict all behave exactly as before.
    """

    def __init__(self, keys=(), registry: MetricsRegistry | None = None,
                 name: str = "xmem_events_total", label: str = "event",
                 help: str = ""):
        self._registry = registry if registry is not None \
            else MetricsRegistry()
        self._name = name
        self._label = label
        self._help = help
        self._lock = threading.Lock()
        self._counters = {}
        for k in keys:
            self._counter_for(k)

    def _counter_for(self, key) -> Counter:
        c = self._counters.get(key)
        if c is None:
            c = self._registry.counter(
                self._name, self._help, labels={self._label: str(key)})
            self._counters[key] = c
        return c

    def __getitem__(self, key) -> int:
        return self._counters[key].value

    def __setitem__(self, key, value) -> None:
        with self._lock:
            self._counter_for(key).set(int(value))

    def __contains__(self, key) -> bool:
        return key in self._counters

    def __iter__(self):
        return iter(list(self._counters))

    def __len__(self) -> int:
        return len(self._counters)

    def keys(self):
        return list(self._counters)

    def values(self):
        return [c.value for c in self._counters.values()]

    def items(self):
        return [(k, c.value) for k, c in self._counters.items()]

    def get(self, key, default=None):
        c = self._counters.get(key)
        return c.value if c is not None else default

    def inc(self, key, n: int = 1) -> None:
        with self._lock:
            self._counter_for(key).inc(n)

    def __eq__(self, other) -> bool:
        if isinstance(other, CounterDict):
            return dict(self.items()) == dict(other.items())
        if isinstance(other, dict):
            return dict(self.items()) == other
        return NotImplemented

    def __repr__(self) -> str:
        return f"CounterDict({dict(self.items())!r})"
