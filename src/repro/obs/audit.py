"""Crash-safe decision audit trail (ISSUE 10 tentpole, part 3).

Append-only JSONL with fsync'd rotation and TraceStore-style
quarantine recovery for torn tails. One record per
decide/plan/place/evacuate carrying the correlation ID, cache
provenance, degradation rung, and chosen counter-offer — a
reject→plan→retry chain is reconstructible offline from the log
alone.

Crash-safety model (mirrors ``service/store.py``):

* Appends go to a single active ``<name>.jsonl`` file under an
  instance lock; each record is one JSON line flushed to the OS
  buffer immediately. By default (``fsync="rotate"``) fsync happens
  at rotation and close — a hard crash can tear at most the tail of
  the active file, never a rotated one. ``fsync="always"`` fsyncs
  every record for callers that want it.
* On open, :meth:`_recover` scans the active file from the front and
  stops at the first byte that is not part of a complete,
  JSON-parseable line. Everything after that point is **quarantined,
  not deleted** (``quarantine/<seq>.<pid>.<reason>.<basename>``) and
  the file is truncated back to the last good record — restart never
  loses intact records and never silently discards torn bytes.
* Rotation renames the active file to ``<name>-NNNNNN.jsonl`` via
  ``os.replace`` and fsyncs the directory, so a rotated segment is
  durable before new appends land.
"""
from __future__ import annotations

import json
import os
import threading
import time


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class AuditLog:
    """Append-only JSONL decision log with torn-tail recovery."""

    QUARANTINE_DIR = "quarantine"

    def __init__(self, directory: str, *, name: str = "audit",
                 max_bytes: int = 8 << 20, fsync: str = "rotate"):
        if fsync not in ("rotate", "always"):
            raise ValueError(f"fsync must be 'rotate' or 'always', "
                             f"got {fsync!r}")
        self.directory = directory
        self.name = name
        self.max_bytes = max_bytes
        self.fsync = fsync
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._qseq = 0
        self.appended = 0
        self.rotations = self._count_rotated()
        self.recovery = self._recover()
        self._seq = self.recovery["records"]
        self._fh = open(self.path, "ab")

    @property
    def path(self) -> str:
        return os.path.join(self.directory, f"{self.name}.jsonl")

    def _rotated_paths(self) -> list[str]:
        prefix = f"{self.name}-"
        try:
            names = sorted(
                n for n in os.listdir(self.directory)
                if n.startswith(prefix) and n.endswith(".jsonl"))
        except OSError:
            return []
        return [os.path.join(self.directory, n) for n in names]

    def _count_rotated(self) -> int:
        return len(self._rotated_paths())

    def _quarantine(self, data: bytes, reason: str) -> str:
        qdir = os.path.join(self.directory, self.QUARANTINE_DIR)
        os.makedirs(qdir, exist_ok=True)
        with self._lock:
            self._qseq += 1
            seq = self._qseq
        dest = os.path.join(
            qdir, f"{seq:04d}.{os.getpid()}.{reason}."
                  f"{self.name}.jsonl")
        with open(dest, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(qdir)
        return dest

    def _recover(self) -> dict:
        """Scan the active file; quarantine and truncate a torn tail.
        Returns ``{"records", "torn_bytes", "quarantined"}``."""
        report = {"records": 0, "torn_bytes": 0, "quarantined": 0}
        if not os.path.exists(self.path):
            return report
        with open(self.path, "rb") as f:
            raw = f.read()
        pos = 0
        records = 0
        while pos < len(raw):
            nl = raw.find(b"\n", pos)
            if nl < 0:
                break  # incomplete last line — torn
            line = raw[pos:nl].strip()
            if line:
                try:
                    json.loads(line)
                except ValueError:
                    break  # corrupt line — torn from here on
                records += 1
            pos = nl + 1
        report["records"] = records
        torn = raw[pos:]
        if torn:
            report["torn_bytes"] = len(torn)
            report["quarantined"] = 1
            self._quarantine(torn, "torn")
            with open(self.path, "r+b") as f:
                f.truncate(pos)
                f.flush()
                os.fsync(f.fileno())
        return report

    def append(self, record: dict) -> dict:
        """Append one record (adds ``seq`` and ``ts``); returns the
        record as written. Thread-safe; exactly one line per call."""
        with self._lock:
            self._seq += 1
            rec = {"seq": self._seq, "ts": time.time(), **record}
            line = json.dumps(rec, separators=(",", ":"),
                              default=str).encode() + b"\n"
            self._fh.write(line)
            self._fh.flush()
            if self.fsync == "always":
                os.fsync(self._fh.fileno())
            self.appended += 1
            if self._fh.tell() >= self.max_bytes:
                self._rotate_locked()
        return rec

    def _rotate_locked(self) -> None:
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self._fh.close()
        dest = os.path.join(
            self.directory,
            f"{self.name}-{self.rotations:06d}.jsonl")
        os.replace(self.path, dest)
        _fsync_dir(self.directory)
        self.rotations += 1
        self._fh = open(self.path, "ab")

    def records(self, kind: str | None = None) -> list[dict]:
        """All intact records, rotated segments first, in append
        order; optionally filtered by ``kind``."""
        with self._lock:
            self._fh.flush()
            paths = self._rotated_paths() + [self.path]
        out = []
        for path in paths:
            try:
                with open(path, "rb") as f:
                    raw = f.read()
            except OSError:
                continue
            for line in raw.split(b"\n"):
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except ValueError:
                    continue  # torn tail of a live file
                if kind is None or rec.get("kind") == kind:
                    out.append(rec)
        return out

    def stats(self) -> dict:
        with self._lock:
            return {"appended": self.appended,
                    "rotations": self.rotations,
                    "records": self._seq,
                    "recovery": dict(self.recovery),
                    "path": self.path}

    def close(self) -> None:
        with self._lock:
            if self._fh.closed:
                return
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
