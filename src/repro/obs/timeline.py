"""Memory-timeline export (ISSUE 10 tentpole, part 4a).

Renders the simulated allocator demand curve and the top-K block
lifecycles from an :class:`~repro.core.estimator.EstimateReport` as a
Chrome-trace / Perfetto JSON document:

* one **counter track** ("C" events) per memory space, sampled from
  the replay's ``(t, allocated, reserved)`` curve — timestamps are
  allocator event ticks, which Perfetto renders as microseconds;
* the K largest blocks as **slice tracks** ("X" events), labeled with
  kind/phase/op/space so a rejected dry run hands the user an
  inspectable picture of *what* owned the peak, not just a number.

Pure functions over report objects — no observability context needed.
"""
from __future__ import annotations

import json
import os


def _flatten_blocks(composition) -> list:
    """``report.composition`` is ``PeriodicBlocks`` (prefix/cycle/
    suffix) on the fast path, a flat block list on the reference
    path, or absent; normalize to one list."""
    if composition is None:
        return []
    if isinstance(composition, (list, tuple)):
        return list(composition)
    blocks = []
    for part in ("prefix", "cycle", "suffix", "blocks"):
        seg = getattr(composition, part, None)
        if seg:
            blocks.extend(seg)
    return blocks


def _block_size(block) -> int:
    for attr in ("sharded_size", "size"):
        v = getattr(block, attr, None)
        if v is not None:
            return int(v)
    return 0


def timeline_events(report, top_k: int = 20) -> dict:
    """Build the Chrome-trace document for one estimate report."""
    events = []
    sim = getattr(report, "sim", None)
    curve = list(getattr(sim, "curve", None) or ())
    for t, allocated, reserved in curve:
        events.append({
            "name": "memory", "ph": "C", "pid": 0, "tid": 0,
            "ts": t, "args": {"allocated": allocated,
                              "reserved": reserved}})
    stats = getattr(sim, "stats", None) or {}
    space_peaks = stats.get("space_peaks") or {}
    horizon = curve[-1][0] if curve else 0
    for space, peak in space_peaks.items():
        events.append({
            "name": f"peak[{space}]", "ph": "C", "pid": 0, "tid": 0,
            "ts": horizon, "args": {"peak_bytes": peak}})

    blocks = _flatten_blocks(getattr(report, "composition", None))
    top = sorted(blocks, key=_block_size, reverse=True)[:top_k]
    if top:
        ends = [getattr(b, "free_t", None) for b in top]
        horizon = max([horizon] +
                      [e for e in ends if e is not None] +
                      [getattr(b, "alloc_t", 0) for b in top])
    for i, b in enumerate(top):
        alloc_t = getattr(b, "alloc_t", 0)
        free_t = getattr(b, "free_t", None)
        kind = getattr(b, "block_kind", None)
        events.append({
            "name": f"{getattr(kind, 'value', kind) or 'block'}:"
                    f"{getattr(b, 'op', '') or getattr(b, 'scope', '')}",
            "ph": "X", "pid": 0, "tid": i + 1, "ts": alloc_t,
            "dur": max(0, (free_t if free_t is not None else horizon)
                       - alloc_t),
            "args": {
                "bytes": _block_size(b),
                "phase": str(getattr(b, "phase", "")),
                "scope": str(getattr(b, "scope", "")),
                "space": str(getattr(b, "space", "")),
            }})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {
                "peak_bytes": getattr(report, "peak_bytes", None),
                "persistent_bytes": getattr(report, "persistent_bytes",
                                            None),
                "curve_points": len(curve),
                "blocks_rendered": len(top),
                "blocks_total": len(blocks)}}


def write_timeline(report, path: str, top_k: int = 20) -> str:
    """Write the Perfetto artifact for ``report`` to ``path``
    (atomically) and return the path."""
    doc = timeline_events(report, top_k=top_k)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)
    return path
