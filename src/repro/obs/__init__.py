"""End-to-end observability layer (ISSUE 10).

Four zero-dependency pieces, threaded through every pipeline layer:

* :mod:`repro.obs.spans` — context-manager tracing spans with a
  per-request correlation ID, exportable as Chrome-trace JSON;
* :mod:`repro.obs.metrics` — thread-safe counters / gauges /
  bounded-reservoir histograms, exported as Prometheus text and JSON;
* :mod:`repro.obs.audit` — crash-safe append-only JSONL decision log
  with torn-tail quarantine recovery;
* :mod:`repro.obs.timeline` / :mod:`repro.obs.ingest` — Perfetto
  memory-timeline export and observed-peak residual ingestion.

:class:`Observability` bundles them behind one handle. The admission
service always owns one (``obs=`` kwarg, default *disabled*): the
metrics registry is live either way — it is the single source for the
service/daemon counters, so ``stats``, ``health`` and ``metrics``
kinds can never drift — while spans, correlation IDs and audit
records only activate when ``enabled=True``. Disabled instrumentation
costs one attribute check / ``ContextVar.get`` per hook site, and an
enabled run is bit-identical to a bare one by construction: observers
never feed back into decisions.
"""
from __future__ import annotations

from . import spans as _spans
from .audit import AuditLog
from .metrics import (Counter, CounterDict, Gauge, Histogram,
                      MetricsRegistry, parse_prometheus)
from .spans import (Span, Tracer, current_correlation_id,
                    mint_correlation_id)

__all__ = [
    "AuditLog", "Counter", "CounterDict", "Gauge", "Histogram",
    "MetricsRegistry", "Observability", "Span", "Tracer",
    "current_correlation_id", "mint_correlation_id",
    "parse_prometheus",
]


class Observability:
    """One handle bundling tracer + metrics registry + audit log."""

    def __init__(self, enabled: bool = True, *,
                 registry: MetricsRegistry | None = None,
                 tracer: Tracer | None = None,
                 audit_dir: str | None = None,
                 audit: AuditLog | None = None,
                 max_spans: int = 4096):
        self.enabled = bool(enabled)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.tracer = tracer if tracer is not None \
            else Tracer(max_spans=max_spans)
        if audit is not None:
            self.audit = audit
        elif audit_dir is not None:
            self.audit = AuditLog(audit_dir)
        else:
            self.audit = None

    def request(self, kind: str, job_id: str = "") -> "_RequestScope":
        """Per-request entry point: mints a correlation ID, installs
        the span context, and opens the root span. Yields the
        correlation ID (None when disabled)."""
        return _RequestScope(self, kind, job_id)

    def span(self, name: str, **attrs):
        """An explicit span on this handle's tracer (layers that hold
        the handle; deep layers use the module-level
        :func:`repro.obs.spans.span` instead)."""
        if not self.enabled:
            return _spans._NOOP
        return self.tracer.span(name, **attrs)

    def record(self, kind: str, correlation_id: str | None = None,
               **fields) -> dict | None:
        """Append one audit record (no-op without an audit log)."""
        if self.audit is None:
            return None
        if correlation_id is None:
            correlation_id = current_correlation_id()
        return self.audit.append(
            {"kind": kind, "correlation_id": correlation_id,
             **fields})

    def to_chrome_trace(self) -> dict:
        return self.tracer.to_chrome_trace()

    def stats(self) -> dict:
        out = {"enabled": self.enabled,
               "spans": self.tracer.stats()}
        if self.audit is not None:
            out["audit"] = self.audit.stats()
        return out

    def close(self) -> None:
        if self.audit is not None:
            self.audit.close()


class _RequestScope:
    """Class-based per-request context (one per decision — cheaper
    than a ``contextlib`` generator pair): correlation ID + activated
    span context + root span when enabled, a no-op yielding None when
    disabled."""

    __slots__ = ("_act", "_span", "_cid")

    def __init__(self, obs: Observability, kind: str, job_id: str):
        if not obs.enabled:
            self._act = None
            self._cid = None
            return
        cid = mint_correlation_id()
        self._cid = cid
        self._act = _spans.activate(obs.tracer, cid)
        self._span = obs.tracer.span(f"service.{kind}",
                                     correlation_id=cid,
                                     job_id=job_id)

    def __enter__(self) -> str | None:
        if self._act is None:
            return None
        self._act.__enter__()
        self._span.__enter__()
        return self._cid

    def __exit__(self, *exc) -> bool:
        if self._act is not None:
            self._span.__exit__(*exc)
            self._act.__exit__(*exc)
        return False
