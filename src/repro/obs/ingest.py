"""Observed-peak telemetry ingestion (ISSUE 10 tentpole, part 4b).

Accepts ``GPUMemorySnapshot``-shaped observed-peak records (the ktrdr
monitoring idiom: per-device allocated/reserved/total MB plus
utilization) keyed by ``(model digest, config family)`` — the same
content digest the trace cache uses (``fn_digest``) and the same
structural family fingerprint the degradation ladder uses
(``request_family``) — and persists estimate-vs-observed residuals as
crash-safe JSONL next to the TraceStore. This is the substrate the
ROADMAP's feedback-calibration item reads: a future PR turns these
residuals into calibrated estimates with confidence intervals; this
PR makes sure the records exist and survive restarts.

Also usable as a CLI::

    python -m repro.obs.ingest --dir STORE/telemetry \\
        --model-digest abc123 --family fam0 \\
        --estimate-bytes 1000000 --observed-mb 1.2
    python -m repro.obs.ingest --dir STORE/telemetry --summary
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from .audit import AuditLog

MB = 2 ** 20


@dataclasses.dataclass
class GPUMemorySnapshot:
    """One observed device-memory sample (ktrdr monitoring shape)."""

    timestamp: float
    device_id: int = 0
    allocated_mb: float = 0.0
    reserved_mb: float = 0.0
    total_mb: float = 0.0
    free_mb: float = 0.0
    utilization_percent: float = 0.0
    temperature_celsius: float | None = None
    power_usage_watts: float | None = None

    @property
    def reserved_bytes(self) -> int:
        return int(self.reserved_mb * MB)

    @property
    def allocated_bytes(self) -> int:
        return int(self.allocated_mb * MB)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "GPUMemorySnapshot":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


class TelemetryIngestor:
    """Persist estimate-vs-observed residual records, one JSONL line
    per observation, with the audit log's torn-tail recovery."""

    def __init__(self, directory: str):
        self.log = AuditLog(directory, name="residuals")

    def ingest(self, model_digest: str, config_family: str,
               estimate_bytes: int,
               snapshot: GPUMemorySnapshot | None = None,
               observed_bytes: int | None = None) -> dict:
        """Record one observed peak against its estimate. The observed
        peak is the snapshot's *reserved* bytes (what the allocator
        actually held — the quantity xMem estimates) unless
        ``observed_bytes`` is given explicitly."""
        if observed_bytes is None:
            if snapshot is None:
                raise ValueError(
                    "need a snapshot or explicit observed_bytes")
            observed_bytes = snapshot.reserved_bytes
        rec = {
            "kind": "residual",
            "model_digest": model_digest,
            "config_family": config_family,
            "estimate_bytes": int(estimate_bytes),
            "observed_bytes": int(observed_bytes),
            "residual_bytes": int(observed_bytes) - int(estimate_bytes),
            "ratio": (observed_bytes / estimate_bytes
                      if estimate_bytes else None),
        }
        if snapshot is not None:
            rec["snapshot"] = snapshot.to_dict()
        return self.log.append(rec)

    def residuals(self, model_digest: str | None = None,
                  config_family: str | None = None) -> list[dict]:
        out = []
        for rec in self.log.records(kind="residual"):
            if model_digest is not None and \
                    rec.get("model_digest") != model_digest:
                continue
            if config_family is not None and \
                    rec.get("config_family") != config_family:
                continue
            out.append(rec)
        return out

    def summary(self) -> dict:
        """Per-(model digest, config family) residual statistics —
        the shape a calibration pass consumes."""
        groups: dict = {}
        for rec in self.log.records(kind="residual"):
            key = f"{rec.get('model_digest')}/{rec.get('config_family')}"
            g = groups.setdefault(
                key, {"n": 0, "sum_residual": 0, "sum_ratio": 0.0,
                      "max_ratio": None, "min_ratio": None})
            g["n"] += 1
            g["sum_residual"] += rec.get("residual_bytes", 0)
            ratio = rec.get("ratio")
            if ratio is not None:
                g["sum_ratio"] += ratio
                if g["max_ratio"] is None or ratio > g["max_ratio"]:
                    g["max_ratio"] = ratio
                if g["min_ratio"] is None or ratio < g["min_ratio"]:
                    g["min_ratio"] = ratio
        out = {}
        for key, g in groups.items():
            n = g["n"]
            out[key] = {
                "n": n,
                "mean_residual_bytes": g["sum_residual"] / n,
                "mean_ratio": g["sum_ratio"] / n if n else None,
                "max_ratio": g["max_ratio"],
                "min_ratio": g["min_ratio"],
            }
        return out

    def stats(self) -> dict:
        return self.log.stats()

    def close(self) -> None:
        self.log.close()


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="Ingest observed GPU-memory peaks and store "
                    "estimate-vs-observed residuals")
    p.add_argument("--dir", required=True,
                   help="telemetry directory (e.g. STORE/telemetry)")
    p.add_argument("--summary", action="store_true",
                   help="print per-(digest, family) residual summary")
    p.add_argument("--model-digest", help="content digest of the model"
                                          " fn (see fn_digest)")
    p.add_argument("--family", help="config family fingerprint (see "
                                    "request_family)")
    p.add_argument("--estimate-bytes", type=int,
                   help="xMem estimated peak in bytes")
    p.add_argument("--observed-bytes", type=int,
                   help="observed peak in bytes")
    p.add_argument("--observed-mb", type=float,
                   help="observed reserved MB (GPUMemorySnapshot "
                        "shape)")
    p.add_argument("--snapshot-json",
                   help="path to a GPUMemorySnapshot JSON file")
    args = p.parse_args(argv)

    ing = TelemetryIngestor(args.dir)
    try:
        if args.summary:
            print(json.dumps(ing.summary(), indent=2, sort_keys=True))
            return 0
        if not (args.model_digest and args.family
                and args.estimate_bytes is not None):
            p.error("ingestion needs --model-digest, --family and "
                    "--estimate-bytes (or use --summary)")
        snapshot = None
        observed = args.observed_bytes
        if args.snapshot_json:
            with open(args.snapshot_json) as f:
                snapshot = GPUMemorySnapshot.from_dict(json.load(f))
        elif args.observed_mb is not None:
            snapshot = GPUMemorySnapshot(timestamp=0.0,
                                         reserved_mb=args.observed_mb)
        rec = ing.ingest(args.model_digest, args.family,
                         args.estimate_bytes, snapshot=snapshot,
                         observed_bytes=observed)
        print(json.dumps(rec, sort_keys=True))
        return 0
    finally:
        ing.close()


if __name__ == "__main__":
    sys.exit(main())
