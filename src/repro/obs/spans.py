"""Structured tracing spans (ISSUE 10 tentpole, part 1).

A :class:`Tracer` collects context-manager spans with monotonic
timings, parent links, and a per-request **correlation ID** minted in
``AdmissionService.decide`` and carried — via a ``contextvars``
context — through ``TraceCache`` lookups, the columnar replay, the
degradation-ladder rungs, ``RemediationPlanner`` searches and
``FleetScheduler`` placements/evictions. Finished spans export as
Chrome-trace / Perfetto JSON (:meth:`Span.to_chrome_trace` /
:meth:`Tracer.to_chrome_trace`).

Deep pipeline layers never hold an observability handle: they call the
module-level :func:`span` / :func:`event` helpers, which read the
active context from a :class:`contextvars.ContextVar`. When no context
is active (observability disabled — the default) the helpers cost one
``ContextVar.get`` returning ``None`` and a shared ``nullcontext``:
the instrumented pipeline stays bit-identical and within the <3%
overhead gate. ``decide`` runs *on* the worker thread for
``decide_many``, so the ContextVar propagates to every layer a
decision touches without explicit plumbing; the deadline side-thread
(``_call_with_deadline``) copies the caller's context explicitly.

Zero dependencies beyond the standard library by design — this module
must be importable from ``core/`` without cycles.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import itertools
import os
import threading
import time
from collections import deque


@dataclasses.dataclass(slots=True)
class Span:
    """One finished (or in-flight) operation. Timings are
    ``time.perf_counter`` seconds — monotonic, arbitrary origin.
    Slotted: spans are allocated several times per decision on the
    warm path, and skipping the per-instance ``__dict__`` is part of
    staying inside the <3% instrumentation-overhead gate."""

    name: str
    span_id: int
    parent_id: int | None
    correlation_id: str | None
    t_start: float
    t_end: float | None = None
    attrs: dict = dataclasses.field(default_factory=dict)
    thread: int = 0

    @property
    def duration_s(self) -> float:
        return (self.t_end if self.t_end is not None
                else self.t_start) - self.t_start

    def to_chrome_trace(self) -> dict:
        """One Chrome-trace *complete* ("X") event — ts/dur in µs, as
        chrome://tracing and Perfetto expect."""
        args = {k: v for k, v in self.attrs.items()}
        if self.correlation_id:
            args["correlation_id"] = self.correlation_id
        if self.parent_id is not None:
            args["parent_span"] = self.parent_id
        return {"name": self.name, "ph": "X", "pid": os.getpid(),
                "tid": self.thread, "ts": round(self.t_start * 1e6, 3),
                "dur": round(self.duration_s * 1e6, 3), "args": args}


class Tracer:
    """Thread-safe collector of finished spans (bounded ring buffer —
    the oldest spans fall off under sustained load; ``dropped`` counts
    them so truncation is never silent)."""

    def __init__(self, max_spans: int = 4096):
        self.max_spans = max_spans
        self._lock = threading.Lock()
        self._spans: deque[tuple] = deque(maxlen=max_spans)
        # itertools.count.__next__ is a single C call — atomic under
        # the GIL, so span-id allocation needs no lock
        self._ids = itertools.count(1)
        # the span stack is a ContextVar, not thread-local state: a
        # context copied onto a side thread keeps its parent links
        self._stack: contextvars.ContextVar[tuple] = \
            contextvars.ContextVar("xmem_span_stack", default=())
        self.started = 0
        self.dropped = 0

    def _open(self, name: str, correlation_id: str | None,
              attrs: dict) -> Span:
        sid = next(self._ids)
        parents = self._stack.get()
        parent = parents[-1] if parents else None
        return Span(
            name=name, span_id=sid,
            parent_id=parent.span_id if parent is not None else None,
            correlation_id=correlation_id or (
                parent.correlation_id if parent is not None else None),
            t_start=time.perf_counter(), attrs=attrs,
            thread=threading.get_ident())

    def _close(self, sp: Span) -> None:
        sp.t_end = time.perf_counter()
        # retain a plain tuple, not the Span object: tuples/dicts of
        # scalars are untracked by the cyclic GC after their first
        # survey, so a full 4096-entry ring adds nothing to collection
        # scans — while retained *objects* churn into gen2 and trigger
        # full collections over the (large) JAX heap, which is the
        # dominant instrumentation cost on the warm decide path
        rec = (sp.name, sp.span_id, sp.parent_id, sp.correlation_id,
               sp.t_start, sp.t_end, sp.attrs, sp.thread)
        with self._lock:
            self.started += 1
            if len(self._spans) == self._spans.maxlen:
                self.dropped += 1
            self._spans.append(rec)

    def span(self, name: str, correlation_id: str | None = None,
             **attrs) -> "_SpanHandle":
        """Context manager: a span covering the ``with`` body. Nested
        spans link to their parent automatically. (A slotted handle,
        not a ``contextlib`` generator — this sits on the warm decide
        path, where generator setup/teardown is measurable against
        the <3% overhead gate.)"""
        return _SpanHandle(self, self._open(name, correlation_id,
                                            attrs))

    def event(self, name: str, correlation_id: str | None = None,
              **attrs) -> Span:
        """A zero-duration span (point annotation, e.g. a cache hit)."""
        sp = self._open(name, correlation_id, attrs)
        self._close(sp)
        return sp

    def spans(self) -> list[Span]:
        with self._lock:
            recs = list(self._spans)
        return [Span(name=r[0], span_id=r[1], parent_id=r[2],
                     correlation_id=r[3], t_start=r[4], t_end=r[5],
                     attrs=r[6], thread=r[7]) for r in recs]

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.started = 0
            self.dropped = 0

    def to_chrome_trace(self) -> dict:
        """The collected spans as a Chrome-trace JSON object — load it
        in chrome://tracing or ui.perfetto.dev."""
        return {"traceEvents": [s.to_chrome_trace()
                                for s in self.spans()],
                "displayTimeUnit": "ms"}

    def stats(self) -> dict:
        with self._lock:
            return {"spans": len(self._spans), "started": self.started,
                    "dropped": self.dropped,
                    "max_spans": self.max_spans}


class _SpanHandle:
    """Minimal enter/exit wrapper pairing :meth:`Tracer._open` with
    :meth:`Tracer._close`; yields the :class:`Span`."""

    __slots__ = ("_tracer", "_span", "_token")

    def __init__(self, tracer: Tracer, span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        stack = self._tracer._stack
        self._token = stack.set(stack.get() + (self._span,))
        return self._span

    def __exit__(self, *exc) -> bool:
        self._tracer._stack.reset(self._token)
        self._tracer._close(self._span)
        return False


# -- the active observability context ----------------------------------------
@dataclasses.dataclass
class ObsContext:
    """What deep layers see while a request is being decided."""

    tracer: Tracer
    correlation_id: str | None = None


_ACTIVE: contextvars.ContextVar[ObsContext | None] = \
    contextvars.ContextVar("xmem_obs_ctx", default=None)

#: Shared no-op context manager — nullcontext is reentrant and
#: reusable, so one instance serves every disabled call site.
_NOOP = contextlib.nullcontext()


def current() -> ObsContext | None:
    """The active observability context, or None (disabled)."""
    return _ACTIVE.get()


def current_correlation_id() -> str | None:
    ctx = _ACTIVE.get()
    return ctx.correlation_id if ctx is not None else None


def span(name: str, **attrs):
    """A span on the active tracer, or a shared no-op context manager
    when observability is off — one ``ContextVar.get`` either way."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return _NOOP
    return ctx.tracer.span(name, correlation_id=ctx.correlation_id,
                           **attrs)


def event(name: str, **attrs) -> None:
    """A zero-duration annotation on the active tracer (no-op when
    observability is off)."""
    ctx = _ACTIVE.get()
    if ctx is not None:
        ctx.tracer.event(name, correlation_id=ctx.correlation_id,
                         **attrs)


class activate:
    """Install an observability context for the ``with`` body — the
    service's per-request entry point. (Class-based rather than a
    ``contextlib`` generator: it runs once per decision.)"""

    __slots__ = ("_ctx", "_token")

    def __init__(self, tracer: Tracer,
                 correlation_id: str | None = None):
        self._ctx = ObsContext(tracer, correlation_id)

    def __enter__(self) -> ObsContext:
        self._token = _ACTIVE.set(self._ctx)
        return self._ctx

    def __exit__(self, *exc) -> bool:
        _ACTIVE.reset(self._token)
        return False


def mint_correlation_id(prefix: str = "xm") -> str:
    """A fresh per-request correlation ID (64 random bits — the same
    entropy as ``uuid4().hex[:16]`` but without the UUID object
    construction, which is measurable at per-decide frequency)."""
    return f"{prefix}-{os.urandom(8).hex()}"
