"""Analytic roofline terms (napkin math, per DESIGN.md §8).

XLA's cost_analysis undercounts loop bodies (counted once — see
hlo_analysis.py), so the compute/memory roofline terms come from
standard MFU-style analytic accounting over the exact configs:

* FLOPs: (6 + 2*refwd)·N_active·tokens for training (refwd=1 under full
  remat), 2·N_active·tokens for prefill, 2·N_active·batch per decoded
  token — plus the attention quadratic term per attention layer
  (causal-halved; sliding-window layers use min(S, window)).
* HBM bytes: parameter traffic (microbatch-aware: every microbatch
  re-reads the parameters — the real cost of gradient accumulation),
  optimizer read+write, gradient write+read, activation traffic
  (write+read of materialized per-layer tensors; remat re-writes),
  KV-cache read for decode.

All terms are per device on the given mesh.
"""
from __future__ import annotations

from ..configs.base import ModelConfig, ShapeSpec


def _attention_flops(cfg: ModelConfig, S: int, tokens: int) -> float:
    """Quadratic attention FLOPs (fwd, causal) across the stack."""
    if cfg.family == "ssm":
        x = cfg.xlstm
        dv = cfg.d_model // cfg.n_heads
        dk = max(int(dv * x.qk_dim_factor), 8)
        # chunkwise mLSTM: per token, a [chunk] window of k/v
        return 2.0 * tokens * x.chunk * cfg.n_heads * (dk + dv) \
            * cfg.n_layers
    per_layer = []
    for i in range(cfg.n_layers):
        if cfg.family == "hybrid" and cfg.attention.attn_every \
                and i % cfg.attention.attn_every != 0:
            continue  # mamba layer: linear state term, negligible here
        win = S
        if cfg.attention.sliding_window and cfg.attention.global_every:
            if (i % cfg.attention.global_every) != \
                    cfg.attention.global_every - 1:
                win = min(S, cfg.attention.sliding_window)
        # 2 matmuls (QK^T, PV), causal halves the square
        per_layer.append(2.0 * tokens * min(win, S) * cfg.n_heads
                         * cfg.hd)
    return float(sum(per_layer))


def analytic_flops(cfg: ModelConfig, shape: ShapeSpec, *,
                   remat_refwd: bool = True) -> float:
    """Global FLOPs for one step of this cell."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        mult = 6.0 + (2.0 if remat_refwd else 0.0)
        body = mult * n_act * shape.tokens
        attn = _attention_flops(cfg, shape.seq_len, shape.tokens) \
            * (4.0 if remat_refwd else 3.0)
        return body + attn
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.tokens \
            + _attention_flops(cfg, shape.seq_len, shape.tokens)
    # decode: one token per sequence against a seq_len cache
    flops = 2.0 * n_act * shape.global_batch
    if cfg.family != "ssm":
        l_attn = cfg.n_layers
        if cfg.family == "hybrid" and cfg.attention.attn_every:
            l_attn = cfg.n_layers // cfg.attention.attn_every
        flops += 4.0 * shape.global_batch * shape.seq_len * cfg.n_heads \
            * cfg.hd * l_attn
    return flops


def analytic_bytes(cfg: ModelConfig, shape: ShapeSpec, *,
                   n_devices: int, model_shards: int, fsdp_shards: int,
                   microbatches: int = 1, opt_state_mult: float = 2.0,
                   act_tensors_per_layer: float = 14.0,
                   act_passes: float = 3.0) -> float:
    """Per-device HBM traffic (bytes) for one step.

    ``act_passes`` is the number of HBM passes over the materialized
    activations: 3.0 under full remat (write + refwd rewrite + read),
    2.0 with no remat (write + read) — the remediation planner's cost
    model varies it per ``cfg.remat`` candidate."""
    dtype_b = cfg.dtype.itemsize
    p_dev = cfg.param_count() * dtype_b / (model_shards * fsdp_shards)
    dp = max(n_devices // model_shards, 1)
    tokens_dev = shape.tokens / dp if shape.kind != "decode" \
        else shape.global_batch / dp
    if shape.kind == "train":
        # fwd + remat-refwd + bwd parameter reads, per microbatch
        param_traffic = 3.0 * p_dev * microbatches
        opt_b = cfg.param_count() * 4.0 * opt_state_mult \
            / (model_shards * fsdp_shards)
        opt_traffic = 2.0 * opt_b + 3.0 * p_dev  # read+write opt, rw grads
        # activations: materialized tensors written+read (+refwd rewrite)
        act = tokens_dev * cfg.d_model * dtype_b \
            * act_tensors_per_layer * cfg.n_layers * act_passes \
            / microbatches \
            * microbatches  # per-microbatch traffic sums back to total
        return param_traffic + opt_traffic + act
    if shape.kind == "prefill":
        act = tokens_dev * cfg.d_model * dtype_b \
            * act_tensors_per_layer * cfg.n_layers
        return p_dev + act
    # decode: read params once, read the whole cache, write one slot
    if cfg.family == "ssm":
        x = cfg.xlstm
        dv = cfg.d_model // cfg.n_heads
        dk = max(int(dv * x.qk_dim_factor), 8)
        cache_dev = (shape.global_batch / dp) * cfg.n_heads * dk * dv \
            * 4.0 * cfg.n_layers
    else:
        l_kv = cfg.n_layers
        if cfg.family == "hybrid" and cfg.attention.attn_every:
            l_kv = cfg.n_layers // cfg.attention.attn_every
        cache_global = (shape.global_batch * shape.seq_len
                        * cfg.n_kv_heads * cfg.hd * dtype_b * 2 * l_kv)
        cache_dev = cache_global / n_devices  # batch x context sharding
    return p_dev + 2.0 * cache_dev \
        + (shape.global_batch / dp) * cfg.d_model * dtype_b \
        * act_tensors_per_layer * cfg.n_layers


def analytic_peak_bytes(cfg: ModelConfig, shape: ShapeSpec, *,
                        microbatches: int = 1,
                        with_optimizer: bool = True,
                        opt_state_mult: float = 2.0,
                        act_tensors_per_layer: float = 14.0,
                        model_shards: int = 1,
                        fsdp_shards: int = 1) -> int:
    """Closed-form **upper bound** on the per-device peak (bytes).

    The degradation ladder's last rung (ISSUE 6): when replay and the
    decision log are both unavailable, the admission service answers
    from this bound with a widened safety margin. It deliberately
    over-counts — full activation materialization with NO remat credit,
    fp32 optimizer moments, grads coexisting with parameters, plus the
    logits/loss buffers — so a degraded admit stays OOM-safe; the cost
    is headroom, never correctness.
    """
    dtype_b = cfg.dtype.itemsize
    shards = model_shards * fsdp_shards
    params = cfg.param_count() * dtype_b / shards
    grads = params if shape.kind == "train" else 0.0
    opt = (cfg.param_count() * 4.0 * opt_state_mult / shards
           if with_optimizer and shape.kind == "train" else 0.0)
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.tokens / max(int(microbatches), 1)
    acts = tokens * cfg.d_model * dtype_b \
        * act_tensors_per_layer * cfg.n_layers
    # output head: logits + fp32 softmax/loss scratch
    logits = tokens * cfg.padded_vocab * (dtype_b + 4.0)
    inputs = shape.tokens * 4.0 * 2.0      # token ids + targets (int32)
    return int(params + grads + opt + acts + logits + inputs)
