"""Optimized-HLO analysis for roofline terms.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (verified
empirically in tests/test_hlo_analysis.py) — for scan-over-layers
programs that undercounts FLOPs/bytes/collectives by ~L. This module
parses the optimized HLO text into computation blocks, extracts each
while loop's trip count from its condition, and charges in-loop
collectives (and dot FLOPs) multiplied by the enclosing loops' trip
counts.
"""
from __future__ import annotations

import re
from collections import defaultdict

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

def normalize_cost_analysis(ca) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Older JAX returns one dict per device program (a list, usually of
    length 1); newer JAX returns the dict directly. Multi-entry lists are
    summed per numeric key (per-device programs partition the work).
    Returns {} for None/empty.
    """
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return ca
    if isinstance(ca, (list, tuple)):
        if not ca:
            return {}
        if len(ca) == 1:
            return dict(ca[0])
        out: dict = {}
        for entry in ca:
            for k, v in entry.items():
                if isinstance(v, (int, float)):
                    out[k] = out.get(k, 0) + v
                else:
                    out.setdefault(k, v)
        return out
    return {}


def cost_analysis_of(compiled) -> dict:
    """``compiled.cost_analysis()`` with the version normalization."""
    return normalize_cost_analysis(compiled.cost_analysis())


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,\s]*)\]")
_RESULT_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_COMP_HDR_RE = re.compile(r"^(?:%)?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")


def _shape_bytes(ty: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        d = d.strip()
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(ty, 4)


def split_computations(hlo: str) -> dict[str, list[str]]:
    """{computation_name: [instruction lines]} from optimized HLO text.

    Computation headers look like ``%name (params...) -> result {`` (the
    param list may contain nested parens, so the name is taken as the
    first token) or ``ENTRY %name (...) -> ... {``.
    """
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if stripped.endswith("{") and "->" in stripped \
                and not stripped.startswith(" "):
            toks = stripped.split()
            if not toks:
                continue
            name = toks[1] if toks[0] == "ENTRY" and len(toks) > 1 \
                else toks[0]
            cur = name.lstrip("%")
            comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def trip_count_of(cond_lines: list[str]) -> int:
    """Trip count from a while condition: the largest integer constant
    feeding a comparison. Fallback 1 (conservative: never inflates)."""
    consts = []
    # the comparison may be wrapped in a kLoop fusion returning pred[]
    has_compare = any("compare(" in ln or "pred[]" in ln
                      for ln in cond_lines)
    for ln in cond_lines:
        m = re.search(r"constant\((\d+)\)", ln)
        if m:
            consts.append(int(m.group(1)))
    if has_compare and consts:
        return max(consts)
    return 1


def loop_multipliers(hlo: str) -> dict[str, int]:
    """{computation_name: product of enclosing trip counts} — charges
    nested loop bodies correctly (outer trips x inner trips)."""
    comps = split_computations(hlo)
    # direct while edges: parent_comp -> (body, trips)
    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        for ln in lines:
            m = _WHILE_RE.search(ln)
            if m:
                cond, body = m.group(1), m.group(2)
                trips = trip_count_of(comps.get(cond, []))
                edges[name].append((body, trips))
    mult: dict[str, int] = defaultdict(lambda: 1)

    def visit(comp: str, factor: int, depth=0):
        if depth > 12:
            return
        mult[comp] = max(mult[comp], factor)
        for body, trips in edges.get(comp, []):
            visit(body, factor * max(trips, 1), depth + 1)

    for entry in comps:
        if entry not in {b for v in edges.values() for b, _ in v}:
            visit(entry, 1)
    return dict(mult)


def fusion_multipliers(hlo: str) -> dict[str, int]:
    """Map fused computations to their caller's multiplier (collectives
    never live inside fusions, so this is only needed for completeness)."""
    return {}


def collective_bytes(hlo: str) -> dict:
    """Collective operand bytes, loop-trip corrected.

    Returns raw (once-counted) and corrected totals per collective kind.
    """
    comps = split_computations(hlo)
    mults = loop_multipliers(hlo)
    name_bytes: dict[str, int] = {}
    for lines in comps.values():
        for ln in lines:
            rm = _RESULT_RE.match(ln)
            if not rm:
                continue
            rhs = ln.split("=", 1)[1].lstrip() if "=" in ln else ""
            if rhs.startswith("("):
                total = sum(_shape_bytes(t, d) for t, d in
                            _SHAPE_RE.findall(rhs[:rhs.find(")") + 1]))
            else:
                sm = _SHAPE_RE.match(rhs)
                total = _shape_bytes(sm.group(1), sm.group(2)) if sm else 0
            name_bytes[rm.group(1)] = total

    op_re = re.compile(r"(" + "|".join(COLLECTIVES)
                       + r")(?:-start|-done)?\(")
    raw = {c: 0 for c in COLLECTIVES}
    corrected = {c: 0 for c in COLLECTIVES}
    count = {c: 0 for c in COLLECTIVES}
    for comp_name, lines in comps.items():
        mult = mults.get(comp_name, 1)
        for ln in lines:
            m = op_re.search(ln)
            if not m or "-done(" in ln:
                continue
            kind = m.group(1)
            args = ln[m.end():]
            depth, j = 1, 0
            while j < len(args) and depth:
                if args[j] == "(":
                    depth += 1
                elif args[j] == ")":
                    depth -= 1
                j += 1
            operands = re.findall(r"%?([\w.\-]+)", args[:j - 1])
            total = sum(name_bytes.get(n, 0) for n in operands)
            if total == 0:
                rm = _RESULT_RE.match(ln)
                if rm:
                    total = name_bytes.get(rm.group(1), 0)
            raw[kind] += total
            corrected[kind] += total * mult
            count[kind] += 1
    return {
        "bytes": raw, "count": count, "total_bytes": sum(raw.values()),
        "corrected_bytes": corrected,
        "corrected_total_bytes": sum(corrected.values()),
        "loop_multipliers": {k: v for k, v in mults.items() if v > 1},
    }
