"""Production mesh construction (assignment contract).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state. Single-pod: 16x16
(data, model) = 256 chips. Multi-pod: 2x16x16 (pod, data, model) = 512
chips — the ``pod`` axis carries data parallelism across the inter-pod
DCI (gradient all-reduce crosses pods; TP/EP stay inside a pod on ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_smoke_mesh(devices: int | None = None):
    """Tiny mesh over however many (host) devices exist — for tests."""
    n = devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
