"""Serving driver: batched prefill + decode with xMem cache budgeting.

Before allocating KV caches, the xMem serving estimator sizes the peak
(params + caches + decode transients) so the server picks the largest
batch that fits — the serving analogue of the training admission gate.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
      --max-len 64 --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke
from ..core.estimator import XMemEstimator
from ..models import model as M

HBM_BYTES = 16 * 2**30


def pick_batch(cfg, max_len: int, hbm_bytes: int, candidates=(64, 32, 16,
                                                              8, 4, 2, 1)):
    """Largest batch whose serving estimate fits (binary-search-free)."""
    params = M.abstract_params(cfg)
    for b in candidates:
        cache = jax.eval_shape(lambda: M.init_cache(cfg, b, max_len))
        tok = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)} \
            if cfg.family != "audio" else \
            {"codes": jax.ShapeDtypeStruct((b, 1, cfg.num_codebooks),
                                           jnp.int32)}

        def decode(params, cache, batch):
            return M.decode_step(params, cache, batch, jnp.int32(0), cfg)

        rep = XMemEstimator.for_tpu().estimate_serving(
            decode, params, cache, tok)
        if rep.peak_bytes <= hbm_bytes:
            return b, rep
    return 1, rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--hbm-gib", type=float, default=16.0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    batch, rep = pick_batch(cfg, args.max_len,
                            int(args.hbm_gib * 2**30))
    print(f"[xmem] serving batch={batch} "
          f"(peak {rep.peak_bytes/2**20:.1f} MiB, "
          f"est. {rep.wall_time_s*1e3:.0f} ms)")

    params = M.init_params(cfg, jax.random.key(0))
    cache = M.init_cache(cfg, batch, args.max_len)
    if cfg.family == "audio":
        tok = jnp.zeros((batch, 1, cfg.num_codebooks), jnp.int32)
        batch_fn = lambda t: {"codes": t}          # noqa: E731
    else:
        tok = jnp.zeros((batch, 1), jnp.int32)
        batch_fn = lambda t: {"tokens": t}         # noqa: E731

    @jax.jit
    def step(params, cache, tok, pos):
        return M.decode_step(params, cache, batch_fn(tok), pos, cfg)

    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        nxt = jnp.argmax(logits[..., -1, :] if cfg.family != "audio"
                         else logits[:, -1], axis=-1).astype(jnp.int32)
        tok = nxt.reshape(tok.shape)
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x batch {batch} in {dt:.2f}s "
          f"({args.tokens * batch / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
