"""Serving driver: batched prefill + decode with xMem cache budgeting.

Before allocating KV caches, the xMem serving estimator sizes the peak
so the server picks the largest batch that fits — the serving analogue
of the training admission gate. The gate covers BOTH serving phases:
the prefill peak (full-prompt forward with the cache resident) and the
decode-step peak. Gating on the decode step alone — the original bug —
admits batches that OOM during prefill, before a single token decodes.

Two gates live here (ISSUE 9):

* ``pick_batch`` — the static gate: largest fixed batch whose
  monolithic-cache prefill/decode estimates fit;
* ``pick_serving`` — the request-driven gate: a continuous-batching
  runtime over a ``RequestMix`` (paged KV cache, prefix sharing,
  speculative scratch) gated on the worst-case peak of the scripted
  timeline, with serving counter-offers (page size / concurrency /
  KV dtype) on rejection.

Estimates route through the admission service
(:mod:`repro.service.admission`), so repeated gate decisions are warm
(content-addressed trace cache) and, with ``--store-dir``, survive
restarts.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
      --max-len 64 --tokens 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
      --serve-mix 48:16:8,16:48:8 --max-concurrent 8 --page-size 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke
from ..models import model as M
from ..train.train_step import make_prefill_step


def decode_input(cfg, b: int, abstract: bool = True):
    """One-token decode batch for ``M.decode_step``."""
    if abstract:
        tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)  # noqa: E731
    else:
        tok = lambda *sh: jnp.zeros(sh, jnp.int32)             # noqa: E731
    if cfg.family == "audio":
        return {"codes": tok(b, 1, cfg.num_codebooks)}
    return {"tokens": tok(b, 1)}


def prompt_specs(cfg, b: int, seq: int) -> dict:
    """Full-prompt prefill batch (no labels — serving, not training)."""
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)  # noqa: E731
    if cfg.family == "vlm":
        P = cfg.num_patches
        return {"patch_embeds": jax.ShapeDtypeStruct((b, P, cfg.d_model),
                                                     cfg.dtype),
                "tokens": tok(b, max(seq - P, 8))}
    if cfg.family == "audio":
        return {"codes": tok(b, seq, cfg.num_codebooks)}
    return {"tokens": tok(b, seq)}


def make_decode_fn(cfg):
    def decode(params, cache, batch):
        return M.decode_step(params, cache, batch, jnp.int32(0), cfg)
    return decode


def make_prefill_fn(cfg):
    """(params, cache, batch) prefill wrapper: the KV cache rides along
    as persistent state so the prefill estimate includes it."""
    step = make_prefill_step(cfg)

    def prefill(params, cache, batch):
        return step(params, batch), cache
    return prefill


def serving_cache_profile(cfg, max_len: int,
                          probe_delta: int = 8) -> tuple[int, int]:
    """(kv_bytes_per_token, resident_bytes_per_request) of ``cfg``'s
    decode cache — the continuous-batching scheduler's byte inputs.

    Classified by finite differencing ``init_cache`` totals at two max
    lengths (batch 1): the slope is the paged, length-proportional KV
    footprint per token; the intercept is the per-request resident
    state that never pages (SSM / conv state in the ssm and hybrid
    families — constant-size, so a paged server must keep it whole per
    active slot)."""
    def total(L):
        tree = jax.eval_shape(lambda: M.init_cache(cfg, 1, L))
        out = 0
        for leaf in jax.tree_util.tree_leaves(tree):
            n = 1
            for dim in leaf.shape:
                n *= int(dim)
            out += n * leaf.dtype.itemsize
        return out
    lo, hi = total(max_len), total(max_len + probe_delta)
    kv_tok = max((hi - lo) // probe_delta, 0)
    resident = max(lo - kv_tok * max_len, 0)
    return int(kv_tok), int(resident)


def _gate_service(service, store_dir):
    """The admission service a gate call runs against. ``store_dir``
    threads the CLI's persistent trace store through to library callers
    — previously a ``service=None`` call silently rebuilt a storeless
    service and every gate decision re-traced after a restart."""
    if service is not None:
        return service
    from ..service import AdmissionService
    return AdmissionService(workers=1, store_dir=store_dir)


def pick_batch(cfg, max_len: int, hbm_bytes: int,
               candidates=(64, 32, 16, 8, 4, 2, 1), service=None,
               store_dir=None):
    """Largest batch whose serving estimates fit (binary-search-free).

    Gates on ``max(prefill, decode)`` peak. Returns ``(batch, gate)``
    where ``gate`` holds the admitting prefill/decode decisions, or
    ``(None, gate)`` — an explicit no-fit result — when no candidate
    fits (including an empty candidate list or estimates that raise).
    Every failing candidate records its own error in
    ``gate["errors"]`` (``{batch, error}`` rows, in trial order);
    ``gate["error"]`` keeps the most recent one for compact
    reporting."""
    svc = _gate_service(service, store_dir)
    params = M.abstract_params(cfg)
    decode_fn = make_decode_fn(cfg)
    prefill_fn = make_prefill_fn(cfg)
    gate: dict = {"candidates": [], "errors": [], "error": None}
    for b in candidates:
        cache = jax.eval_shape(lambda: M.init_cache(cfg, b, max_len))
        try:
            dec = svc.decide_serving(
                f"{cfg.name}-b{b}-decode", decode_fn, params, cache,
                decode_input(cfg, b), capacity=hbm_bytes)
            pre = svc.decide_serving(
                f"{cfg.name}-b{b}-prefill", prefill_fn, params, cache,
                prompt_specs(cfg, b, max_len), capacity=hbm_bytes)
        except Exception as e:  # noqa: BLE001 — record, try a smaller batch
            err = f"{type(e).__name__}: {e}"
            gate["errors"].append({"batch": b, "error": err})
            gate["error"] = err
            continue
        peak = max(pre.peak_bytes, dec.peak_bytes)
        gate["candidates"].append(
            {"batch": b, "prefill_peak": pre.peak_bytes,
             "decode_peak": dec.peak_bytes, "peak": peak,
             "fits": peak <= hbm_bytes})
        if peak <= hbm_bytes:
            gate.update(batch=b, prefill=pre, decode=dec, peak=peak)
            return b, gate
    return None, gate


def pick_serving(cfg, mix, hbm_bytes: int, *, knobs=None, space=None,
                 max_len: int | None = None, service=None,
                 store_dir=None):
    """Request-driven serving gate: admit/reject a request mix under a
    continuous-batching runtime, with serving counter-offers on
    rejection.

    Returns ``(decision, gate)``. ``gate["serving"]`` carries the
    :class:`~repro.core.estimator.ServingEstimate` summary (worst-case
    vs steady-state peak, paged-vs-monolithic cache bytes);
    ``decision.counter_offers`` is populated when ``space`` enables
    serving axes and the mix does not fit. The decode step is traced at
    batch 1 — every knob candidate (and every ``pick_serving`` retry)
    shares that one cached trace."""
    from ..core.orchestrator import ServingKnobs
    svc = _gate_service(service, store_dir)
    knobs = knobs or ServingKnobs()
    stream = mix.stream() if hasattr(mix, "stream") else mix
    if max_len is None:
        max_len = max(stream.max_seq_len, 8)
    kv_tok, resident = serving_cache_profile(cfg, max_len)
    params = M.abstract_params(cfg)
    decode_fn = make_decode_fn(cfg)
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 1, max_len))
    plan = None
    if space is not None:
        from ..plan import ServingPlanContext
        plan = ServingPlanContext(
            decode_fn, params, cache, decode_input(cfg, 1), mix,
            knobs=knobs, kv_bytes_per_token=kv_tok,
            resident_bytes_per_request=resident, space=space)
    decision = svc.decide_serving(
        f"{cfg.name}-mix", decode_fn, params, cache,
        decode_input(cfg, 1), capacity=hbm_bytes, mix=mix, knobs=knobs,
        kv_bytes_per_token=kv_tok, resident_bytes_per_request=resident,
        plan=plan)
    gate = {"serving": decision.breakdown.get("serving", {}),
            "kv_bytes_per_token": kv_tok,
            "resident_bytes_per_request": resident,
            "max_len": max_len}
    return decision, gate


def parse_mix(spec: str, arrival_period: int = 1,
              shared_prefix_len: int = 0):
    """``prompt:decode:count[,prompt:decode:count...]`` -> RequestMix."""
    from ..core.orchestrator import RequestMix
    buckets = []
    for part in spec.split(","):
        p, d, c = (int(x) for x in part.split(":"))
        buckets.append((p, d, c))
    return RequestMix(buckets=tuple(buckets),
                      arrival_period=max(int(arrival_period), 1),
                      shared_prefix_len=max(int(shared_prefix_len), 0))


def serve_mix_main(cfg, args, svc) -> int:
    """``--serve-mix`` entry: request-driven gate + offer printout."""
    from ..core.orchestrator import ServingKnobs
    from ..plan import PlanSpace
    mix = parse_mix(args.serve_mix, args.arrival_period,
                    args.shared_prefix)
    knobs = ServingKnobs(page_size=args.page_size,
                         max_concurrent=args.max_concurrent,
                         kv_dtype_bytes=args.kv_dtype_bytes,
                         prefix_cache=not args.no_prefix_cache,
                         speculative_k=args.speculative_k)
    space = None
    if args.plan:
        space = PlanSpace(
            page_sizes=(8, 16, 32),
            max_concurrents=tuple(sorted({max(args.max_concurrent // 2, 1),
                                          args.max_concurrent,
                                          args.max_concurrent * 2})),
            kv_dtypes=(1, 2))
    decision, gate = pick_serving(cfg, mix, int(args.hbm_gib * 2**30),
                                  knobs=knobs, space=space,
                                  max_len=args.max_len, service=svc)
    s = gate["serving"]
    verdict = "admitted" if decision.admit else "rejected"
    print(f"[xmem] serve-mix {cfg.name}: {verdict} — worst-case "
          f"{s.get('worst_case_peak_bytes', decision.peak_bytes)/2**20:.1f}"
          f" MiB / steady "
          f"{s.get('steady_state_peak_bytes', 0)/2**20:.1f} MiB vs "
          f"{args.hbm_gib:.2f} GiB "
          f"(paged {s.get('paged_kv_peak_bytes', 0)/2**20:.1f} MiB vs "
          f"monolithic {s.get('monolithic_cache_bytes', 0)/2**20:.1f} "
          f"MiB; source {decision.provenance['source']})")
    for i, o in enumerate(decision.counter_offers or ()):
        k = o.serving["knobs"]
        print(f"[xmem]   offer #{i+1}: page={k['page_size']} "
              f"c={k['max_concurrent']} kv{8*k['kv_dtype_bytes']} "
              f"prefix={'on' if k['prefix_cache'] else 'off'} "
              f"peak={o.peak_bytes/2**20:.1f} MiB "
              f"slowdown=x{o.slowdown:.2f}")
    return 0 if decision.admit else 2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--hbm-gib", type=float, default=16.0)
    ap.add_argument("--store-dir", default=None,
                    help="persistent trace store for the serving gate")
    ap.add_argument("--serve-mix", default=None,
                    help="request-driven gate: prompt:decode:count[,...]")
    ap.add_argument("--arrival-period", type=int, default=1)
    ap.add_argument("--shared-prefix", type=int, default=0)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-concurrent", type=int, default=8)
    ap.add_argument("--kv-dtype-bytes", type=int, default=2)
    ap.add_argument("--no-prefix-cache", action="store_true")
    ap.add_argument("--speculative-k", type=int, default=0)
    ap.add_argument("--plan", action="store_true",
                    help="on rejection, search serving counter-offers")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    from ..service import AdmissionService
    svc = AdmissionService(workers=1, store_dir=args.store_dir)
    if args.serve_mix:
        return serve_mix_main(cfg, args, svc)
    batch, gate = pick_batch(cfg, args.max_len,
                             int(args.hbm_gib * 2**30), service=svc)
    if batch is None:
        err = f" ({gate['error']})" if gate.get("error") else ""
        print(f"[xmem] no serving batch fits "
              f"{args.hbm_gib:.2f} GiB{err} -> rejected")
        return 2
    print(f"[xmem] serving batch={batch} "
          f"(peak {gate['peak']/2**20:.1f} MiB = max(prefill "
          f"{gate['prefill'].peak_bytes/2**20:.1f}, decode "
          f"{gate['decode'].peak_bytes/2**20:.1f}); "
          f"gate source {gate['decode'].provenance['source']})")

    params = M.init_params(cfg, jax.random.key(0))
    cache = M.init_cache(cfg, batch, args.max_len)
    if cfg.family == "audio":
        tok = jnp.zeros((batch, 1, cfg.num_codebooks), jnp.int32)
        batch_fn = lambda t: {"codes": t}          # noqa: E731
    else:
        tok = jnp.zeros((batch, 1), jnp.int32)
        batch_fn = lambda t: {"tokens": t}         # noqa: E731

    @jax.jit
    def step(params, cache, tok, pos):
        return M.decode_step(params, cache, batch_fn(tok), pos, cfg)

    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        nxt = jnp.argmax(logits[..., -1, :] if cfg.family != "audio"
                         else logits[:, -1], axis=-1).astype(jnp.int32)
        tok = nxt.reshape(tok.shape)
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x batch {batch} in {dt:.2f}s "
          f"({args.tokens * batch / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
