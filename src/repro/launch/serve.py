"""Serving driver: batched prefill + decode with xMem cache budgeting.

Before allocating KV caches, the xMem serving estimator sizes the peak
so the server picks the largest batch that fits — the serving analogue
of the training admission gate. The gate covers BOTH serving phases:
the prefill peak (full-prompt forward with the cache resident) and the
decode-step peak. Gating on the decode step alone — the original bug —
admits batches that OOM during prefill, before a single token decodes.

Estimates route through the admission service
(:mod:`repro.service.admission`), so repeated gate decisions are warm
(content-addressed trace cache) and, with ``--store-dir``, survive
restarts.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
      --max-len 64 --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke
from ..models import model as M
from ..train.train_step import make_prefill_step

HBM_BYTES = 16 * 2**30


def decode_input(cfg, b: int, abstract: bool = True):
    """One-token decode batch for ``M.decode_step``."""
    if abstract:
        tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)  # noqa: E731
    else:
        tok = lambda *sh: jnp.zeros(sh, jnp.int32)             # noqa: E731
    if cfg.family == "audio":
        return {"codes": tok(b, 1, cfg.num_codebooks)}
    return {"tokens": tok(b, 1)}


def prompt_specs(cfg, b: int, seq: int) -> dict:
    """Full-prompt prefill batch (no labels — serving, not training)."""
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)  # noqa: E731
    if cfg.family == "vlm":
        P = cfg.num_patches
        return {"patch_embeds": jax.ShapeDtypeStruct((b, P, cfg.d_model),
                                                     cfg.dtype),
                "tokens": tok(b, max(seq - P, 8))}
    if cfg.family == "audio":
        return {"codes": tok(b, seq, cfg.num_codebooks)}
    return {"tokens": tok(b, seq)}


def make_decode_fn(cfg):
    def decode(params, cache, batch):
        return M.decode_step(params, cache, batch, jnp.int32(0), cfg)
    return decode


def make_prefill_fn(cfg):
    """(params, cache, batch) prefill wrapper: the KV cache rides along
    as persistent state so the prefill estimate includes it."""
    step = make_prefill_step(cfg)

    def prefill(params, cache, batch):
        return step(params, batch), cache
    return prefill


def pick_batch(cfg, max_len: int, hbm_bytes: int,
               candidates=(64, 32, 16, 8, 4, 2, 1), service=None):
    """Largest batch whose serving estimates fit (binary-search-free).

    Gates on ``max(prefill, decode)`` peak. Returns ``(batch, gate)``
    where ``gate`` holds the admitting prefill/decode decisions, or
    ``(None, gate)`` — an explicit no-fit result — when no candidate
    fits (including an empty candidate list or estimates that raise;
    the last error is carried in ``gate["error"]``)."""
    from ..service import AdmissionService
    svc = service or AdmissionService(workers=1)
    params = M.abstract_params(cfg)
    decode_fn = make_decode_fn(cfg)
    prefill_fn = make_prefill_fn(cfg)
    gate: dict = {"candidates": [], "error": None}
    for b in candidates:
        cache = jax.eval_shape(lambda: M.init_cache(cfg, b, max_len))
        try:
            dec = svc.decide_serving(
                f"{cfg.name}-b{b}-decode", decode_fn, params, cache,
                decode_input(cfg, b), capacity=hbm_bytes)
            pre = svc.decide_serving(
                f"{cfg.name}-b{b}-prefill", prefill_fn, params, cache,
                prompt_specs(cfg, b, max_len), capacity=hbm_bytes)
        except Exception as e:  # noqa: BLE001 — record, try a smaller batch
            gate["error"] = f"{type(e).__name__}: {e}"
            continue
        peak = max(pre.peak_bytes, dec.peak_bytes)
        gate["candidates"].append(
            {"batch": b, "prefill_peak": pre.peak_bytes,
             "decode_peak": dec.peak_bytes, "peak": peak,
             "fits": peak <= hbm_bytes})
        if peak <= hbm_bytes:
            gate.update(batch=b, prefill=pre, decode=dec, peak=peak)
            return b, gate
    return None, gate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--hbm-gib", type=float, default=16.0)
    ap.add_argument("--store-dir", default=None,
                    help="persistent trace store for the serving gate")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    from ..service import AdmissionService
    svc = AdmissionService(workers=1, store_dir=args.store_dir)
    batch, gate = pick_batch(cfg, args.max_len,
                             int(args.hbm_gib * 2**30), service=svc)
    if batch is None:
        err = f" ({gate['error']})" if gate.get("error") else ""
        print(f"[xmem] no serving batch fits "
              f"{args.hbm_gib:.2f} GiB{err} -> rejected")
        return 2
    print(f"[xmem] serving batch={batch} "
          f"(peak {gate['peak']/2**20:.1f} MiB = max(prefill "
          f"{gate['prefill'].peak_bytes/2**20:.1f}, decode "
          f"{gate['decode'].peak_bytes/2**20:.1f}); "
          f"gate source {gate['decode'].provenance['source']})")

    params = M.init_params(cfg, jax.random.key(0))
    cache = M.init_cache(cfg, batch, args.max_len)
    if cfg.family == "audio":
        tok = jnp.zeros((batch, 1, cfg.num_codebooks), jnp.int32)
        batch_fn = lambda t: {"codes": t}          # noqa: E731
    else:
        tok = jnp.zeros((batch, 1), jnp.int32)
        batch_fn = lambda t: {"tokens": t}         # noqa: E731

    @jax.jit
    def step(params, cache, tok, pos):
        return M.decode_step(params, cache, batch_fn(tok), pos, cfg)

    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        nxt = jnp.argmax(logits[..., -1, :] if cfg.family != "audio"
                         else logits[:, -1], axis=-1).astype(jnp.int32)
        tok = nxt.reshape(tok.shape)
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x batch {batch} in {dt:.2f}s "
          f"({args.tokens * batch / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
