"""Admission-service daemon: estimation-as-a-service over line JSON.

The long-running form of the admission gate (ISSUE 4): a scheduler
connects over TCP (newline-delimited JSON, one request per line) and
gets a priori CPU-only admission decisions without ever touching an
accelerator. The daemon shares one content-addressed trace cache across
all connections and (with ``--store-dir``) persists traces to disk, so
a restarted daemon answers repeat requests without re-tracing.

  PYTHONPATH=src python -m repro.launch.served --port 7777 \
      --store-dir /tmp/xmem-store --workers 2

  # one-shot mode (no socket): read a single request from stdin
  echo '{"kind":"train","arch":"qwen3-32b","smoke":true,"batch":8}' | \
      PYTHONPATH=src python -m repro.launch.served --once

Request kinds:

* ``train`` — ``{"kind":"train","arch":...,"smoke":bool,"optimizer":
  "adamw","microbatches":1,"clip_norm":1.0,"seq":64,"batch":8,
  "hbm_gib":0.25,"probe_min_capacity":false}``
* ``serve`` — ``{"kind":"serve","arch":...,"smoke":bool,"max_len":64,
  "batch":8,"hbm_gib":0.25}`` (gates on max(prefill, decode))
* ``plan`` — the same job fields as ``train`` plus the remediation
  search space: ``{"kind":"plan","arch":...,"batch":32,"hbm_gib":0.01,
  "devices":[4,8],"batch_grid":[16,8],"microbatch_grid":[2,4],
  "remat_grid":["full"],"pad_vocab_multiple":16,"max_offers":5}`` —
  answers a non-fitting job with ranked feasible counter-offers
  (ISSUE 5); grid keys are optional (defaults derive from the job)
* ``stats`` / ``ping`` / ``shutdown``
"""
from __future__ import annotations

import argparse
import json
import socket
import socketserver
import sys
import threading


def _train_job(d: dict):
    """(cfg, policy, shape) from a wire-level train-job description.
    ``seq``/``batch`` are honored in both smoke and full-scale modes
    (full-scale defaults come from TRAIN_4K when absent)."""
    import dataclasses
    from ..configs import get_config, get_smoke
    from ..configs.base import smoke_shape, TRAIN_4K
    from ..train import TrainPolicy

    arch = d["arch"]
    smoke = bool(d.get("smoke", True))
    cfg = get_smoke(arch) if smoke else get_config(arch)
    if d.get("remat"):
        cfg = dataclasses.replace(cfg, remat=str(d["remat"]))
    policy = TrainPolicy(
        optimizer=d.get("optimizer", "adamw"),
        microbatches=int(d.get("microbatches", 1)),
        clip_norm=d.get("clip_norm", 1.0))
    if smoke:
        shape = smoke_shape(int(d.get("seq", 64)), int(d.get("batch", 8)))
    else:
        shape = dataclasses.replace(
            TRAIN_4K,
            seq_len=int(d.get("seq", TRAIN_4K.seq_len)),
            global_batch=int(d.get("batch", TRAIN_4K.global_batch)))
    return cfg, policy, shape


def build_train_request(d: dict):
    """AdmissionRequest from a wire-level train-job description."""
    from ..configs.registry import input_specs
    from ..models import model as M
    from ..service import AdmissionRequest
    from ..train import make_estimator_hooks

    cfg, policy, shape = _train_job(d)
    fwd_bwd, update, opt_init = make_estimator_hooks(cfg, policy)
    return AdmissionRequest(
        job_id=str(d.get("id", f"{d['arch']}-b{shape.global_batch}")),
        fwd_bwd_fn=fwd_bwd, params=M.abstract_params(cfg),
        batch=input_specs(cfg, shape), update_fn=update,
        opt_init_fn=opt_init,
        capacity=int(float(d.get("hbm_gib", 16.0)) * 2**30),
        probe_min_capacity=bool(d.get("probe_min_capacity", False)))


def build_plan_space(d: dict):
    """PlanSpace from the optional wire-level grid keys."""
    from ..plan import PlanSpace
    return PlanSpace(
        batches=(tuple(int(b) for b in d["batch_grid"])
                 if "batch_grid" in d else None),
        microbatches=(tuple(int(m) for m in d["microbatch_grid"])
                      if "microbatch_grid" in d else None),
        remat=(tuple(str(r) for r in d["remat_grid"])
               if "remat_grid" in d else None),
        devices=tuple(int(n) for n in d.get("devices", ())),
        pad_vocab_multiple=d.get("pad_vocab_multiple"),
        max_offers=int(d.get("max_offers", 5)))


def handle_request(service, d: dict) -> dict:
    """One wire request -> one JSON-safe response dict."""
    kind = d.get("kind", "train")
    try:
        if kind == "ping":
            return {"ok": True, "pong": True}
        if kind == "stats":
            return {"ok": True, "stats": service.stats()}
        if kind == "shutdown":
            return {"ok": True, "shutdown": True}
        if kind == "train":
            decision = service.decide(build_train_request(d))
            return {"ok": True, **decision.to_json()}
        if kind == "plan":
            from ..plan import RemediationPlanner
            cfg, policy, shape = _train_job(d)
            planner = RemediationPlanner(service)
            res = planner.plan(
                cfg, policy, shape,
                capacity=int(float(d.get("hbm_gib", 16.0)) * 2**30),
                space=build_plan_space(d),
                job_id=str(d.get("id", f"{d['arch']}-plan")))
            return {"ok": True, **res.to_json()}
        if kind == "serve":
            from ..configs import get_config, get_smoke
            from .serve import pick_batch
            arch = d["arch"]
            cfg = (get_smoke(arch) if d.get("smoke", True)
                   else get_config(arch))
            hbm = int(float(d.get("hbm_gib", 16.0)) * 2**30)
            cand = (int(d["batch"]),) if "batch" in d \
                else (64, 32, 16, 8, 4, 2, 1)
            batch, gate = pick_batch(cfg, int(d.get("max_len", 64)),
                                     hbm, candidates=cand, service=service)
            resp = {"ok": True, "admit": batch is not None,
                    "batch": batch, "candidates": gate["candidates"]}
            if batch is not None:
                resp.update(peak_bytes=gate["peak"],
                            prefill_peak=gate["prefill"].peak_bytes,
                            decode_peak=gate["decode"].peak_bytes,
                            source=gate["decode"].provenance["source"])
            elif gate.get("error"):
                resp["error"] = gate["error"]
            return resp
        return {"ok": False, "error": f"unknown request kind {kind!r}"}
    except Exception as e:  # noqa: BLE001 — a bad request must not kill the daemon
        return {"ok": False, "error": f"{type(e).__name__}: {e}"}


class _Handler(socketserver.StreamRequestHandler):
    def handle(self):
        for raw in self.rfile:
            line = raw.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except ValueError as e:
                resp = {"ok": False, "error": f"bad JSON: {e}"}
            else:
                resp = handle_request(self.server.service, d)
            self.wfile.write((json.dumps(resp) + "\n").encode())
            self.wfile.flush()
            if resp.get("shutdown"):
                threading.Thread(target=self.server.shutdown,
                                 daemon=True).start()
                return


class AdmissionServer(socketserver.ThreadingTCPServer):
    """Line-JSON TCP front of an :class:`AdmissionService`."""

    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr, service):
        super().__init__(addr, _Handler)
        self.service = service


def request_once(host: str, port: int, d: dict, timeout: float = 60.0) -> dict:
    """Client helper: one request/response round trip (used by tests
    and the concurrent-client benchmark)."""
    with socket.create_connection((host, port), timeout=timeout) as s:
        f = s.makefile("rwb")
        f.write((json.dumps(d) + "\n").encode())
        f.flush()
        return json.loads(f.readline())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7777)
    ap.add_argument("--workers", type=int, default=2,
                    help="service worker threads")
    ap.add_argument("--store-dir", default=None,
                    help="persistent trace store directory (content-"
                         "addressed; traces survive daemon restarts)")
    ap.add_argument("--store-max-entries", type=int, default=256)
    ap.add_argument("--once", action="store_true",
                    help="serve one request from stdin and exit")
    args = ap.parse_args()

    from ..service import AdmissionService
    service = AdmissionService(workers=args.workers,
                               store_dir=args.store_dir,
                               store_max_entries=args.store_max_entries)
    if args.once:
        d = json.loads(sys.stdin.readline())
        print(json.dumps(handle_request(service, d)))
        return 0
    with AdmissionServer((args.host, args.port), service) as server:
        host, port = server.server_address[:2]
        store = f", store={args.store_dir}" if args.store_dir else ""
        print(f"[served] admission daemon on {host}:{port} "
              f"({args.workers} workers{store})", flush=True)
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
    service.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
