"""Admission-service daemon: estimation-as-a-service over line JSON.

The long-running form of the admission gate (ISSUE 4): a scheduler
connects over TCP (newline-delimited JSON, one request per line) and
gets a priori CPU-only admission decisions without ever touching an
accelerator. The daemon shares one content-addressed trace cache across
all connections and (with ``--store-dir``) persists traces to disk, so
a restarted daemon answers repeat requests without re-tracing.

  PYTHONPATH=src python -m repro.launch.served --port 7777 \
      --store-dir /tmp/xmem-store --workers 2

  # one-shot mode (no socket): read a single request from stdin
  echo '{"kind":"train","arch":"qwen3-32b","smoke":true,"batch":8}' | \
      PYTHONPATH=src python -m repro.launch.served --once

Request kinds:

* ``train`` — ``{"kind":"train","arch":...,"smoke":bool,"optimizer":
  "adamw","microbatches":1,"clip_norm":1.0,"seq":64,"batch":8,
  "hbm_gib":0.25,"probe_min_capacity":false}``; an optional
  ``"offload":{"optimizer_state":true,"activations":0.5}`` object
  estimates with host offload applied (response breakdown carries
  per-space peaks)
* ``serve`` — ``{"kind":"serve","arch":...,"smoke":bool,"max_len":64,
  "batch":8,"hbm_gib":0.25}`` (gates on max(prefill, decode))
* ``plan`` — the same job fields as ``train`` plus the remediation
  search space: ``{"kind":"plan","arch":...,"batch":32,"hbm_gib":0.01,
  "devices":[4,8],"batch_grid":[16,8],"microbatch_grid":[2,4],
  "remat_grid":["full"],"pad_vocab_multiple":16,"max_offers":5}`` —
  answers a non-fitting job with ranked feasible counter-offers
  (ISSUE 5); grid keys are optional (defaults derive from the job);
  ``"offload_opt_state":true`` / ``"offload_activations":[0.5]``
  add host-offload counter-offers to the search (ISSUE 8)
* ``place`` — fleet scheduling (ISSUE 7): the same job fields as
  ``train`` plus optional ``priority``/``duration_ticks``; the daemon's
  lazily-built :class:`~repro.sched.FleetScheduler` (sized by
  ``--fleet-nodes``/``--fleet-hbm-gib``, or per-request
  ``fleet_nodes``/``fleet_hbm_gib`` on first use) bin-packs the job
  onto a node — answering which node(s), what each is charged, and the
  fleet snapshot after placement
* ``evacuate`` — ``{"kind":"evacuate","node":"node000","event":
  "node.fail"|"node.flap"|"node.shrink"|"restore","shrink_frac":0.5}``
  — applies the fleet event and reports where every displaced job was
  re-placed (or that it was lost)
* ``stats`` / ``ping`` / ``shutdown``
* ``health`` — degradation/robustness diagnostics (ISSUE 6): rung
  counters, retry/timeout totals, store + quarantine state, queue
  depth, daemon in-flight/rejected counts

Hardening (ISSUE 6): request lines are length-bounded (oversized or
malformed lines get a structured ``{"kind": "error"}`` response and the
connection stays up), reads carry a per-connection idle timeout,
``--max-in-flight`` sheds load with ``{"kind": "overloaded"}`` instead
of queueing without bound, and shutdown drains in-flight requests
(new requests are refused with ``{"kind": "draining"}``). ``train``
requests honor a wire-level ``deadline_s`` budget — over-deadline
estimates are answered degraded (see ``repro.service.degrade``).
"""
from __future__ import annotations

import argparse
import json
import socket
import socketserver
import sys
import threading


def _train_job(d: dict):
    """(cfg, policy, shape) from a wire-level train-job description.
    ``seq``/``batch`` are honored in both smoke and full-scale modes
    (full-scale defaults come from TRAIN_4K when absent)."""
    import dataclasses
    from ..configs import get_config, get_smoke
    from ..configs.base import smoke_shape, TRAIN_4K
    from ..train import TrainPolicy

    arch = d["arch"]
    smoke = bool(d.get("smoke", True))
    cfg = get_smoke(arch) if smoke else get_config(arch)
    if d.get("remat"):
        cfg = dataclasses.replace(cfg, remat=str(d["remat"]))
    policy = TrainPolicy(
        optimizer=d.get("optimizer", "adamw"),
        microbatches=int(d.get("microbatches", 1)),
        clip_norm=d.get("clip_norm", 1.0))
    if smoke:
        shape = smoke_shape(int(d.get("seq", 64)), int(d.get("batch", 8)))
    else:
        shape = dataclasses.replace(
            TRAIN_4K,
            seq_len=int(d.get("seq", TRAIN_4K.seq_len)),
            global_batch=int(d.get("batch", TRAIN_4K.global_batch)))
    return cfg, policy, shape


def build_offload_plan(d: dict):
    """OffloadPlan from the optional wire-level ``offload`` object
    (``{"optimizer_state": bool, "activations": 0..1,
    "space": "host_pinned"|"host_pageable"}``); None when absent or
    disabled."""
    o = d.get("offload")
    if not o:
        return None
    from ..core.events import MemorySpace
    from ..core.orchestrator import OffloadPlan
    kw = {}
    if "space" in o:
        kw["space"] = MemorySpace(str(o["space"]))
    if "min_block_bytes" in o:
        kw["min_block_bytes"] = int(o["min_block_bytes"])
    plan = OffloadPlan(
        optimizer_state=bool(o.get("optimizer_state", False)),
        activations=float(o.get("activations", 0.0)), **kw)
    return plan if plan.enabled else None


def build_train_request(d: dict):
    """AdmissionRequest from a wire-level train-job description."""
    from ..configs.registry import input_specs
    from ..models import model as M
    from ..service import AdmissionRequest
    from ..train import make_estimator_hooks

    cfg, policy, shape = _train_job(d)
    fwd_bwd, update, opt_init = make_estimator_hooks(cfg, policy)
    deadline = d.get("deadline_s")
    return AdmissionRequest(
        job_id=str(d.get("id", f"{d['arch']}-b{shape.global_batch}")),
        fwd_bwd_fn=fwd_bwd, params=M.abstract_params(cfg),
        batch=input_specs(cfg, shape), update_fn=update,
        opt_init_fn=opt_init,
        capacity=int(float(d.get("hbm_gib", 16.0)) * 2**30),
        probe_min_capacity=bool(d.get("probe_min_capacity", False)),
        offload=build_offload_plan(d),
        deadline_s=float(deadline) if deadline is not None else None)


def build_plan_space(d: dict):
    """PlanSpace from the optional wire-level grid keys."""
    from ..plan import PlanSpace
    return PlanSpace(
        batches=(tuple(int(b) for b in d["batch_grid"])
                 if "batch_grid" in d else None),
        microbatches=(tuple(int(m) for m in d["microbatch_grid"])
                      if "microbatch_grid" in d else None),
        remat=(tuple(str(r) for r in d["remat_grid"])
               if "remat_grid" in d else None),
        devices=tuple(int(n) for n in d.get("devices", ())),
        pad_vocab_multiple=d.get("pad_vocab_multiple"),
        max_offers=int(d.get("max_offers", 5)),
        offload_opt_state=bool(d.get("offload_opt_state", False)),
        offload_activations=tuple(
            float(f) for f in d.get("offload_activations", ())))


def build_serving_knobs(d: dict):
    """ServingKnobs from a wire-level ``serve_plan`` request."""
    from ..core.orchestrator import ServingKnobs
    return ServingKnobs(
        page_size=int(d.get("page_size", 16)),
        max_concurrent=int(d.get("max_concurrent", 8)),
        kv_dtype_bytes=int(d.get("kv_dtype_bytes", 2)),
        prefix_cache=bool(d.get("prefix_cache", True)),
        speculative_k=int(d.get("speculative_k", 0)))


def build_serving_space(d: dict):
    """Serving-axis PlanSpace from a wire request, or None when the
    request enables no axis (gate only, no counter-offer search)."""
    from ..plan import PlanSpace
    pages = tuple(int(p) for p in d.get("page_sizes", ()))
    concs = tuple(int(c) for c in d.get("max_concurrents", ()))
    dtypes = tuple(int(b) for b in d.get("kv_dtypes", ()))
    prefixes = tuple(bool(x) for x in d.get("prefix_cache_grid", ()))
    if not (pages or concs or dtypes or prefixes):
        return None
    return PlanSpace(page_sizes=pages, max_concurrents=concs,
                     kv_dtypes=dtypes, prefix_cache=prefixes,
                     max_offers=int(d.get("max_offers", 5)))


def build_fleet_arrival(d: dict):
    """JobArrival (fleet placement) from a wire-level train job."""
    from ..service.cluster import JobArrival
    req = build_train_request(d)
    duration = d.get("duration_ticks")
    return JobArrival(
        req.job_id, req.fwd_bwd_fn, req.params, req.batch,
        update_fn=req.update_fn, opt_init_fn=req.opt_init_fn,
        capacity=req.capacity, deadline_s=req.deadline_s,
        family=str(d.get("family", d.get("arch", "workload"))),
        priority=int(d.get("priority", 0)),
        duration_ticks=int(duration) if duration is not None else None)


def fleet_scheduler(service, d: dict, server=None):
    """The daemon's fleet scheduler, built lazily on the first
    ``place``/``evacuate`` request — sized by the server's
    ``--fleet-nodes``/``--fleet-hbm-gib`` flags, overridable by
    ``fleet_nodes``/``fleet_hbm_gib`` on that first request. Shared
    (and internally locked) across all daemon connections."""
    sched = getattr(service, "_fleet_scheduler", None)
    if sched is None:
        from ..sched import FleetScheduler, build_fleet
        n = int(d.get("fleet_nodes",
                      getattr(server, "fleet_nodes", None) or 4))
        hbm = float(d.get("fleet_hbm_gib",
                          getattr(server, "fleet_hbm_gib", None) or 16.0))
        sched = FleetScheduler(service, build_fleet(n, int(hbm * 2**30)),
                               obs=service.obs)
        service._fleet_scheduler = sched
    return sched


def handle_request(service, d: dict, server=None) -> dict:
    """One wire request -> one JSON-safe response dict."""
    kind = d.get("kind", "train")
    service.obs.registry.counter(
        "xmem_daemon_requests_total",
        "Daemon requests by wire kind", labels={"kind": kind}).inc()
    try:
        if kind == "ping":
            return {"ok": True, "pong": True}
        if kind == "stats":
            return {"ok": True, "stats": service.stats()}
        if kind == "health":
            h = service.health()
            if server is not None:
                h["daemon"] = server.daemon_stats()
            return {"ok": True, "health": h}
        if kind == "metrics":
            # the whole registry — service + daemon + fleet + collectors
            # — in both wire shapes, from the one source of truth
            reg = service.obs.registry
            return {"ok": True, "metrics": reg.to_json(),
                    "prometheus": reg.to_prometheus()}
        if kind == "shutdown":
            return {"ok": True, "shutdown": True}
        if kind == "train":
            decision = service.decide(build_train_request(d))
            return {"ok": True, **decision.to_json()}
        if kind == "plan":
            from ..plan import RemediationPlanner
            cfg, policy, shape = _train_job(d)
            planner = RemediationPlanner(service)
            res = planner.plan(
                cfg, policy, shape,
                capacity=int(float(d.get("hbm_gib", 16.0)) * 2**30),
                space=build_plan_space(d),
                job_id=str(d.get("id", f"{d['arch']}-plan")))
            return {"ok": True, **res.to_json()}
        if kind == "place":
            sched = fleet_scheduler(service, d, server)
            out = sched.place(build_fleet_arrival(d))
            return {"ok": True, **out.to_json(),
                    "fleet": sched.fleet.snapshot()}
        if kind == "evacuate":
            sched = fleet_scheduler(service, d, server)
            node = str(d["node"])
            event = str(d.get("event", "node.fail"))
            if event == "restore":
                sched.fleet.restore(node)
                return {"ok": True, "node": node, "event": "restore",
                        "fleet": sched.fleet.snapshot()}
            out = sched.evacuate_node(
                node, event, shrink_frac=float(d.get("shrink_frac", 0.5)))
            return {"ok": True, **out.to_json(),
                    "fleet": sched.fleet.snapshot()}
        if kind == "serve":
            from ..configs import get_config, get_smoke
            from .serve import pick_batch
            arch = d["arch"]
            cfg = (get_smoke(arch) if d.get("smoke", True)
                   else get_config(arch))
            hbm = int(float(d.get("hbm_gib", 16.0)) * 2**30)
            cand = (int(d["batch"]),) if "batch" in d \
                else (64, 32, 16, 8, 4, 2, 1)
            batch, gate = pick_batch(cfg, int(d.get("max_len", 64)),
                                     hbm, candidates=cand, service=service)
            resp = {"ok": True, "admit": batch is not None,
                    "batch": batch, "candidates": gate["candidates"]}
            if batch is not None:
                resp.update(peak_bytes=gate["peak"],
                            prefill_peak=gate["prefill"].peak_bytes,
                            decode_peak=gate["decode"].peak_bytes,
                            source=gate["decode"].provenance["source"])
            elif gate.get("error"):
                resp["error"] = gate["error"]
                resp["errors"] = gate.get("errors", [])
            return resp
        if kind == "serve_plan":
            from ..configs import get_config, get_smoke
            from .serve import parse_mix, pick_serving
            arch = d["arch"]
            cfg = (get_smoke(arch) if d.get("smoke", True)
                   else get_config(arch))
            hbm = int(float(d.get("hbm_gib", 16.0)) * 2**30)
            mix = parse_mix(str(d["mix"]),
                            int(d.get("arrival_period", 1)),
                            int(d.get("shared_prefix", 0)))
            max_len = d.get("max_len")
            decision, gate = pick_serving(
                cfg, mix, hbm, knobs=build_serving_knobs(d),
                space=build_serving_space(d),
                max_len=int(max_len) if max_len is not None else None,
                service=service)
            return {"ok": True, **decision.to_json(),
                    "kv_bytes_per_token": gate["kv_bytes_per_token"],
                    "resident_bytes_per_request":
                        gate["resident_bytes_per_request"]}
        return {"ok": False, "error": f"unknown request kind {kind!r}"}
    except Exception as e:  # noqa: BLE001 — a bad request must not kill the daemon
        return {"ok": False, "error": f"{type(e).__name__}: {e}"}


class _Handler(socketserver.StreamRequestHandler):
    """Hardened line-JSON handler (ISSUE 6).

    A malformed or oversized line costs the CLIENT one structured
    ``{"kind": "error"}`` response, never the daemon its connection or
    its process; an idle connection is dropped at the read timeout; a
    daemon at its in-flight cap answers ``{"kind": "overloaded"}``
    immediately instead of queueing the request behind the pool."""

    def setup(self):
        super().setup()
        self.connection.settimeout(self.server.read_timeout)

    def _send(self, resp: dict) -> None:
        self.wfile.write((json.dumps(resp) + "\n").encode())
        self.wfile.flush()

    def _read_line(self):
        """One bounded line; None at EOF/timeout (drop the connection),
        False for an oversized line (already answered + drained)."""
        limit = self.server.max_line_bytes
        try:
            raw = self.rfile.readline(limit + 1)
        except (TimeoutError, socket.timeout, OSError):
            return None
        if not raw:
            return None
        if len(raw) > limit and not raw.endswith(b"\n"):
            # drain the remainder of the oversized line so the NEXT
            # line parses cleanly, then refuse this one
            while True:
                try:
                    chunk = self.rfile.readline(limit)
                except (TimeoutError, socket.timeout, OSError):
                    return None
                if not chunk or chunk.endswith(b"\n"):
                    break
            self.server._m_oversized.inc()
            self._send({"ok": False, "kind": "error",
                        "error": f"request line exceeds "
                                 f"{limit} bytes"})
            return False
        return raw

    def handle(self):
        server = self.server
        service = server.service
        while True:
            raw = self._read_line()
            if raw is None:
                return
            if raw is False:
                continue
            line = raw.strip()
            if not line:
                continue
            if server.faults is not None:
                try:
                    server.faults.check("socket")
                except Exception as e:  # noqa: BLE001 — injected socket fault
                    self._send({"ok": False, "kind": "error",
                                "error": f"socket fault: {e}"})
                    continue
            try:
                d = json.loads(line)
                if not isinstance(d, dict):
                    raise ValueError("request must be a JSON object")
            except ValueError as e:
                server._m_malformed.inc()
                self._send({"ok": False, "kind": "error",
                            "error": f"bad JSON: {e}"})
                continue
            if server.draining:
                self._send({"ok": False, "kind": "draining",
                            "error": "daemon is shutting down"})
                continue
            if not server.enter():
                server._m_rejected.inc()
                self._send({"ok": False, "kind": "overloaded",
                            "error": f"daemon at max in-flight "
                                     f"({server.max_in_flight})"})
                continue
            try:
                resp = handle_request(service, d, server=server)
            finally:
                server.leave()
            self._send(resp)
            if resp.get("shutdown"):
                threading.Thread(target=server.graceful_shutdown,
                                 daemon=True).start()
                return


class AdmissionServer(socketserver.ThreadingTCPServer):
    """Line-JSON TCP front of an :class:`AdmissionService`.

    ``read_timeout`` bounds how long an idle connection may hold a
    handler thread; ``max_line_bytes`` bounds a single request line;
    ``max_in_flight`` bounds concurrently-executing requests
    (backpressure — excess requests are refused as ``overloaded``, the
    scheduler's cue to retry with backoff rather than pile up)."""

    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr, service, *, read_timeout: float = 60.0,
                 max_line_bytes: int = 1 << 20, max_in_flight: int = 8,
                 faults=None, fleet_nodes: int | None = None,
                 fleet_hbm_gib: float | None = None):
        super().__init__(addr, _Handler)
        self.service = service
        self.fleet_nodes = fleet_nodes
        self.fleet_hbm_gib = fleet_hbm_gib
        self.read_timeout = float(read_timeout)
        self.max_line_bytes = int(max_line_bytes)
        self.max_in_flight = int(max_in_flight)
        self.faults = faults
        self.draining = False
        # daemon counters live in the service's metrics registry
        # (ISSUE 10 satellite): daemon_stats(), health's "daemon"
        # block, and the "metrics" wire kind all read the same
        # objects, so the three surfaces cannot drift
        reg = service.obs.registry
        self._m_in_flight = reg.gauge(
            "xmem_daemon_in_flight", "Requests currently executing")
        self._m_rejected = reg.counter(
            "xmem_daemon_rejected_overload_total",
            "Requests shed at the in-flight cap")
        self._m_malformed = reg.counter(
            "xmem_daemon_malformed_total", "Unparseable request lines")
        self._m_oversized = reg.counter(
            "xmem_daemon_oversized_total",
            "Request lines over --max-line-bytes")
        reg.register_collector("xmem_daemon", self.daemon_stats)
        self._flight_lock = threading.Lock()
        self._idle = threading.Condition(self._flight_lock)

    # read-only legacy surface over the registry counters
    @property
    def in_flight(self) -> int:
        return self._m_in_flight.value

    @property
    def rejected_overload(self) -> int:
        return self._m_rejected.value

    @property
    def malformed(self) -> int:
        return self._m_malformed.value

    @property
    def oversized(self) -> int:
        return self._m_oversized.value

    def enter(self) -> bool:
        with self._flight_lock:
            if self._m_in_flight.value >= self.max_in_flight:
                return False
            self._m_in_flight.inc()
            return True

    def leave(self) -> None:
        with self._flight_lock:
            self._m_in_flight.dec()
            if self._m_in_flight.value == 0:
                self._idle.notify_all()

    def daemon_stats(self) -> dict:
        with self._flight_lock:
            return {"in_flight": self.in_flight,
                    "max_in_flight": self.max_in_flight,
                    "draining": self.draining,
                    "rejected_overload": self.rejected_overload,
                    "malformed": self.malformed,
                    "oversized": self.oversized,
                    "read_timeout_s": self.read_timeout,
                    "max_line_bytes": self.max_line_bytes}

    def graceful_shutdown(self, drain_timeout_s: float = 30.0) -> None:
        """Stop accepting work, let in-flight requests finish (bounded),
        then stop the accept loop. New requests on live connections are
        answered ``{"kind": "draining"}`` while this runs."""
        self.draining = True
        with self._idle:
            self._idle.wait_for(lambda: self.in_flight == 0,
                                timeout=drain_timeout_s)
        self.shutdown()


def request_once(host: str, port: int, d: dict, timeout: float = 60.0) -> dict:
    """Client helper: one request/response round trip (used by tests
    and the concurrent-client benchmark)."""
    with socket.create_connection((host, port), timeout=timeout) as s:
        f = s.makefile("rwb")
        f.write((json.dumps(d) + "\n").encode())
        f.flush()
        return json.loads(f.readline())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7777)
    ap.add_argument("--workers", type=int, default=2,
                    help="service worker threads")
    ap.add_argument("--store-dir", default=None,
                    help="persistent trace store directory (content-"
                         "addressed; traces survive daemon restarts)")
    ap.add_argument("--store-max-entries", type=int, default=256)
    ap.add_argument("--once", action="store_true",
                    help="serve one request from stdin and exit")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="default per-request answer budget; over-budget"
                         " estimates degrade (rung 2/3) instead of "
                         "blocking the scheduler")
    ap.add_argument("--read-timeout", type=float, default=60.0,
                    help="idle-connection read timeout (seconds)")
    ap.add_argument("--max-line-bytes", type=int, default=1 << 20,
                    help="maximum request line length")
    ap.add_argument("--max-in-flight", type=int, default=8,
                    help="max concurrently-executing requests before "
                         "answering 'overloaded'")
    ap.add_argument("--fleet-nodes", type=int, default=None,
                    help="fleet size for 'place'/'evacuate' requests")
    ap.add_argument("--fleet-hbm-gib", type=float, default=None,
                    help="per-node HBM (GiB) for the fleet scheduler")
    ap.add_argument("--metrics", action="store_true",
                    help="enable observability (spans + correlation "
                         "IDs); the 'metrics' wire kind serves the "
                         "registry either way")
    ap.add_argument("--audit-dir", default=None,
                    help="append-only decision audit trail directory "
                         "(crash-safe JSONL; implies --metrics)")
    args = ap.parse_args()

    from ..service import AdmissionService
    obs = None
    if args.metrics or args.audit_dir:
        from ..obs import Observability
        obs = Observability(enabled=True, audit_dir=args.audit_dir)
    service = AdmissionService(workers=args.workers,
                               store_dir=args.store_dir,
                               store_max_entries=args.store_max_entries,
                               deadline_s=args.deadline_s, obs=obs)
    if args.once:
        d = json.loads(sys.stdin.readline())
        print(json.dumps(handle_request(service, d)))
        return 0
    with AdmissionServer((args.host, args.port), service,
                         read_timeout=args.read_timeout,
                         max_line_bytes=args.max_line_bytes,
                         max_in_flight=args.max_in_flight,
                         fleet_nodes=args.fleet_nodes,
                         fleet_hbm_gib=args.fleet_hbm_gib) as server:
        host, port = server.server_address[:2]
        store = f", store={args.store_dir}" if args.store_dir else ""
        print(f"[served] admission daemon on {host}:{port} "
              f"({args.workers} workers{store})", flush=True)
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
    service.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
