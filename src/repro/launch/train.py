"""Training driver with the xMem admission gate (first-class feature).

Flow:
  1. resolve --arch config + shapes + mesh;
  2. **admission gate**: run the xMem estimator on the exact
     (fwd_bwd, update, opt_init) triple of this job; if the per-device
     estimate exceeds HBM, reject (or auto-replan: more microbatches)
     BEFORE touching devices — the paper's scheduler integration;
  3. init or restore from the newest valid checkpoint (fault tolerance);
  4. step loop with periodic checkpoints, straggler monitoring, and an
     emergency checkpoint on any exception.

On this CPU box, use smoke-scale flags:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke \
      --steps 20 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke
from ..configs.base import ShapeSpec, smoke_shape, TRAIN_4K
from ..core.estimator import XMemEstimator
from ..models import model as M
from ..train import (CheckpointManager, StragglerMonitor, SyntheticDataset,
                     TrainPolicy, make_estimator_hooks, make_train_step)

HBM_BYTES = 16 * 2**30     # v5e


def admission_check(cfg, policy: TrainPolicy, shape: ShapeSpec,
                    hbm_bytes: int = HBM_BYTES, shard_factor_fn=None,
                    verbose: bool = True, est: XMemEstimator | None = None,
                    service=None, return_decision: bool = False):
    """xMem gate: estimate peak device memory a priori (CPU-only).

    Decisions route through the admission service
    (:mod:`repro.service.admission`): estimator hooks are re-created per
    decision, but the content-addressed trace cache makes structurally
    identical jobs warm (and, with a persistent store, warm across
    process restarts). Pass ``service`` to amortize across repeated
    gate decisions; ``est`` builds a one-off service around an existing
    estimator's cache (back-compat)."""
    from ..service import AdmissionRequest, AdmissionService
    fwd_bwd, update, opt_init = make_estimator_hooks(cfg, policy)
    from ..configs.registry import input_specs
    params = M.abstract_params(cfg)
    batch = input_specs(cfg, shape)
    if service is None:
        service = AdmissionService(
            workers=1, cache=est.trace_cache if est is not None else None)
    decision = service.decide(AdmissionRequest(
        job_id=f"{cfg.name}/{shape.name}/mb{policy.microbatches}",
        fwd_bwd_fn=fwd_bwd, params=params, batch=batch,
        update_fn=update, opt_init_fn=opt_init,
        shard_factor_fn=shard_factor_fn, capacity=hbm_bytes))
    rep = decision.report
    ok = decision.admit
    if verbose:
        tc = decision.provenance.get("trace_cache", {})
        cache_note = (f", trace cache {tc.get('hits', 0)}h/"
                      f"{tc.get('misses', 0)}m"
                      f" [{decision.provenance['source']}]")
        print(f"[xmem] estimated peak {rep.peak_bytes/2**30:.2f} GiB "
              f"(persistent {rep.persistent_bytes/2**30:.2f}) vs HBM "
              f"{hbm_bytes/2**30:.0f} GiB -> "
              f"{'ADMIT' if ok else 'REJECT'} "
              f"({decision.wall_s:.2f}s estimation{cache_note})")
    if return_decision:
        return ok, rep, decision
    return ok, rep


def replan_if_needed(cfg, policy: TrainPolicy, shape, hbm_bytes,
                     shard_factor_fn=None, service=None):
    """Auto-replan a rejected job through the remediation planner.

    The planner's microbatch axis replaces the old ad-hoc doubling
    loop: candidates are the accumulation factors that still divide the
    global batch (``_split_microbatches`` requires even splits), they
    are probed cheapest-modeled-cost first, and ``early_stop`` bails at
    the first feasible offer — the same trace count as the doubling
    loop, but the chosen plan comes back with its modeled slowdown and
    is reproducible via ``CounterOffer.admission_request``."""
    from ..plan import PlanSpace, RemediationPlanner
    from ..service import AdmissionService
    service = service or AdmissionService(workers=1)  # warm across probes
    ok, rep, decision = admission_check(cfg, policy, shape, hbm_bytes,
                                        shard_factor_fn, service=service,
                                        return_decision=True)
    if ok:
        return policy, rep
    # microbatch axis only: batch size and remat belong to the caller,
    # mirroring the replaced doubling loop's contract; the gate's own
    # rejection is the baseline, so the planner does not re-estimate it
    space = PlanSpace(batches=(), remat=(), devices=(), mb_doublings=3,
                      early_stop=True, max_offers=1)
    res = RemediationPlanner(service).plan(
        cfg, policy, shape, capacity=hbm_bytes, space=space,
        job_id=f"{cfg.name}/{shape.name}", baseline=decision,
        shard_factor_fn=shard_factor_fn)
    offer = res.best()
    if offer is not None:
        p = dataclasses.replace(policy, microbatches=offer.microbatches)
        print(f"[xmem] replanning: microbatches -> {p.microbatches} "
              f"(peak {offer.peak_bytes/2**30:.2f} GiB, modeled "
              f"slowdown x{offer.slowdown:.2f})")
        return p, offer.report
    return policy, rep


def train_loop(cfg, shape, policy: TrainPolicy, *, steps: int,
               ckpt_dir: str, ckpt_every: int = 20,
               hbm_bytes: int = HBM_BYTES, skip_gate: bool = False) -> float:
    """The reusable training loop (admission gate -> resume -> steps ->
    checkpoints -> emergency save). Returns the final loss."""
    import time as _time
    if not skip_gate:
        policy, rep = replan_if_needed(cfg, policy, shape, hbm_bytes)
        if rep.peak_bytes > hbm_bytes:
            raise MemoryError("xmem gate: job will not fit — rejected")
    train_step, opt = make_train_step(cfg, policy)
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))
    ckpt = CheckpointManager(ckpt_dir)
    ds = SyntheticDataset(cfg, shape)
    monitor = StragglerMonitor(n_workers=1)
    params = M.init_params(cfg, jax.random.key(0))
    opt_state = opt.init(params)
    start_step = 0
    restored = ckpt.restore_latest({"params": params,
                                    "opt_state": opt_state})
    if restored is not None:
        start_step, state = restored
        params, opt_state = state["params"], state["opt_state"]
        print(f"[ckpt] resumed from step {start_step}")
    loss = float("nan")
    step = start_step
    try:
        for step in range(start_step, steps):
            t0 = _time.perf_counter()
            batch = jax.tree_util.tree_map(jnp.asarray, ds.batch(step))
            loss, params, opt_state = step_fn(params, opt_state, batch)
            dt = _time.perf_counter() - t0
            monitor.record(0, dt)
            if step % 10 == 0 or step == steps - 1:
                print(f"step {step:5d} loss {float(loss):.4f} "
                      f"({dt*1000:.0f} ms)")
            if (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, {"params": params,
                                     "opt_state": opt_state})
    except BaseException:
        ckpt.emergency(step, {"params": params, "opt_state": opt_state})
        print(f"[ckpt] emergency checkpoint at step {step}")
        raise
    ckpt.save(steps, {"params": params, "opt_state": opt_state})
    return float(loss)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--hbm-gib", type=float, default=16.0)
    ap.add_argument("--skip-gate", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    shape = smoke_shape(args.seq, args.batch) if args.smoke else TRAIN_4K
    policy = TrainPolicy(optimizer=args.optimizer,
                         learning_rate=args.lr,
                         microbatches=args.microbatches)
    try:
        loss = train_loop(cfg, shape, policy, steps=args.steps,
                          ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every,
                          hbm_bytes=int(args.hbm_gib * 2**30),
                          skip_gate=args.skip_gate)
    except MemoryError as e:
        print(f"[xmem] {e}")
        return 2
    print("[done] final loss", loss)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
