import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing: hypothesis -> change -> re-lower -> measure.

Three cells (selection rationale in EXPERIMENTS.md §Perf):
  A qwen3-32b / prefill_32k     — most collective-bound cell family
  B internvl2-1b / train_4k     — worst memory cell (unshardable vocab)
  C kimi-k2-1t-a32b / train_4k  — most representative of the paper's
                                  technique (the memory-gate workload)

Each variant re-lowers the cell with one change and records the roofline
terms; results land in artifacts/hillclimb/*.json and EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.hillclimb [--cell A|B|C|all]
"""
import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402

from ..configs import get_config                          # noqa: E402
from ..configs.base import SHAPES_BY_NAME                 # noqa: E402
from ..configs.registry import input_specs                # noqa: E402
from ..distributed.act_sharding import (DEFAULT_RULES,    # noqa: E402
                                        logical_axis_rules)
from ..distributed.sharding import (ShardingPolicy,       # noqa: E402
                                    batch_shardings, opt_state_shardings,
                                    param_shardings)
from ..models import model as M                           # noqa: E402
from ..train.train_step import (TrainPolicy,              # noqa: E402
                                make_prefill_step, make_train_step)
from .analytic import analytic_bytes, analytic_flops      # noqa: E402
from .hlo_analysis import collective_bytes                # noqa: E402
from .mesh import make_production_mesh, mesh_axis_sizes   # noqa: E402

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9


def _sds(tree, shardings):
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


def lower_cell(cfg, shape_name: str, *, multi_pod=False,
               tpolicy: TrainPolicy | None = None,
               fsdp: bool | None = None) -> dict:
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = mesh_axis_sizes(mesh)
    if cfg.moe is not None:
        groups = sizes.get("data", 1) * sizes.get("pod", 1)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, num_groups=groups))
    if fsdp is None:
        fsdp = cfg.param_count() > 8e9
    axes = ("data", "pod") if "pod" in mesh.axis_names else ("data",)
    spolicy = ShardingPolicy(
        fsdp=fsdp, fsdp_axes=axes,
        batch_axes=tuple(a for a in ("pod", "data")
                         if a in mesh.axis_names))
    aparams = M.abstract_params(cfg)
    params_s = _sds(aparams, param_shardings(aparams, cfg, mesh, spolicy))
    t0 = time.time()
    if shape.kind == "train":
        tpolicy = tpolicy or TrainPolicy(optimizer="adamw", microbatches=1)
        step, opt = make_train_step(cfg, tpolicy)
        aopt = jax.eval_shape(opt.init, aparams)
        opt_s = _sds(aopt, opt_state_shardings(aopt, mesh, spolicy))
        bs = input_specs(cfg, shape)
        batch_s = _sds(bs, batch_shardings(bs, mesh, spolicy))
        with mesh, logical_axis_rules(mesh, DEFAULT_RULES):
            compiled = jax.jit(step, donate_argnums=(0, 1)).lower(
                params_s, opt_s, batch_s).compile()
        micro = tpolicy.microbatches
    else:
        step = make_prefill_step(cfg)
        bs = input_specs(cfg, shape)
        batch_s = _sds(bs, batch_shardings(bs, mesh, spolicy))
        with mesh, logical_axis_rules(mesh, DEFAULT_RULES):
            compiled = jax.jit(step).lower(params_s, batch_s).compile()
        micro = 1
    ma = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    n_dev = mesh.devices.size
    fsdp_shards = (sizes.get("data", 1) * sizes.get("pod", 1)
                   if spolicy.fsdp else 1)
    a_flops = analytic_flops(cfg, shape) / n_dev
    a_bytes = analytic_bytes(cfg, shape, n_devices=n_dev,
                             model_shards=sizes.get("model", 1),
                             fsdp_shards=fsdp_shards, microbatches=micro)
    t_comp = a_flops / PEAK_FLOPS
    t_mem = a_bytes / HBM_BW
    t_coll = coll["corrected_total_bytes"] / ICI_BW
    return {
        "compile_s": time.time() - t0,
        "mem_per_dev_gib": (ma.argument_size_in_bytes
                            + ma.output_size_in_bytes
                            + ma.temp_size_in_bytes
                            - ma.alias_size_in_bytes) / 2**30,
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": max(("compute", t_comp), ("memory", t_mem),
                        ("collective", t_coll), key=lambda x: x[1])[0],
        "coll_corrected_gib": coll["corrected_total_bytes"] / 2**30,
        "coll_raw_gib": coll["total_bytes"] / 2**30,
        "roofline_frac": (a_flops / PEAK_FLOPS)
        / max(t_comp, t_mem, t_coll),
    }


# ---------------------------------------------------------------------------
def cell_A():
    """qwen3-32b prefill_32k — collective-bound."""
    base = get_config("qwen3-32b")
    variants = {
        "baseline": base,
        "repeat_kv": dataclasses.replace(
            base, attention=dataclasses.replace(
                base.attention, repeat_kv_for_tp=True)),
        "repeat_kv+ckv4096": dataclasses.replace(
            base, attention=dataclasses.replace(
                base.attention, repeat_kv_for_tp=True, chunk_kv=4096)),
        # H3: inference needs no gradient/optimizer sharding — FSDP's
        # per-layer param all-gathers are pure overhead for prefill;
        # TP-only weights (params fit: 64 GB bf16 / 16 = 4 GB/dev).
        "no_fsdp": base,
    }
    return "qwen3-32b", "prefill_32k", variants, {}


def cell_B():
    """internvl2-1b train_4k — worst memory (vocab 151655 % 16 != 0)."""
    base = get_config("internvl2-1b")
    variants = {
        "baseline": base,
        "pad_vocab16": dataclasses.replace(base, pad_vocab_multiple=16),
        "pad_vocab16+mb4": dataclasses.replace(base,
                                               pad_vocab_multiple=16),
    }
    policies = {"pad_vocab16+mb4": TrainPolicy(optimizer="adamw",
                                               microbatches=4)}
    return "internvl2-1b", "train_4k", variants, policies


def cell_C():
    """kimi-k2 train_4k — the admission-gate workload (69.8 GiB > HBM)."""
    base = get_config("kimi-k2-1t-a32b")
    variants = {
        "baseline": base,
        "mb16": base,
        "mb32": base,
        "mb32+repeat_kv": dataclasses.replace(
            base, attention=dataclasses.replace(
                base.attention, repeat_kv_for_tp=True)),
    }
    policies = {
        "baseline": TrainPolicy(optimizer="adafactor", microbatches=8),
        "mb16": TrainPolicy(optimizer="adafactor", microbatches=16),
        "mb32": TrainPolicy(optimizer="adafactor", microbatches=32),
        "mb32+repeat_kv": TrainPolicy(optimizer="adafactor",
                                      microbatches=32),
    }
    return "kimi-k2-1t-a32b", "train_4k", variants, policies


CELLS = {"A": cell_A, "B": cell_B, "C": cell_C}


# ---------------------------------------------------------------------------
def xmem_batch_hillclimb(arch: str, hbm_bytes: int, seq: int = 64,
                         max_batch: int = 512, smoke: bool = True,
                         verbose: bool = True,
                         microbatches: int = 1, obs=None,
                         timeline_out: str | None = None) -> dict:
    """Estimator-driven batch-size search: the memory-gate workload the
    estimation fast path exists for (ISSUE 1, re-based on the sweep
    service in ISSUE 2).

    The doubling grid is handed to ``SweepService.estimate_many`` as one
    batch: three probe batches are traced for real, the rest are
    synthesized from the columnar affine trace model (with per-point
    exactness checks) and replayed through the vectorized engine. The
    largest fitting batch wins and its exact minimum feasible capacity
    comes from the single instrumented replay
    (``min_feasible_capacity``) — no per-capacity ``would_oom`` sweep.

    With gradient accumulation (``microbatches > 1``) every probed
    batch — including the sweep service's min/median/max probes and any
    repair probe, which are all drawn from this grid — must divide by
    the accumulation factor (``_split_microbatches`` asserts it), so
    the grid is snapped to multiples of ``microbatches``: it starts at
    the factor itself and doubles from there.
    """
    from ..configs import get_config, get_smoke
    from ..configs.base import smoke_shape
    from ..configs.registry import input_specs
    from ..core.estimator import XMemEstimator
    from ..core.sweep import SweepPoint, SweepService
    from ..models import model as M
    from ..train import TrainPolicy, make_estimator_hooks

    cfg = get_smoke(arch) if smoke else get_config(arch)
    m = max(int(microbatches), 1)
    policy = TrainPolicy(optimizer="adamw", microbatches=m)
    fwd_bwd, update, opt_init = make_estimator_hooks(cfg, policy)
    params = M.abstract_params(cfg)
    est = XMemEstimator.for_tpu()
    svc = SweepService(est)            # hooks are closures: inline service
    grid = []
    b = m                              # snapped: every entry divides by m
    while b <= max_batch:
        grid.append(b)
        b *= 2
    if not grid:
        grid = [m]
    points = [SweepPoint(
        fwd_bwd, params,
        input_specs(cfg, smoke_shape(seq_len=seq, global_batch=gb)),
        update_fn=update, opt_init_fn=opt_init) for gb in grid]
    cid = None
    if obs is not None and obs.enabled:
        # one correlation ID covers the whole gated search — every
        # trace/replay span under it carries the same ID
        with obs.request("hillclimb", job_id=f"{cfg.name}-climb") as cid:
            result = svc.estimate_many(points)
    else:
        result = svc.estimate_many(points)
    probes = []
    best = None
    for gb, rep in zip(grid, result.reports):
        fits = rep.fits(hbm_bytes)
        probes.append({"batch": gb, "peak_bytes": rep.peak_bytes,
                       "fits": fits, "wall_s": rep.wall_time_s,
                       "cache_hits": rep.cache_stats.get("hits", 0)})
        if verbose:
            print(f"[xmem-hillclimb] batch={gb:4d} "
                  f"peak={rep.peak_bytes/2**30:6.3f} GiB "
                  f"{'fits' if fits else 'OOM '}", flush=True)
        if fits and (best is None or gb > best[0]):
            best = (gb, rep)
    out = {"arch": cfg.name, "hbm_bytes": hbm_bytes, "probes": probes,
           "microbatches": m,
           "sweep": {k: result.stats[k] for k in
                     ("points", "traced", "interpolated", "fallback",
                      "wall_s")}}
    if cid is not None:
        out["correlation_id"] = cid
        if verbose:
            print(f"[xmem-hillclimb] correlation_id={cid}", flush=True)
    if timeline_out is not None:
        rep_tl = (best[1] if best is not None
                  else result.reports[-1] if result.reports else None)
        if rep_tl is not None:
            from ..obs.timeline import write_timeline
            out["timeline"] = write_timeline(rep_tl, timeline_out)
            if verbose:
                print(f"[xmem-hillclimb] timeline written to "
                      f"{timeline_out}", flush=True)
    if verbose:
        s = out["sweep"]
        print(f"[xmem-hillclimb] sweep: {s['points']} points, "
              f"{s['traced']} traced, {s['interpolated']} interpolated "
              f"({s['wall_s']*1e3:.0f} ms total)", flush=True)
    if best is not None:
        gb, rep = best
        min_cap = est.min_feasible_capacity(fwd_bwd, params, None,
                                            report=rep)
        out.update(best_batch=gb, best_peak_bytes=rep.peak_bytes,
                   min_feasible_capacity=min_cap)
        if verbose:
            print(f"[xmem-hillclimb] best batch={gb} "
                  f"min feasible capacity "
                  f"{min_cap/2**30:.3f} GiB", flush=True)
    return out


def xmem_mesh_hillclimb(arch: str, hbm_bytes: int, seq: int = 64,
                        batch: int = 32, devices: tuple = (8, 16, 32),
                        smoke: bool = True, verbose: bool = True) -> dict:
    """Estimator-driven mesh-topology search: evaluate every
    (pod, data, model, fsdp) factorization of the candidate device
    counts from ONE cached trace (``SweepService.estimate_mesh_sweep``)
    and pick the cheapest topology whose spec-driven per-device estimate
    fits the budget — the ROADMAP's multi-device scenario axis, with no
    XLA compile and no re-tracing per topology."""
    from ..configs import get_config, get_smoke
    from ..configs.base import smoke_shape
    from ..configs.registry import input_specs
    from ..core.estimator import XMemEstimator
    from ..core.sweep import SweepService, topology_grid
    from ..models import model as M
    from ..train import TrainPolicy, make_estimator_hooks

    cfg = get_smoke(arch) if smoke else get_config(arch)
    policy = TrainPolicy(optimizer="adamw", microbatches=1)
    fwd_bwd, update, opt_init = make_estimator_hooks(cfg, policy)
    params = M.abstract_params(cfg)
    batch_specs = input_specs(cfg, smoke_shape(seq_len=seq,
                                               global_batch=batch))
    grid = [t for n in devices for t in topology_grid(n)]
    svc = SweepService(XMemEstimator.for_tpu())
    result = svc.estimate_mesh_sweep(fwd_bwd, params, batch_specs, grid,
                                     update_fn=update,
                                     opt_init_fn=opt_init, cfg=cfg)
    rows = []
    for topo, rep in result:
        fits = rep.fits(hbm_bytes)
        rows.append({"topology": topo.label, "devices": topo.n_devices,
                     "peak_bytes": rep.peak_bytes, "fits": fits})
        if verbose:
            print(f"[xmem-mesh] {topo.label:14s} dev={topo.n_devices:4d} "
                  f"peak={rep.peak_bytes/2**20:8.2f} MiB "
                  f"{'fits' if fits else 'OOM '}", flush=True)
    out = {"arch": cfg.name, "kind": "xmem_mesh", "hbm_bytes": hbm_bytes,
           "seq": seq, "batch": batch, "topologies": rows,
           "sweep": result.stats}
    best = result.best(hbm_bytes)
    if best is not None:
        topo, rep = best
        out.update(best_topology=topo.label, best_devices=topo.n_devices,
                   best_peak_bytes=rep.peak_bytes)
        if verbose:
            print(f"[xmem-mesh] best: {topo.label} "
                  f"({topo.n_devices} devices, "
                  f"{rep.peak_bytes/2**20:.2f} MiB/device) — "
                  f"{result.stats['topologies']} topologies from "
                  f"{result.stats['trace_cache']['misses']} traces in "
                  f"{result.stats['wall_s']*1e3:.0f} ms", flush=True)
    elif verbose:
        print("[xmem-mesh] no topology fits the budget", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all")
    ap.add_argument("--out", default="artifacts/hillclimb")
    ap.add_argument("--xmem-batch", metavar="ARCH",
                    help="run the estimator-driven batch-size hillclimb "
                         "for ARCH (smoke scale) instead of the cells")
    ap.add_argument("--xmem-mesh", metavar="ARCH",
                    help="run the estimator-driven mesh-topology search "
                         "for ARCH (smoke scale) instead of the cells")
    ap.add_argument("--xmem-plan", metavar="ARCH",
                    help="run the remediation planner for ARCH (smoke "
                         "scale): rank counter-offers (batch/microbatch/"
                         "remat/topology) for a job that misses the "
                         "--hbm-gib budget")
    ap.add_argument("--batch", type=int, default=32,
                    help="rejected job's global batch for --xmem-plan")
    ap.add_argument("--seq", type=int, default=48,
                    help="sequence length for --xmem-plan")
    ap.add_argument("--remat", default=None,
                    help="rejected job's remat policy for --xmem-plan "
                         "(full|dots|none; default: the config's)")
    ap.add_argument("--devices", default="8,16,32",
                    help="comma-separated device counts for --xmem-mesh")
    ap.add_argument("--hbm-gib", type=float, default=0.25,
                    help="capacity budget for --xmem-batch/--xmem-mesh "
                         "(smoke scale)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation factor for --xmem-batch "
                         "(the sweep grid snaps to its multiples)")
    ap.add_argument("--timeline-out", default=None,
                    help="write a Perfetto/chrome-trace memory timeline "
                         "of the winning probe's replay to this path "
                         "(--xmem-batch only)")
    args = ap.parse_args()
    if args.xmem_plan:
        from ..obs import Observability
        from ..plan import run_plan_search
        from ..service import AdmissionService
        devices = tuple(int(d) for d in args.devices.split(","))
        svc = AdmissionService(workers=1,
                               obs=Observability(enabled=True))
        r = run_plan_search(args.xmem_plan, int(args.hbm_gib * 2**30),
                            seq=args.seq, batch=args.batch,
                            microbatches=args.microbatches,
                            remat=args.remat, devices=devices,
                            service=svc)
        os.makedirs(args.out, exist_ok=True)
        path = os.path.join(args.out, f"xmem_plan__{args.xmem_plan}.json")
        with open(path, "w") as f:
            json.dump(r, f, indent=1)
        if r.get("correlation_id"):
            print(f"[xmem-plan] correlation_id={r['correlation_id']}")
        print(f"[xmem-plan] wrote {path}")
        return
    if args.xmem_mesh:
        devices = tuple(int(d) for d in args.devices.split(","))
        r = xmem_mesh_hillclimb(args.xmem_mesh,
                                int(args.hbm_gib * 2**30),
                                devices=devices)
        os.makedirs(args.out, exist_ok=True)
        path = os.path.join(args.out, f"xmem_mesh__{args.xmem_mesh}.json")
        with open(path, "w") as f:
            json.dump(r, f, indent=1)
        print(f"[xmem-mesh] wrote {path}")
        return
    if args.xmem_batch:
        from ..obs import Observability
        r = xmem_batch_hillclimb(args.xmem_batch,
                                 int(args.hbm_gib * 2**30),
                                 microbatches=args.microbatches,
                                 obs=Observability(enabled=True),
                                 timeline_out=args.timeline_out)
        os.makedirs(args.out, exist_ok=True)
        path = os.path.join(args.out, f"xmem_batch__{args.xmem_batch}.json")
        with open(path, "w") as f:
            json.dump(r, f, indent=1)
        print(f"[xmem-hillclimb] wrote {path}")
        return
    os.makedirs(args.out, exist_ok=True)
    names = list(CELLS) if args.cell == "all" else [args.cell]
    for name in names:
        arch, shape, variants, policies = CELLS[name]()
        for vname, cfg in variants.items():
            path = os.path.join(args.out, f"{name}__{vname}.json")
            if os.path.exists(path):
                r = json.load(open(path))
            else:
                try:
                    r = lower_cell(cfg, shape,
                                   tpolicy=policies.get(vname),
                                   fsdp=(False if "no_fsdp" in vname
                                         else None))
                except Exception as e:  # noqa: BLE001
                    r = {"error": f"{type(e).__name__}: {e}"}
                r.update(cell=name, arch=arch, shape=shape,
                         variant=vname)
                with open(path, "w") as f:
                    json.dump(r, f, indent=1)
            if "error" in r:
                print(f"[{name}/{vname}] ERROR {r['error'][:100]}",
                      flush=True)
            else:
                print(f"[{name}/{vname}] mem={r['mem_per_dev_gib']:.2f}GiB "
                      f"comp={r['t_compute_s']:.4f}s "
                      f"mem_t={r['t_memory_s']:.4f}s "
                      f"coll={r['t_collective_s']:.4f}s "
                      f"dom={r['dominant']} "
                      f"roofline={r['roofline_frac']*100:.1f}%",
                      flush=True)


if __name__ == "__main__":
    main()
