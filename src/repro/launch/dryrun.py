import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the
device count at first init) — this is why the docstring sits below them.

Per cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. materializes abstract params / optimizer state / batch as sharded
     ShapeDtypeStructs (zero allocation);
  3. ``jax.jit(step).lower(...).compile()`` — success proves the
     sharding config is coherent end-to-end;
  4. prints ``memory_analysis()`` (does it fit?) and ``cost_analysis()``
     (FLOPs/bytes for the roofline);
  5. parses the optimized HLO for collective operand bytes;
  6. writes a JSON artifact consumed by benchmarks/roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import re           # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from ..configs import get_config                          # noqa: E402
from ..configs.base import (SHAPES_BY_NAME, ShapeSpec,    # noqa: E402
                            supports_long_context)
from ..configs.registry import (abstract_cache,           # noqa: E402
                                decode_input_specs, input_specs)
from ..distributed.act_sharding import (DEFAULT_RULES,    # noqa: E402
                                        logical_axis_rules)
from ..distributed.sharding import (ShardingPolicy,       # noqa: E402
                                    batch_shardings, cache_shardings,
                                    opt_state_shardings, param_shardings)
from ..models import model as M                           # noqa: E402
from ..train.train_step import (TrainPolicy,              # noqa: E402
                                make_serve_step, make_train_step)
from .analytic import analytic_bytes, analytic_flops     # noqa: E402
from .hlo_analysis import (collective_bytes as hlo_collective_bytes,  # noqa: E402
                           cost_analysis_of)
from .mesh import make_production_mesh, mesh_axis_sizes   # noqa: E402

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


# ---------------------------------------------------------------------------
def arch_train_policy(arch: str, cfg) -> TrainPolicy:
    """Per-arch training policy a real team would pick at this scale."""
    n = cfg.param_count()
    if n > 80e9:
        return TrainPolicy(optimizer="adafactor", microbatches=8,
                           clip_norm=1.0)
    if n > 8e9:
        return TrainPolicy(optimizer="adamw", microbatches=4, clip_norm=1.0)
    return TrainPolicy(optimizer="adamw", microbatches=1, clip_norm=1.0)


def arch_sharding_policy(cfg, mesh) -> ShardingPolicy:
    axes = ("data", "pod") if "pod" in mesh.axis_names else ("data",)
    fsdp = cfg.param_count() > 8e9     # ZeRO-3 for everything sizable
    return ShardingPolicy(fsdp=fsdp, fsdp_axes=axes,
                          batch_axes=tuple(a for a in ("pod", "data")
                                           if a in mesh.axis_names))


def _with_moe_groups(cfg, mesh):
    if cfg.moe is None:
        return cfg
    sizes = mesh_axis_sizes(mesh)
    groups = sizes.get("data", 1) * sizes.get("pod", 1)
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_groups=groups))


def _sds(tree, shardings):
    """Attach shardings to ShapeDtypeStructs."""
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


# ---------------------------------------------------------------------------
def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in optimized HLO.

    Builds a name->result-bytes table in one pass, then resolves each
    collective's operand names; falls back to the collective's own result
    shape when an operand is unresolvable.
    """
    dtype_bytes = {
        "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
        "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8,
        "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    }

    def shape_bytes(ty: str, dims: str) -> int:
        n = 1
        for d in dims.split(","):
            d = d.strip()
            if d:
                n *= int(d)
        return n * dtype_bytes.get(ty, 4)

    name_bytes: dict[str, int] = {}
    result_re = re.compile(
        r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(?:\()?([a-z0-9]+)\[([\d,\s]*)\]")
    tuple_re = re.compile(r"([a-z0-9]+)\[([\d,\s]*)\]")
    for line in hlo_text.splitlines():
        m = result_re.match(line)
        if m:
            name = m.group(1)
            if line.split("=", 1)[1].lstrip().startswith("("):
                # tuple result: sum element sizes
                rhs = line.split("=", 1)[1]
                paren = rhs[:rhs.find(")") + 1]
                total = sum(shape_bytes(t, d)
                            for t, d in tuple_re.findall(paren))
                name_bytes[name] = total
            else:
                name_bytes[name] = shape_bytes(m.group(2), m.group(3))

    out = {c: 0 for c in COLLECTIVES}
    count = {c: 0 for c in COLLECTIVES}
    op_re = re.compile(r"(" + "|".join(COLLECTIVES) + r")(?:-start|-done)?\(")
    for line in hlo_text.splitlines():
        m = op_re.search(line)
        if not m or "-done(" in line:
            continue  # count each start/fused op once
        kind = m.group(1)
        args = line[m.end():]
        depth, j = 1, 0
        while j < len(args) and depth:
            if args[j] == "(":
                depth += 1
            elif args[j] == ")":
                depth -= 1
            j += 1
        operand_names = re.findall(r"%?([\w.\-]+)", args[:j - 1])
        total = sum(name_bytes.get(n, 0) for n in operand_names)
        if total == 0:
            rm = result_re.match(line)
            if rm:
                total = name_bytes.get(rm.group(1), 0)
        out[kind] += total
        count[kind] += 1
    return {"bytes": out, "count": count,
            "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "artifacts/dryrun",
             skip_existing: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
              "ok": False}
    if shape.name == "long_500k" and not supports_long_context(cfg):
        record.update(skipped=True, reason="full-attention arch: "
                      "long_500k requires sub-quadratic family "
                      "(DESIGN.md §4)")
        _write(path, record)
        return record

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cfg = _with_moe_groups(cfg, mesh)
        spolicy = arch_sharding_policy(cfg, mesh)
        record["sharding"] = {"fsdp": spolicy.fsdp}
        aparams = M.abstract_params(cfg)
        pshard = param_shardings(aparams, cfg, mesh, spolicy)
        params_s = _sds(aparams, pshard)

        if shape.kind == "train":
            tpolicy = arch_train_policy(arch, cfg)
            record["train_policy"] = {
                "optimizer": tpolicy.optimizer,
                "microbatches": tpolicy.microbatches}
            step, opt = make_train_step(cfg, tpolicy)
            aopt = jax.eval_shape(opt.init, aparams)
            oshard = opt_state_shardings(aopt, mesh, spolicy)
            opt_s = _sds(aopt, oshard)
            bspecs = input_specs(cfg, shape)
            bshard = batch_shardings(bspecs, mesh, spolicy)
            batch_s = _sds(bspecs, bshard)
            with mesh, logical_axis_rules(mesh, DEFAULT_RULES):
                lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                    params_s, opt_s, batch_s)
                compiled = lowered.compile()
        elif shape.kind == "prefill":
            from ..train.train_step import make_prefill_step
            step = make_prefill_step(cfg)
            bspecs = input_specs(cfg, shape)
            bshard = batch_shardings(bspecs, mesh, spolicy)
            batch_s = _sds(bspecs, bshard)
            with mesh, logical_axis_rules(mesh, DEFAULT_RULES):
                lowered = jax.jit(step).lower(params_s, batch_s)
                compiled = lowered.compile()
        else:  # decode
            step = make_serve_step(cfg, cache_len=shape.seq_len - 1)
            acache = abstract_cache(cfg, shape)
            cshard = cache_shardings(acache, mesh, spolicy)
            cache_s = _sds(acache, cshard)
            bspecs = decode_input_specs(cfg, shape)
            bshard = batch_shardings(bspecs, mesh, spolicy)
            batch_s = _sds(bspecs, bshard)
            with mesh, logical_axis_rules(mesh, DEFAULT_RULES):
                lowered = jax.jit(step, donate_argnums=(1,)).lower(
                    params_s, cache_s, batch_s)
                compiled = lowered.compile()

        ma = compiled.memory_analysis()
        print(ma)
        ca = cost_analysis_of(compiled)
        print({k: ca.get(k) for k in ("flops", "bytes accessed")})
        hlo = compiled.as_text()
        coll = hlo_collective_bytes(hlo)
        n_dev = mesh.devices.size
        sizes = mesh_axis_sizes(mesh)
        micro = record.get("train_policy", {}).get("microbatches", 1)
        fsdp_shards = (sizes.get("data", 1) * sizes.get("pod", 1)
                       if spolicy.fsdp else 1)
        a_flops = analytic_flops(cfg, shape) / n_dev
        a_bytes = analytic_bytes(
            cfg, shape, n_devices=n_dev,
            model_shards=sizes.get("model", 1), fsdp_shards=fsdp_shards,
            microbatches=micro)
        record.update(
            ok=True,
            compile_s=time.time() - t0,
            devices=int(n_dev),
            memory={
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "generated_code_bytes": int(
                    ma.generated_code_size_in_bytes),
                "per_device_bytes": int(
                    ma.argument_size_in_bytes + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
            },
            cost={
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                # cost_analysis counts while bodies once; analytic terms
                # are the corrected roofline inputs (launch/analytic.py)
                "analytic_flops_per_device": float(a_flops),
                "analytic_bytes_per_device": float(a_bytes),
            },
            collectives=coll,
            hlo_ops=len(hlo.splitlines()),
        )
    except Exception as e:  # noqa: BLE001 — record the failure, move on
        record.update(ok=False, error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:],
                      compile_s=time.time() - t0)
    _write(path, record)
    return record


def _write(path: str, record: dict) -> None:
    with open(path + ".tmp", "w") as f:
        json.dump(record, f, indent=1)
    os.replace(path + ".tmp", path)


def iter_cells():
    from ..configs import ARCH_IDS
    for arch in ARCH_IDS:
        for shape_name in ("train_4k", "prefill_32k", "decode_32k",
                           "long_500k"):
            yield arch, shape_name


# ---------------------------------------------------------------------------
def xmem_gate(arch: str, hbm_gib: float = 0.25, seq: int = 64,
              batches: tuple = (1, 2, 4, 8, 12, 16, 24, 32, 48, 64),
              out_dir: str = "artifacts/dryrun", microbatches: int = 1,
              service=None, store_dir: str | None = None,
              obs=None, timeline_out: str | None = None) -> dict:
    """Estimator-side admission gate for a dry-run cell family: sweep
    the candidate batch sizes through the admission service's batched
    path (``AdmissionService.decide_sweep`` -> columnar trace
    interpolation + vectorized replay) BEFORE paying any XLA compile,
    and record which settings fit the device. Smoke-scale configs keep
    this runnable anywhere; the full-scale dry-run then only compiles
    settings the gate admits. With gradient accumulation the candidate
    grid snaps to multiples of ``microbatches`` (non-divisible batches
    cannot even be traced — ``_split_microbatches`` asserts)."""
    from ..configs import get_smoke
    from ..configs.base import smoke_shape
    from ..configs.registry import input_specs
    from ..models import model as M
    from ..service import AdmissionRequest, AdmissionService
    from ..train import TrainPolicy, make_estimator_hooks

    if service is not None and store_dir is not None:
        raise ValueError("pass either service= or store_dir=, not both "
                         "(a provided service keeps its own store)")
    cfg = get_smoke(arch)
    m = max(int(microbatches), 1)
    batches = tuple(b for b in batches if b % m == 0) or (m,)
    tpolicy = TrainPolicy(optimizer="adamw", microbatches=m)
    fwd_bwd, update, opt_init = make_estimator_hooks(cfg, tpolicy)
    params = M.abstract_params(cfg)
    svc = service or AdmissionService(workers=1, store_dir=store_dir,
                                      obs=obs)
    hbm = int(hbm_gib * 2**30)
    reqs = [AdmissionRequest(
        job_id=f"{cfg.name}-b{b}", fwd_bwd_fn=fwd_bwd, params=params,
        batch=input_specs(cfg, smoke_shape(seq_len=seq, global_batch=b)),
        update_fn=update, opt_init_fn=opt_init, capacity=hbm)
        for b in batches]
    decisions = svc.decide_sweep(reqs)
    record = {
        "arch": cfg.name, "kind": "xmem_gate", "hbm_bytes": hbm,
        "seq": seq, "microbatches": m,
        "sweep": decisions[0].provenance["sweep"] if decisions else {},
        "settings": [
            {"batch": b, "peak_bytes": d.peak_bytes, "fits": d.admit}
            for b, d in zip(batches, decisions)],
    }
    record["admitted"] = [s["batch"] for s in record["settings"]
                          if s["fits"]]
    cid = decisions[0].correlation_id if decisions else None
    if cid is not None:
        record["correlation_id"] = cid
    if timeline_out is not None:
        # Perfetto memory timeline of the largest batch's replay
        rep = next((d.report for d in reversed(decisions)
                    if d.report is not None), None)
        if rep is not None:
            from ..obs.timeline import write_timeline
            record["timeline"] = write_timeline(rep, timeline_out)
    os.makedirs(out_dir, exist_ok=True)
    _write(os.path.join(out_dir, f"{arch}__xmem_gate.json"), record)
    return record


def xmem_mesh_gate(arch: str, hbm_gib: float = 0.25, seq: int = 64,
                   batch: int = 32, devices: tuple = (8, 16, 32),
                   out_dir: str = "artifacts/dryrun") -> dict:
    """Per-device admission gate over mesh topologies: every
    (pod, data, model, fsdp) factorization of the candidate device
    counts is estimated from ONE cached trace with spec-driven shard
    factors and per-axis collective staging buffers — BEFORE paying any
    XLA compile. The full-scale dry-run then only compiles mesh cells
    the gate admits (smoke-scale configs keep this runnable anywhere)."""
    from ..configs import get_smoke
    from ..configs.base import smoke_shape
    from ..configs.registry import input_specs
    from ..core.estimator import XMemEstimator
    from ..core.sweep import SweepService, topology_grid
    from ..models import model as M
    from ..train import TrainPolicy, make_estimator_hooks

    cfg = get_smoke(arch)
    tpolicy = TrainPolicy(optimizer="adamw", microbatches=1)
    fwd_bwd, update, opt_init = make_estimator_hooks(cfg, tpolicy)
    params = M.abstract_params(cfg)
    batch_specs = input_specs(cfg, smoke_shape(seq_len=seq,
                                               global_batch=batch))
    grid = [t for n in devices for t in topology_grid(n)]
    svc = SweepService(XMemEstimator.for_tpu())
    result = svc.estimate_mesh_sweep(fwd_bwd, params, batch_specs, grid,
                                     update_fn=update,
                                     opt_init_fn=opt_init, cfg=cfg)
    hbm = int(hbm_gib * 2**30)
    record = {
        "arch": cfg.name, "kind": "xmem_mesh_gate", "hbm_bytes": hbm,
        "seq": seq, "batch": batch,
        "sweep": result.stats,
        "topologies": [
            {"topology": t.label, "devices": t.n_devices,
             "peak_bytes": rep.peak_bytes,
             "persistent_bytes": rep.persistent_bytes,
             "fits": rep.fits(hbm)}
            for t, rep in result],
    }
    record["admitted"] = [r["topology"] for r in record["topologies"]
                          if r["fits"]]
    best = result.best(hbm)
    if best is not None:
        record["best_topology"] = best[0].label
    os.makedirs(out_dir, exist_ok=True)
    _write(os.path.join(out_dir, f"{arch}__xmem_mesh_gate.json"), record)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--xmem-gate", metavar="ARCH",
                    help="run the estimator-side batch admission sweep "
                         "for ARCH (smoke scale, no compile) and exit")
    ap.add_argument("--xmem-mesh-gate", metavar="ARCH",
                    help="run the estimator-side mesh-topology admission "
                         "sweep for ARCH (smoke scale, no compile) and "
                         "exit")
    ap.add_argument("--xmem-plan", metavar="ARCH",
                    help="run the remediation planner for ARCH (smoke "
                         "scale, no compile): a job that misses the "
                         "--hbm-gib budget is answered with ranked "
                         "feasible counter-offers, written as an "
                         "artifact")
    ap.add_argument("--plan-batch", type=int, default=32,
                    help="rejected job's global batch for --xmem-plan")
    ap.add_argument("--plan-seq", type=int, default=48,
                    help="sequence length for --xmem-plan")
    ap.add_argument("--devices", default="8,16,32",
                    help="comma-separated device counts for "
                         "--xmem-mesh-gate")
    ap.add_argument("--hbm-gib", type=float, default=0.25,
                    help="capacity budget for --xmem-gate/"
                         "--xmem-mesh-gate (smoke scale)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation factor for --xmem-gate "
                         "(the candidate grid snaps to its multiples)")
    ap.add_argument("--timeline-out", default=None,
                    help="write a Perfetto/chrome-trace memory timeline "
                         "of the gated replay to this path "
                         "(--xmem-gate only)")
    args = ap.parse_args()

    if args.xmem_plan:
        from ..obs import Observability
        from ..plan import run_plan_search
        from ..service import AdmissionService
        devices = tuple(int(d) for d in args.devices.split(","))
        svc = AdmissionService(workers=1, obs=Observability(enabled=True))
        r = run_plan_search(args.xmem_plan, int(args.hbm_gib * 2**30),
                            seq=args.plan_seq, batch=args.plan_batch,
                            microbatches=args.microbatches,
                            devices=devices, service=svc)
        os.makedirs(args.out, exist_ok=True)
        _write(os.path.join(args.out, f"{args.xmem_plan}__xmem_plan.json"),
               r)
        s = r["stats"]
        if r["admit"]:
            print(f"[xmem-plan] {r['arch']}: already fits")
        else:
            print(f"[xmem-plan] {r['arch']}: {len(r['counter_offers'])} "
                  f"offers from {s['candidates']} candidates "
                  f"({s['fresh_traces']} fresh traces)")
        if r.get("correlation_id"):
            print(f"[xmem-plan] correlation_id={r['correlation_id']}")
        return

    if args.xmem_mesh_gate:
        devices = tuple(int(d) for d in args.devices.split(","))
        r = xmem_mesh_gate(args.xmem_mesh_gate, hbm_gib=args.hbm_gib,
                           devices=devices, out_dir=args.out)
        s = r["sweep"]
        print(f"[xmem-mesh-gate] {r['arch']}: "
              f"{len(r['admitted'])}/{s['topologies']} topologies "
              f"admitted (best {r.get('best_topology', '—')}; "
              f"{s['trace_cache']['misses']} phases traced, "
              f"{s['wall_s']*1e3:.0f} ms)")
        return

    if args.xmem_gate:
        from ..obs import Observability
        r = xmem_gate(args.xmem_gate, hbm_gib=args.hbm_gib,
                      out_dir=args.out, microbatches=args.microbatches,
                      obs=Observability(enabled=True),
                      timeline_out=args.timeline_out)
        s = r["sweep"]
        print(f"[xmem-gate] {r['arch']}: admitted batches "
              f"{r['admitted']} of "
              f"{[x['batch'] for x in r['settings']]} "
              f"({s['traced']} traced / {s['interpolated']} "
              f"interpolated)")
        if r.get("correlation_id"):
            print(f"[xmem-gate] correlation_id={r['correlation_id']}")
        if r.get("timeline"):
            print(f"[xmem-gate] timeline written to {r['timeline']}")
        return

    meshes = (False, True) if (args.both_meshes or args.all) \
        else (args.multi_pod,)
    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    results = []
    for arch, shape_name in cells:
        for mp in meshes:
            r = run_cell(arch, shape_name, mp, args.out,
                         skip_existing=not args.force)
            tag = "OK " if r.get("ok") else ("SKIP" if r.get("skipped")
                                             else "FAIL")
            extra = ""
            if r.get("ok"):
                extra = (f" mem/dev={r['memory']['per_device_bytes']/2**30:.2f}GiB"
                         f" flops={r['cost']['flops']:.3g}"
                         f" coll={r['collectives']['total_bytes']/2**30:.2f}GiB"
                         f" t={r['compile_s']:.0f}s")
            elif r.get("error"):
                extra = " " + r["error"][:120]
            print(f"[{tag}] {arch} {shape_name} "
                  f"{'2x16x16' if mp else '16x16'}{extra}", flush=True)
            results.append(r)
    n_ok = sum(1 for r in results if r.get("ok"))
    n_skip = sum(1 for r in results if r.get("skipped"))
    print(f"== {n_ok} ok, {n_skip} skipped, "
          f"{len(results) - n_ok - n_skip} failed ==")


if __name__ == "__main__":
    main()
