"""Elastic scaling + straggler mitigation.

Elasticity model (DESIGN.md §5): the mesh is re-carved along the
``data``/``pod`` axes when nodes join/leave; parameters are resharded
from the last checkpoint (replicated or re-laid-out by GSPMD on the new
mesh), and the data pipeline's stateless (seed, step, shard) indexing
regenerates each shard's stream for the new shard count — no coordinator
state beyond the checkpoint itself.

Straggler mitigation: deterministic shard assignment means any spare
worker can recompute a slow worker's shard; ``StragglerMonitor``
implements the detection half (per-step timing, MAD-based outlier
rule) and reports which data shard to reassign.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """A target device layout (axis sizes)."""

    pod: int
    data: int
    model: int

    @property
    def devices(self) -> int:
        return self.pod * self.data * self.model


def replan_mesh(current: MeshPlan, available_devices: int,
                min_model: int = 1) -> MeshPlan:
    """Re-carve the mesh after a membership change.

    Keeps the model axis (TP requires stable weight sharding) and folds
    the loss into data/pod parallelism — the standard elastic response:
    losing nodes costs throughput, not correctness.
    """
    model = max(current.model, min_model)
    if available_devices < model:
        raise ValueError(
            f"cannot keep model axis {model} with only "
            f"{available_devices} devices")
    replicas = available_devices // model
    # prefer keeping pods balanced: largest pod count that divides
    pod = math.gcd(current.pod, replicas) or 1
    data = replicas // pod
    if pod * data < replicas:
        # unreachable while the carve is a gcd (a gcd of replicas always
        # divides it) — kept as a hard floor so any future pod-selection
        # change that picks a non-divisor falls back to a flat data axis
        # instead of silently stranding replicas
        pod, data = 1, replicas
    return MeshPlan(pod=pod, data=data, model=model)


def reshard_batch_size(global_batch: int, old: MeshPlan, new: MeshPlan
                       ) -> int:
    """Per-replica batch after re-carving (global batch preserved; if not
    divisible, round up per-replica and trim in the data pipeline)."""
    replicas = new.pod * new.data
    return -(-global_batch // replicas)


@dataclasses.dataclass
class ElasticReplan:
    """Outcome of a shrink event: the re-carved mesh, the admission
    decision on it, and — when the old policy no longer fits — the
    planner's counter-offer already applied to (cfg, policy, shape)."""

    plan: MeshPlan                  # the re-carved mesh
    topology: object                # MeshTopology used for admission
    decision: object                # AdmissionDecision on the new mesh
    offer: object | None            # applied CounterOffer (or None)
    cfg: object
    policy: object
    shape: object

    @property
    def admitted(self) -> bool:
        return bool(self.decision.admit or self.offer is not None)


def shrink_and_replan(cfg, policy, shape, current: MeshPlan,
                      available_devices: int, hbm_bytes: int, *,
                      fsdp: bool | None = None, min_model: int = 1,
                      service=None, space=None) -> ElasticReplan:
    """Shrink event -> planner (ISSUE 5): after ``replan_mesh``
    re-carves the mesh, re-admit the job on the new topology with
    spec-driven per-device factors instead of assuming the old policy
    still fits; on rejection, search microbatch/batch remediations *on
    that mesh* (``PlanSpace.base_topology``) and apply the best
    counter-offer. Returns the updated (cfg, policy, shape) alongside
    the decision, so the training driver can restart from checkpoint
    with a plan that actually fits the smaller fleet."""
    import dataclasses as dc

    from ..core.sweep import MeshTopology
    from ..plan import PlanSpace, RemediationPlanner

    new = replan_mesh(current, available_devices, min_model=min_model)
    if fsdp is None:
        fsdp = cfg.param_count() > 8e9
    topo = MeshTopology(pod=new.pod, data=new.data, model=new.model,
                        fsdp=bool(fsdp) and new.pod * new.data > 1)
    space = space or PlanSpace(remat=())
    space = dc.replace(space, base_topology=topo, devices=())
    planner = RemediationPlanner(service)
    res = planner.plan(cfg, policy, shape, capacity=hbm_bytes,
                       space=space, job_id=f"{cfg.name}/shrink")
    offer = None
    cfg2, policy2, shape2 = cfg, policy, shape
    if not res.baseline.admit and res.offers:
        offer = res.offers[0]
        cfg2, policy2, shape2 = offer.apply(cfg, policy, shape)
    return ElasticReplan(plan=new, topology=topo, decision=res.baseline,
                         offer=offer, cfg=cfg2, policy=policy2,
                         shape=shape2)


class StragglerMonitor:
    """Per-worker step-time tracking with MAD outlier detection."""

    def __init__(self, n_workers: int, window: int = 32,
                 threshold: float = 4.0):
        self.n = n_workers
        self.window = window
        self.threshold = threshold
        self._times: list[list[float]] = [[] for _ in range(n_workers)]

    def record(self, worker: int, step_time_s: float) -> None:
        t = self._times[worker]
        t.append(step_time_s)
        if len(t) > self.window:
            t.pop(0)

    def forget(self, worker: int) -> None:
        """Drop a worker's timing history — call when its node fails,
        flaps, or is drained so stale samples neither flag the restored
        node as a straggler nor skew the fleet median while it's gone."""
        self._times[worker] = []

    def stragglers(self) -> list[int]:
        """Workers whose median step time is a MAD outlier vs the fleet."""
        meds = np.array([np.median(t) if t else np.nan for t in self._times])
        valid = meds[~np.isnan(meds)]
        if len(valid) < 3:
            return []
        fleet_med = np.median(valid)
        mad = np.median(np.abs(valid - fleet_med)) + 1e-9
        out = []
        for i, m in enumerate(meds):
            if not np.isnan(m) and (m - fleet_med) / mad > self.threshold:
                out.append(i)
        return out

    def reassignment_plan(self) -> dict[int, int]:
        """{straggler_shard: backup_worker} — deterministic pairing of
        flagged shards to the fastest healthy workers."""
        lag = self.stragglers()
        if not lag:
            return {}
        meds = [(np.median(t) if t else float("inf"), i)
                for i, t in enumerate(self._times)]
        healthy = [i for _, i in sorted(meds) if i not in lag]
        return {s: healthy[k % len(healthy)] for k, s in enumerate(lag)}
