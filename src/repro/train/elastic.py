"""Elastic scaling + straggler mitigation.

Elasticity model (DESIGN.md §5): the mesh is re-carved along the
``data``/``pod`` axes when nodes join/leave; parameters are resharded
from the last checkpoint (replicated or re-laid-out by GSPMD on the new
mesh), and the data pipeline's stateless (seed, step, shard) indexing
regenerates each shard's stream for the new shard count — no coordinator
state beyond the checkpoint itself.

Straggler mitigation: deterministic shard assignment means any spare
worker can recompute a slow worker's shard; ``StragglerMonitor``
implements the detection half (per-step timing, MAD-based outlier
rule) and reports which data shard to reassign.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """A target device layout (axis sizes)."""

    pod: int
    data: int
    model: int

    @property
    def devices(self) -> int:
        return self.pod * self.data * self.model


def replan_mesh(current: MeshPlan, available_devices: int,
                min_model: int = 1) -> MeshPlan:
    """Re-carve the mesh after a membership change.

    Keeps the model axis (TP requires stable weight sharding) and folds
    the loss into data/pod parallelism — the standard elastic response:
    losing nodes costs throughput, not correctness.
    """
    model = max(current.model, min_model)
    if available_devices < model:
        raise ValueError(
            f"cannot keep model axis {model} with only "
            f"{available_devices} devices")
    replicas = available_devices // model
    # prefer keeping pods balanced: largest pod count that divides
    pod = math.gcd(current.pod, replicas) or 1
    data = replicas // pod
    return MeshPlan(pod=pod, data=data, model=model)


def reshard_batch_size(global_batch: int, old: MeshPlan, new: MeshPlan
                       ) -> int:
    """Per-replica batch after re-carving (global batch preserved; if not
    divisible, round up per-replica and trim in the data pipeline)."""
    replicas = new.pod * new.data
    return -(-global_batch // replicas)


class StragglerMonitor:
    """Per-worker step-time tracking with MAD outlier detection."""

    def __init__(self, n_workers: int, window: int = 32,
                 threshold: float = 4.0):
        self.n = n_workers
        self.window = window
        self.threshold = threshold
        self._times: list[list[float]] = [[] for _ in range(n_workers)]

    def record(self, worker: int, step_time_s: float) -> None:
        t = self._times[worker]
        t.append(step_time_s)
        if len(t) > self.window:
            t.pop(0)

    def stragglers(self) -> list[int]:
        """Workers whose median step time is a MAD outlier vs the fleet."""
        meds = np.array([np.median(t) if t else np.nan for t in self._times])
        valid = meds[~np.isnan(meds)]
        if len(valid) < 3:
            return []
        fleet_med = np.median(valid)
        mad = np.median(np.abs(valid - fleet_med)) + 1e-9
        out = []
        for i, m in enumerate(meds):
            if not np.isnan(m) and (m - fleet_med) / mad > self.threshold:
                out.append(i)
        return out

    def reassignment_plan(self) -> dict[int, int]:
        """{straggler_shard: backup_worker} — deterministic pairing of
        flagged shards to the fastest healthy workers."""
        lag = self.stragglers()
        if not lag:
            return {}
        meds = [(np.median(t) if t else float("inf"), i)
                for i, t in enumerate(self._times)]
        healthy = [i for _, i in sorted(meds) if i not in lag]
        return {s: healthy[k % len(healthy)] for k, s in enumerate(lag)}
