"""Training and serving step factories.

``make_train_step`` builds the canonical step:
  loss+grad (remat inside the model) -> optional microbatch
  gradient accumulation (scan over microbatches — activation memory
  scales with microbatch, not global batch; DP all-reduce of microbatch
  k overlaps compute of k+1 under XLA's latency-hiding scheduler) ->
  optional global-norm clipping -> optimizer update with donation.

The xMem estimator consumes the same pieces (fwd_bwd / update / opt_init)
— the estimator *is* wired to the real training step, not a model of it.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import model as M
from .optimizer import Optimizer, clip_by_global_norm, get_optimizer


@dataclasses.dataclass(frozen=True)
class TrainPolicy:
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    clip_norm: float | None = 1.0
    microbatches: int = 1          # gradient-accumulation steps
    opt_kwargs: tuple = ()


def make_fwd_bwd(cfg: ModelConfig, microbatches: int = 1) -> Callable:
    """(params, batch) -> (loss, grads), optionally with gradient
    accumulation over ``microbatches`` — the same scan the real train
    step runs, so the estimator sees accumulation's memory profile
    (activations scale with the microbatch, f32 accumulators persist
    across the scan). ``batch`` leading dims must divide evenly."""
    def fwd_bwd(params, batch):
        return jax.value_and_grad(M.loss_fn)(params, batch, cfg)
    if microbatches <= 1:
        return fwd_bwd
    n = microbatches

    def fwd_bwd_accum(params, batch):
        mb = _split_microbatches(batch, n)

        def acc_body(carry, micro):
            loss_sum, g_acc = carry
            loss, grads = fwd_bwd(params, micro)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(a.dtype), g_acc, grads)
            return (loss_sum + loss, g_acc), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, g_acc), _ = jax.lax.scan(acc_body, (0.0, g0), mb)
        grads = jax.tree_util.tree_map(lambda g: g / n, g_acc)
        return loss_sum / n, grads

    return fwd_bwd_accum


def _split_microbatches(batch: dict, n: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by {n} microbatches"
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def make_train_step(cfg: ModelConfig, policy: TrainPolicy
                    ) -> tuple[Callable, Optimizer]:
    """Returns (train_step(params, opt_state, batch) -> (loss, params,
    opt_state), optimizer). Donation is applied at jit time by the
    launcher (donate_argnums=(0, 1))."""
    opt = get_optimizer(policy.optimizer, lr=policy.learning_rate,
                        **dict(policy.opt_kwargs))
    update_fn = opt.update
    if policy.clip_norm is not None:
        update_fn = clip_by_global_norm(update_fn, policy.clip_norm)
    # the accumulation scan lives in make_fwd_bwd so the estimator hooks
    # and the real step share it by construction (identical code paths)
    fwd_bwd = make_fwd_bwd(cfg, policy.microbatches)

    def train_step(params, opt_state, batch):
        loss, grads = fwd_bwd(params, batch)
        new_params, new_state = update_fn(params, grads, opt_state)
        return loss, new_params, new_state

    return train_step, opt


# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig) -> Callable:
    """Forward over the full prompt -> last-position logits."""
    def prefill_step(params, batch):
        x = M.embed_inputs(params, batch, cfg)
        h = M.backbone(params, x, cfg,
                       positions=jnp.arange(x.shape[1]))
        return M.logits_fn(params, h[:, -1:], cfg)
    return prefill_step


def make_serve_step(cfg: ModelConfig, cache_len: int) -> Callable:
    """One-token decode against a cache of ``cache_len`` context."""
    def serve_step(params, cache, batch):
        logits, new_cache = M.decode_step(
            params, cache, batch, jnp.int32(cache_len), cfg)
        return logits, new_cache
    return serve_step


# ---------------------------------------------------------------------------
def make_estimator_hooks(cfg: ModelConfig, policy: TrainPolicy):
    """The (fwd_bwd, update, opt_init) triple xMem estimates from —
    identical code paths to the real step (first-class integration).
    ``policy.microbatches`` is honored: the estimator's forward phase
    runs the same accumulation scan the real step would, so replanning
    a rejected job onto more microbatches actually changes (shrinks)
    the estimate."""
    opt = get_optimizer(policy.optimizer, lr=policy.learning_rate,
                        **dict(policy.opt_kwargs))
    update_fn = opt.update
    if policy.clip_norm is not None:
        update_fn = clip_by_global_norm(update_fn, policy.clip_norm)
    return (make_fwd_bwd(cfg, policy.microbatches), update_fn, opt.init)
