"""Synthetic data pipeline — deterministic, restart-safe, shardable.

Production properties kept even though the corpus is synthetic:
* stateless indexing: batch ``i`` is a pure function of (seed, i), so a
  job restarted from a step-k checkpoint regenerates exactly the batches
  it would have seen — no data-order drift across failures (the
  fault-tolerance contract);
* per-host sharding by process index (deterministic shard assignment —
  the straggler-mitigation prerequisite: any replacement worker can
  recompute its shard);
* zipfian token distribution so softmax/loss statistics resemble text.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from functools import partial

from ..configs.base import ModelConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_alpha: float = 1.1


class SyntheticDataset:
    """Deterministic synthetic LM batches."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec,
                 data_cfg: DataConfig = DataConfig(),
                 num_shards: int = 1, shard_index: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg
        self.num_shards = num_shards
        self.shard_index = shard_index
        assert shape.global_batch % num_shards == 0
        self.local_batch = shape.global_batch // num_shards

    def _tokens(self, rng: np.random.Generator, shape):
        # zipf over vocab, clipped
        z = rng.zipf(self.data_cfg.zipf_alpha, size=shape)
        return np.minimum(z - 1, self.cfg.vocab - 1).astype(np.int32)

    def batch(self, step: int) -> dict:
        """Batch for global step ``step`` — pure function of (seed, step,
        shard)."""
        rng = np.random.default_rng(
            (self.data_cfg.seed, step, self.shard_index))
        B, S = self.local_batch, self.shape.seq_len
        cfg = self.cfg
        if cfg.family == "vlm":
            P = cfg.num_patches
            S_text = max(S - P, 8)
            toks = self._tokens(rng, (B, S_text + 1))
            return {
                "patch_embeds": rng.standard_normal(
                    (B, P, cfg.d_model)).astype(np.float32) * 0.02,
                "tokens": toks[:, :-1],
                "labels": toks[:, 1:],
            }
        if cfg.family == "audio":
            codes = self._tokens(rng, (B, S + 1, cfg.num_codebooks))
            return {"codes": codes[:, :-1], "labels": codes[:, 1:]}
        toks = self._tokens(rng, (B, S + 1))
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def device_batch(self, step: int, sharding=None) -> dict:
        b = self.batch(step)
        put = partial_put(sharding)
        out = {}
        for k, v in b.items():
            arr = jnp.asarray(v, dtype=self.cfg.dtype
                              if v.dtype == np.float32 else None)
            out[k] = put(arr)
        return out


def partial_put(sharding):
    if sharding is None:
        return lambda x: x
    return lambda x: jax.device_put(x, sharding)
