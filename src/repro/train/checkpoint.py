"""Checkpointing: atomic, resumable, failure-tolerant.

Design for 1000+-node operation (DESIGN.md §5):
* atomic commit: write to ``step_<n>.tmp`` then ``os.replace`` — a crash
  mid-write never corrupts the latest valid checkpoint;
* manifest with step + pytree structure + integrity checksums; restore
  validates before handing arrays back;
* ``latest_step`` scans for the newest *complete* checkpoint, so resume
  after an arbitrary kill is always safe;
* emergency checkpoints: ``CheckpointManager.emergency`` is wired to the
  trainer's exception path (preempt/SIGTERM analogue) and writes a
  distinct tag so post-mortems can distinguish scheduled vs panic saves;
* retention: keep the last ``keep`` checkpoints, never deleting the one
  being written.

Arrays are serialized with numpy's npz (framework-independent, offline-
friendly); at multi-host scale each host writes its param shards —
modeled here by the ``shard_id`` component of the filename.
"""
from __future__ import annotations

import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def _tree_paths(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(k) for k, _ in flat]


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """npz can't hold ml_dtypes (bf16/fp8); store a same-width uint view
    plus the true dtype string for the round-trip."""
    dtype_str = str(arr.dtype)
    if arr.dtype.kind not in "fiub?" or dtype_str not in np.sctypeDict:
        width = {1: np.uint8, 2: np.uint16, 4: np.uint32,
                 8: np.uint64}[arr.dtype.itemsize]
        return arr.view(width), dtype_str
    return arr, dtype_str


def _decode(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(arr.dtype) != dtype_str:
        import ml_dtypes
        true_dtype = np.dtype(getattr(ml_dtypes, dtype_str, dtype_str))
        return arr.view(true_dtype)
    return arr


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, shard_id: int = 0):
        self.dir = directory
        self.keep = keep
        self.shard_id = shard_id
        os.makedirs(directory, exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def _base(self, step: int, tag: str = "ckpt") -> str:
        return os.path.join(self.dir,
                            f"{tag}_step{step:010d}_shard{self.shard_id}")

    def _manifest_path(self, base: str) -> str:
        return base + ".manifest.json"

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: dict, tag: str = "ckpt") -> str:
        base = self._base(step, tag)
        tmp_npz = base + ".npz.tmp"
        flat, treedef = jax.tree_util.tree_flatten(state)
        names = [f"a{i}" for i in range(len(flat))]
        encoded = [_encode(np.asarray(x)) for x in flat]
        arrays = {n: a for n, (a, _) in zip(names, encoded)}
        with open(tmp_npz, "wb") as f:
            np.savez(f, **arrays)
        digest = _file_digest(tmp_npz)
        manifest = {
            "step": step,
            "tag": tag,
            "time": time.time(),
            "paths": _tree_paths(state),
            "names": names,
            "dtypes": [d for _, d in encoded],
            "shapes": [list(np.asarray(x).shape) for x in flat],
            "sha256": digest,
            "complete": True,
        }
        tmp_mani = self._manifest_path(base) + ".tmp"
        with open(tmp_mani, "w") as f:
            json.dump(manifest, f)
        # atomic commit: npz first, manifest last (manifest = commit point)
        os.replace(tmp_npz, base + ".npz")
        os.replace(tmp_mani, self._manifest_path(base))
        self._gc(tag)
        return base

    def emergency(self, step: int, state: dict) -> str:
        """Panic save on preemption/failure — distinct tag, never GC'd
        by the regular retention policy."""
        return self.save(step, state, tag="emergency")

    # -- restore ----------------------------------------------------------------
    def latest_step(self, tag: str = "ckpt") -> int | None:
        steps = []
        for fn in os.listdir(self.dir):
            if fn.startswith(f"{tag}_step") and fn.endswith(".manifest.json"):
                try:
                    with open(os.path.join(self.dir, fn)) as f:
                        m = json.load(f)
                    if m.get("complete"):
                        steps.append(m["step"])
                except (json.JSONDecodeError, KeyError):
                    continue  # torn manifest -> not a valid checkpoint
        return max(steps) if steps else None

    def restore(self, step: int, like: dict, tag: str = "ckpt") -> dict:
        base = self._base(step, tag)
        with open(self._manifest_path(base)) as f:
            manifest = json.load(f)
        npz_path = base + ".npz"
        if _file_digest(npz_path) != manifest["sha256"]:
            raise IOError(f"checkpoint {base} failed integrity check")
        data = np.load(npz_path)
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        flat = []
        for i, (name, ref) in enumerate(zip(manifest["names"], flat_like)):
            arr = _decode(data[name], manifest["dtypes"][i])
            want = tuple(ref.shape) if hasattr(ref, "shape") else None
            if want is not None and tuple(arr.shape) != want:
                raise ValueError(
                    f"checkpoint leaf {i} shape {arr.shape} != {want} "
                    "(elastic reshape required — see elastic.resharded)")
            flat.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, flat)

    def restore_latest(self, like: dict, tag: str = "ckpt"
                       ) -> tuple[int, dict] | None:
        # prefer emergency saves if newer than the last scheduled one
        cands = []
        for t in (tag, "emergency"):
            s = self.latest_step(t)
            if s is not None:
                cands.append((s, t))
        if not cands:
            return None
        step, t = max(cands)
        return step, self.restore(step, like, tag=t)

    # -- retention -----------------------------------------------------------------
    def _gc(self, tag: str) -> None:
        if tag != "ckpt":
            return
        manis = sorted(fn for fn in os.listdir(self.dir)
                       if fn.startswith("ckpt_step")
                       and fn.endswith(".manifest.json"))
        excess = manis[:-self.keep] if self.keep else []
        for fn in excess:
            base = os.path.join(self.dir, fn[:-len(".manifest.json")])
            for suffix in (".manifest.json", ".npz"):
                try:
                    os.remove(base + suffix)
                except FileNotFoundError:
                    pass


def _file_digest(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()
