"""Optimizers (pure init/update pairs, optax-style but dependency-free).

The paper's evaluation sweeps SGD / Adam / AdamW / RMSprop / Adagrad /
Adafactor (§4.1.2) — the optimizer choice changes persistent state 0x-2x
parameter bytes, which is exactly what estimators must capture (DNNMem's
blindness to it is a measured failure mode). All updates are per-leaf
tree.maps so XLA fuses them into the backward pass (eager grad death —
see core.orchestrator); global-norm clipping intentionally couples
gradients and flips the estimator into ``at_update`` mode.

Optimizer state dtype is fp32 regardless of param dtype (master-quality
statistics for bf16 training). Adafactor stores factored second moments
(rows+cols) — the realistic choice for the 100B+ configs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]
    state_multiplier: float   # persistent state size / param size (approx)


def _treemap(fn, *trees, **kw):
    return jax.tree_util.tree_map(fn, *trees, **kw)


def _f32(p):
    return p.astype(jnp.float32)


# ---------------------------------------------------------------------------
def sgd(lr: float = 1e-3, momentum: float = 0.0) -> Optimizer:
    if momentum:
        def init(params):
            return _treemap(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def update(params, grads, state):
            new_m = _treemap(lambda m, g: momentum * m + _f32(g), state, grads)
            new_p = _treemap(lambda p, m: (p - lr * m.astype(p.dtype)),
                             params, new_m)
            return new_p, new_m
        return Optimizer("sgd_momentum", init, update, 1.0)

    def init(params):
        return ()

    def update(params, grads, state):
        return _treemap(lambda p, g: p - lr * g.astype(p.dtype),
                        params, grads), state
    return Optimizer("sgd", init, update, 0.0)


def adam(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         name: str = "adam") -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {"m": _treemap(z, params), "v": _treemap(z, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c

        def upd(p, g, m, v):
            g = _f32(g)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + lr * weight_decay * _f32(p)
            return (p - step.astype(p.dtype)), m, v

        out = _treemap(upd, params, grads, state["m"], state["v"])
        new_p = _treemap(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        new_m = _treemap(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        new_v = _treemap(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(name, init, update, 2.0)


def adamw(lr: float = 1e-3, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr=lr, weight_decay=weight_decay, name="adamw", **kw)


def rmsprop(lr: float = 1e-3, decay: float = 0.9,
            eps: float = 1e-8) -> Optimizer:
    def init(params):
        return _treemap(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(params, grads, state):
        def upd(p, g, v):
            g = _f32(g)
            v = decay * v + (1 - decay) * g * g
            return (p - (lr * g / (jnp.sqrt(v) + eps)).astype(p.dtype)), v
        out = _treemap(upd, params, grads, state)
        new_p = _treemap(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        new_v = _treemap(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return new_p, new_v
    return Optimizer("rmsprop", init, update, 1.0)


def adagrad(lr: float = 1e-2, eps: float = 1e-10) -> Optimizer:
    def init(params):
        return _treemap(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(params, grads, state):
        def upd(p, g, a):
            g = _f32(g)
            a = a + g * g
            return (p - (lr * g / (jnp.sqrt(a) + eps)).astype(p.dtype)), a
        out = _treemap(upd, params, grads, state)
        new_p = _treemap(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        new_a = _treemap(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return new_p, new_a
    return Optimizer("adagrad", init, update, 1.0)


def adafactor(lr: float = 1e-3, decay: float = 0.8,
              eps: float = 1e-30) -> Optimizer:
    """Factored second moments: O(rows+cols) state for matrices — the
    memory-frugal choice the paper uses for its largest models (RQ5)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def st(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": _treemap(st, params), "count": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        count = state["count"] + 1
        beta = 1.0 - (count.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(p, g, s):
            g = _f32(g)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1)[..., None, None],
                                       eps))
                step = g * jax.lax.rsqrt(denom + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                step = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS <= 1) as in the paper's implementation
            rms = jnp.sqrt(jnp.mean(step * step) + eps)
            step = step / jnp.maximum(1.0, rms)
            return (p - (lr * step).astype(p.dtype)), new_s

        out = _treemap(upd, params, grads, state["f"],
                       is_leaf=lambda x: isinstance(x, dict)
                       and ("v" in x or "vr" in x))
        new_p = _treemap(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        new_f = _treemap(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"f": new_f, "count": count}

    return Optimizer("adafactor", init, update, 0.05)


OPTIMIZERS: dict[str, Callable[..., Optimizer]] = {
    "sgd": sgd,
    "sgd_momentum": partial(sgd, momentum=0.9),
    "adam": adam,
    "adamw": adamw,
    "rmsprop": rmsprop,
    "adagrad": adagrad,
    "adafactor": adafactor,
}


def get_optimizer(name: str, **kw) -> Optimizer:
    return OPTIMIZERS[name](**kw)


def clip_by_global_norm(update_fn, max_norm: float = 1.0):
    """Wrap an optimizer update with global-norm clipping.

    NOTE: this *couples* gradients (all must coexist at the update) —
    the estimator's taint analysis detects it and switches grad_release
    to at_update, raising the (correct) estimate.
    """
    def wrapped(params, grads, state):
        gn = jnp.sqrt(sum(jnp.sum(_f32(g) ** 2)
                          for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
        grads = _treemap(lambda g: (_f32(g) * scale).astype(g.dtype), grads)
        return update_fn(params, grads, state)
    return wrapped
