"""Training substrate: optimizers, step factories, data, checkpointing,
elasticity."""
from .checkpoint import CheckpointManager
from .data import DataConfig, SyntheticDataset
from .elastic import (ElasticReplan, MeshPlan, StragglerMonitor,
                      replan_mesh, shrink_and_replan)
from .optimizer import OPTIMIZERS, Optimizer, clip_by_global_norm, get_optimizer
from .train_step import (TrainPolicy, make_estimator_hooks, make_fwd_bwd,
                         make_prefill_step, make_serve_step, make_train_step)

__all__ = ["CheckpointManager", "DataConfig", "SyntheticDataset", "MeshPlan",
           "ElasticReplan", "StragglerMonitor", "replan_mesh",
           "shrink_and_replan", "OPTIMIZERS", "Optimizer",
           "clip_by_global_norm", "get_optimizer", "TrainPolicy",
           "make_estimator_hooks", "make_fwd_bwd", "make_prefill_step",
           "make_serve_step", "make_train_step"]
