"""Pallas TPU kernels (validated in interpret mode on CPU)."""
from . import ops, ref
from .flash_attention import flash_attention_bhsd

__all__ = ["ops", "ref", "flash_attention_bhsd"]
