"""Pure-jnp oracles for the Pallas kernels (per-kernel allclose target)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: int | None = None, kv_len: int | None = None):
    """q: [B, H, Sq, d]; k/v: [B, Hkv, Sk, d] — dense softmax oracle."""
    B, H, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    kv_len = Sk if kv_len is None else kv_len
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(B, Hkv, G, Sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg,
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    ok = k_pos < kv_len
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isfinite(s).any(-1, keepdims=True), p, 0.0)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, d).astype(q.dtype)
