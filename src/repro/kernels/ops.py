"""Jit'd public wrappers around the Pallas kernels.

Handles model-layout conversion ([B, S, H, hd] <-> [B, H, S, hd]),
padding to MXU-aligned tile multiples, GQA head-group bookkeeping, and
backend selection (``interpret=True`` on CPU — the kernel body executes
in Python for validation; on TPU the same call compiles to Mosaic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, block_q: int = 128,
                    block_k: int = 128):
    """Model-layout entry point: q [B, S, H, hd], k/v [B, S, Hkv, hd]."""
    interpret = jax.default_backend() != "tpu"
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    Sq, Sk = qt.shape[2], kt.shape[2]
    bq = min(block_q, max(16, 1 << (Sq - 1).bit_length()))
    bk = min(block_k, max(16, 1 << (Sk - 1).bit_length()))
    qt, _ = _pad_to(qt, 2, bq)
    kt, kv_len = _pad_to(kt, 2, bk)
    vt, _ = _pad_to(vt, 2, bk)
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window, block_q=bq,
        block_k=bk, interpret=interpret, kv_len=kv_len)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)
