"""Pallas TPU flash attention (forward): tiled online-softmax.

TPU-native design (DESIGN.md §6):
* grid = (batch*heads, q_blocks, kv_blocks); the LAST grid axis is
  sequential on TPU, so the same (bh, iq) output block is revisited
  across kv blocks with running (m, l, acc) state in VMEM scratch —
  the canonical revisiting-accumulator pattern;
* BlockSpecs keep one q tile [block_q, d] VMEM-resident while K/V tiles
  [block_k, d] stream from HBM: traffic O(S*d) instead of the O(S^2)
  score matrix;
* tile shapes default to 128 (MXU-aligned; d=head_dim is a multiple of
  8 lanes after padding in ops.py);
* GQA without materializing repeated KV heads: the K/V index maps fold
  the query head onto its kv head (h // group);
* causal + sliding-window masks are applied per-tile from iota position
  grids; fully-masked tiles skip the matmul via ``pl.when``.

Validated in ``interpret=True`` mode against ``ref.py`` over shape/dtype
sweeps (tests/test_kernels.py). Forward-only: training uses the pure-JAX
chunked path in models/layers.py; this kernel serves prefill/decode.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, block_q: int, block_k: int, nk: int,
                 causal: bool, window: int | None, kv_len: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # skip tiles strictly above the causal diagonal
    run = (k_start <= q_start + block_q - 1) if causal else True

    @pl.when(run)
    def _tile():
        q = q_ref[0].astype(jnp.float32)             # [bq, d]
        k = k_ref[0].astype(jnp.float32)             # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        ok = k_pos < kv_len
        if causal:
            ok &= k_pos <= q_pos
        if window is not None:
            ok &= (q_pos - k_pos) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k",
                     "interpret", "kv_len"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         window: int | None = None, block_q: int = 128,
                         block_k: int = 128, interpret: bool = True,
                         kv_len: int | None = None):
    """q: [B, H, Sq, d]; k/v: [B, Hkv, Sk, d] -> [B, H, Sq, d].

    Sq/Sk must be padded to block multiples (ops.py handles padding).
    """
    B, H, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    kv_len = Sk if kv_len is None else kv_len
    nq = Sq // block_q
    nk = Sk // block_k
    scale = 1.0 / math.sqrt(d)
    qf = q.reshape(B * H, Sq, d)
    kf = k.reshape(B * Hkv, Sk, d)
    vf = v.reshape(B * Hkv, Sk, d)

    def kv_index(bh, iq, ik):
        # query head bh = b*H + h attends kv head b*Hkv + h//G
        return (bh // H) * Hkv + (bh % H) // G, ik, 0

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k,
        nk=nk, causal=causal, window=window, kv_len=kv_len)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, d)
