"""Mamba (S6 selective SSM) block — chunked scan formulation.

TPU adaptation (DESIGN.md §2): the CUDA Mamba kernel is a fused
shared-memory scan; the TPU-native structure is a *chunked* scan —
an outer ``lax.scan`` over sequence chunks (rematerialized, so backward
residuals are per-chunk inputs only) with an inner ``lax.scan`` over
steps carrying the [B, d_inner, d_state] SSM state. d_inner is sharded
on the model axis (column-parallel in_proj, row-parallel out_proj), so
the per-chunk backward transient [chunk, B, d_inner/tp, N] stays within
HBM at the assigned shapes.

Decode is the O(1) single-step recurrence over a persistent state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import MambaConfig


def mamba_params(key, d_model: int, cfg: MambaConfig, dtype):
    d_inner = cfg.expand * d_model
    dt_rank = max(1, math.ceil(d_model / 16))
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d_model)
    si = 1.0 / math.sqrt(d_inner)
    return {
        "in_proj": (jax.random.normal(ks[0], (d_model, 2 * d_inner))
                    * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, d_inner))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": (jax.random.normal(ks[2], (d_inner,
                                             dt_rank + 2 * cfg.d_state))
                   * si).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (dt_rank, d_inner))
                    * (dt_rank ** -0.5)).astype(dtype),
        "dt_bias": jnp.full((d_inner,), -4.6, dtype),   # softplus ~ 0.01
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32),
            (d_inner, 1))),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (d_inner, d_model))
                     * si).astype(dtype),
    }


def _causal_conv1d(x, w, b):
    """x: [B, S, C]; depthwise causal conv, kernel [K, C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w[:, None, :].astype(jnp.float32),      # [K, 1, C] HIO-ish
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1])
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_inputs(x, p, cfg: MambaConfig):
    """Shared preamble for scan/step: returns (xa, z, dt, A, Bm, Cm)."""
    d_inner = p["out_proj"].shape[0]
    dt_rank = p["dt_proj"].shape[0]
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc = _causal_conv1d(x_in, p["conv_w"], p["conv_b"])
    xa = jax.nn.silu(xc)
    proj = xa @ p["x_proj"]
    dt_in = proj[..., :dt_rank]
    Bm = proj[..., dt_rank:dt_rank + cfg.d_state].astype(jnp.float32)
    Cm = proj[..., dt_rank + cfg.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus((dt_in @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"])                     # [d_inner, N]
    return xa, z, dt, A, Bm, Cm


def mamba_block(x, p, cfg: MambaConfig):
    """Training/prefill forward. x: [B, S, D] -> [B, S, D]."""
    B, S, D = x.shape
    xa, z, dt, A, Bm, Cm = _ssm_inputs(x, p, cfg)
    d_inner = xa.shape[-1]
    ch = min(cfg.chunk, S)
    n_chunks = -(-S // ch)
    Sp = n_chunks * ch

    def pad(t):
        return jnp.pad(t, ((0, 0), (0, Sp - S)) + ((0, 0),) * (t.ndim - 2))

    xa_, dt_, Bm_, Cm_ = map(pad, (xa, dt, Bm, Cm))

    def chunk_body(h, inputs):
        xc, dtc, Bc, Cc = inputs                 # [B, ch, ...]

        def step(h, inp):
            xt, dtt, Bt, Ct = inp                # [B, d_inner], [B, N]...
            dA = jnp.exp(dtt[..., None] * A)     # [B, d_inner, N]
            h = dA * h + dtt[..., None] * Bt[:, None, :] \
                * xt.astype(jnp.float32)[..., None]
            y = (h * Ct[:, None, :]).sum(-1)     # [B, d_inner]
            return h, y

        h, ys = jax.lax.scan(
            step, h,
            (xc.transpose(1, 0, 2), dtc.transpose(1, 0, 2),
             Bc.transpose(1, 0, 2), Cc.transpose(1, 0, 2)))
        return h, ys.transpose(1, 0, 2)          # [B, ch, d_inner]

    h0 = jnp.zeros((B, d_inner, cfg.d_state), jnp.float32)
    xs = (xa_.reshape(B, n_chunks, ch, d_inner).transpose(1, 0, 2, 3),
          dt_.reshape(B, n_chunks, ch, d_inner).transpose(1, 0, 2, 3),
          Bm_.reshape(B, n_chunks, ch, -1).transpose(1, 0, 2, 3),
          Cm_.reshape(B, n_chunks, ch, -1).transpose(1, 0, 2, 3))
    _, ych = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = ych.transpose(1, 0, 2, 3).reshape(B, Sp, d_inner)[:, :S]
    y = y + p["D"] * xa.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return (y.astype(x.dtype) @ p["out_proj"]), None


def mamba_init_state(batch: int, d_model: int, cfg: MambaConfig):
    d_inner = cfg.expand * d_model
    return {
        "h": jnp.zeros((batch, d_inner, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_inner), jnp.float32),
    }


def mamba_decode_step(x, state, p, cfg: MambaConfig):
    """One-token recurrence. x: [B, 1, D]; O(1) in context length."""
    B = x.shape[0]
    d_inner = p["out_proj"].shape[0]
    dt_rank = p["dt_proj"].shape[0]
    xz = x[:, 0] @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    # rolling conv window
    win = jnp.concatenate(
        [state["conv"], x_in[:, None, :].astype(jnp.float32)], axis=1)
    xc = (win * p["conv_w"].astype(jnp.float32)[None]).sum(1) \
        + p["conv_b"].astype(jnp.float32)
    xa = jax.nn.silu(xc)
    proj = xa.astype(x.dtype) @ p["x_proj"]
    dt = jax.nn.softplus(
        (proj[..., :dt_rank] @ p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    Bm = proj[..., dt_rank:dt_rank + cfg.d_state].astype(jnp.float32)
    Cm = proj[..., dt_rank + cfg.d_state:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)
    h = dA * state["h"] + dt[..., None] * Bm[:, None, :] * xa[..., None]
    y = (h * Cm[:, None, :]).sum(-1) + p["D"] * xa
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(x.dtype) @ p["out_proj"]
    new_state = {"h": h, "conv": win[:, 1:]}
    return out[:, None, :], new_state
