"""Model zoo: unified LM over dense/MoE/hybrid/SSM/VLM/audio families."""
from . import layers, mamba, moe, model, xlstm
from .model import (abstract_params, backbone, decode_step, init_cache,
                    init_params, loss_fn, logits_fn)

__all__ = ["layers", "mamba", "moe", "model", "xlstm", "abstract_params",
           "backbone", "decode_step", "init_cache", "init_params",
           "loss_fn", "logits_fn"]
