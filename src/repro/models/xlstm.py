"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

TPU adaptation (DESIGN.md §2): the xLSTM paper's CUDA kernels stream the
recurrence through shared memory; the TPU-native formulation is the
*chunkwise-parallel* form (same family as GLA/flash-linear-attention):

* within a chunk of length ``ch`` the contribution is a masked [ch, ch]
  quadratic form (MXU-friendly matmuls);
* across chunks a [B, H, dk, dv] matrix state + [B, H, dk] normalizer +
  [B, H] stabilizer are carried through an outer ``lax.scan`` with the
  *exact* exponential-gating stabilization of the paper (running max m,
  denominator lower-bounded by exp(-m)) — validated against the
  sequential recurrent reference in tests;
* decode is the O(1) recurrent step over the persistent (C, n, m) state,
  which is what makes the 500k-token long-context shape feasible for
  this family (no KV cache at all).

sLSTM keeps its inherently sequential scalar recurrence, run as an
outer-chunk (rematerialized) / inner-step scan.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import XLSTMConfig


# ---------------------------------------------------------------------------
# mLSTM
def mlstm_params(key, d_model: int, n_heads: int, cfg: XLSTMConfig, dtype):
    dv = d_model // n_heads
    dk = max(int(dv * cfg.qk_dim_factor), 8)
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d_model)
    return {
        "wq": (jax.random.normal(ks[0], (d_model, n_heads * dk)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, n_heads * dk)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, d_model)) * s).astype(dtype),
        "w_if": (jax.random.normal(ks[3], (d_model, 2 * n_heads)) * s).astype(dtype),
        "b_if": jnp.concatenate([jnp.zeros((n_heads,)),
                                 3.0 * jnp.ones((n_heads,))]).astype(dtype),
        "w_gate": (jax.random.normal(ks[4], (d_model, d_model)) * s).astype(dtype),
        "w_out": (jax.random.normal(ks[5], (d_model, d_model)) * s).astype(dtype),
        "norm": jnp.ones((d_model,), dtype),
    }


def _mlstm_qkvif(x, p, n_heads):
    B, S, D = x.shape
    dv = D // n_heads
    dk = p["wq"].shape[1] // n_heads
    q = (x @ p["wq"]).reshape(B, S, n_heads, dk).astype(jnp.float32)
    k = (x @ p["wk"]).reshape(B, S, n_heads, dk).astype(jnp.float32)
    v = (x @ p["wv"]).reshape(B, S, n_heads, dv).astype(jnp.float32)
    q = q / math.sqrt(dk)
    gates = (x @ p["w_if"] + p["b_if"]).astype(jnp.float32)
    i_pre = gates[..., :n_heads]                      # [B, S, H]
    f_pre = gates[..., n_heads:]
    return q, k, v, i_pre, f_pre


def mlstm_chunkwise(q, k, v, i_pre, f_pre, state=None, chunk: int = 256):
    """Chunkwise-parallel stabilized mLSTM cell.

    q,k: [B,S,H,dk]; v: [B,S,H,dv]; gates: [B,S,H].
    Returns h [B,S,H,dv] and final (C, n, m) state.
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    ch = min(chunk, S)
    nc = -(-S // ch)
    Sp = nc * ch

    def pad(t):
        return jnp.pad(t, ((0, 0), (0, Sp - S)) + ((0, 0),) * (t.ndim - 2))

    qp, kp, vp = pad(q), pad(k), pad(v)
    # padded steps must be identity: no input (i -> -inf) and no decay
    # (f -> +inf so log_sigmoid(f) -> 0), keeping the carried state exact
    ip = jnp.pad(i_pre, ((0, 0), (0, Sp - S), (0, 0)),
                 constant_values=-1e30)
    fp = jnp.pad(f_pre, ((0, 0), (0, Sp - S), (0, 0)),
                 constant_values=1e30)

    def to_chunks(t):
        return t.reshape((B, nc, ch) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    if state is None:
        C0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    causal = jnp.tril(jnp.ones((ch, ch), bool))

    def chunk_body(carry, inp):
        C, n, m = carry                              # stored scaled by e^-m
        qc, kc, vc, ic, fc = inp                     # [B, ch, ...]
        logf = jax.nn.log_sigmoid(fc)                # [B, ch, H]
        F = jnp.cumsum(logf, axis=1)                 # inclusive decay
        s_j = ic - F                                 # log input / decay
        a = jax.lax.cummax(s_j, axis=1)              # running max_j<=i
        Mi = jnp.maximum(a, m[:, None, :])           # [B, ch, H]
        # intra-chunk quadratic form
        A = jnp.einsum("bihd,bjhd->bhij", qc, kc)    # [B, H, ch, ch]
        # W[b,h,i,j] = exp(s_j[b,j,h] - Mi[b,i,h])
        W = jnp.exp(s_j.transpose(0, 2, 1)[:, :, None, :]
                    - Mi.transpose(0, 2, 1)[..., None])  # [B,H,i,j]
        W = jnp.where(causal[None, None], W, 0.0)
        AW = A * W
        y_intra = jnp.einsum("bhij,bjhd->bihd", AW, vc)
        den_intra = AW.sum(-1).transpose(0, 2, 1)    # [B, ch, H]
        # inter-chunk from carried state
        coef = jnp.exp(m[:, None, :] - Mi)           # [B, ch, H]
        y_inter = jnp.einsum("bihd,bhdv->bihv", qc, C) * coef[..., None]
        den_inter = jnp.einsum("bihd,bhd->bih", qc, n) * coef
        den = den_intra + den_inter
        # true stabilizer at position i is m_i = F_i + Mi; num/den are at
        # scale exp(-m_i), so the xLSTM lower bound max(|den_true|, 1)
        # becomes exp(-m_i) here
        h = (y_intra + y_inter) / jnp.maximum(
            jnp.abs(den), jnp.exp(-(F + Mi)))[..., None]
        # state update to chunk end
        Ftot = F[:, -1]                              # [B, H]
        a_last = a[:, -1]
        Mc = jnp.maximum(m, a_last)
        wj = jnp.exp(s_j - Mc[:, None, :])           # [B, ch, H]
        C_new = C * jnp.exp(m - Mc)[..., None, None] \
            + jnp.einsum("bjhd,bjhv,bjh->bhdv", kc, vc, wj)
        n_new = n * jnp.exp(m - Mc)[..., None] \
            + jnp.einsum("bjhd,bjh->bhd", kc, wj)
        m_new = Ftot + Mc
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(
        jax.checkpoint(chunk_body), (C0, n0, m0),
        tuple(map(to_chunks, (qp, kp, vp, ip, fp))))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, dv)[:, :S]
    return h, (C, n, m)


def mlstm_recurrent_reference(q, k, v, i_pre, f_pre):
    """Sequential per-step reference (tests; float32, no chunking)."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    C = jnp.zeros((B, H, dk, dv), jnp.float32)
    n = jnp.zeros((B, H, dk), jnp.float32)
    m = jnp.full((B, H), -1e30, jnp.float32)
    hs = []
    for t in range(S):
        logf = jax.nn.log_sigmoid(f_pre[:, t])
        i_t = i_pre[:, t]
        m_new = jnp.maximum(logf + m, i_t)
        C = C * jnp.exp(logf + m - m_new)[..., None, None] \
            + jnp.exp(i_t - m_new)[..., None, None] \
            * jnp.einsum("bhd,bhv->bhdv", k[:, t], v[:, t])
        n = n * jnp.exp(logf + m - m_new)[..., None] \
            + jnp.exp(i_t - m_new)[..., None] * k[:, t]
        m = m_new
        num = jnp.einsum("bhd,bhdv->bhv", q[:, t], C)
        den = jnp.einsum("bhd,bhd->bh", q[:, t], n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
        hs.append(h)
    return jnp.stack(hs, axis=1), (C, n, m)


def mlstm_block(x, p, n_heads: int, cfg: XLSTMConfig):
    """Full mLSTM residual block: norm -> cell -> gated output."""
    B, S, D = x.shape
    from .layers import rms_norm
    xn = rms_norm(x, p["norm"])
    q, k, v, i_pre, f_pre = _mlstm_qkvif(xn, p, n_heads)
    h, _ = mlstm_chunkwise(q, k, v, i_pre, f_pre, chunk=cfg.chunk)
    h = h.reshape(B, S, D).astype(x.dtype)
    h = h * jax.nn.silu(xn @ p["w_gate"])
    return h @ p["w_out"]


def mlstm_decode_step(x, state, p, n_heads: int):
    """O(1) decode: single recurrent step over persistent (C, n, m)."""
    B, S, D = x.shape  # S == 1
    from .layers import rms_norm
    xn = rms_norm(x, p["norm"])
    q, k, v, i_pre, f_pre = _mlstm_qkvif(xn, p, n_heads)
    C, n, m = state
    logf = jax.nn.log_sigmoid(f_pre[:, 0])
    i_t = i_pre[:, 0]
    m_new = jnp.maximum(logf + m, i_t)
    C = C * jnp.exp(logf + m - m_new)[..., None, None] \
        + jnp.exp(i_t - m_new)[..., None, None] \
        * jnp.einsum("bhd,bhv->bhdv", k[:, 0], v[:, 0])
    n = n * jnp.exp(logf + m - m_new)[..., None] \
        + jnp.exp(i_t - m_new)[..., None] * k[:, 0]
    num = jnp.einsum("bhd,bhdv->bhv", q[:, 0], C)
    den = jnp.einsum("bhd,bhd->bh", q[:, 0], n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = h.reshape(B, 1, D).astype(x.dtype)
    h = h * jax.nn.silu(xn @ p["w_gate"])
    return h @ p["w_out"], (C, n, m_new)


def mlstm_init_state(batch: int, d_model: int, n_heads: int,
                     cfg: XLSTMConfig):
    dv = d_model // n_heads
    dk = max(int(dv * cfg.qk_dim_factor), 8)
    return (jnp.zeros((batch, n_heads, dk, dv), jnp.float32),
            jnp.zeros((batch, n_heads, dk), jnp.float32),
            jnp.full((batch, n_heads), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM
def slstm_params(key, d_model: int, n_heads: int, dtype):
    dh = d_model // n_heads
    ks = jax.random.split(key, 9)
    s = 1.0 / math.sqrt(d_model)
    sh = 1.0 / math.sqrt(dh)
    p = {"norm": jnp.ones((d_model,), dtype)}
    for idx, g in enumerate(("z", "i", "f", "o")):
        p[f"w_{g}"] = (jax.random.normal(ks[idx], (d_model, d_model))
                       * s).astype(dtype)
        p[f"r_{g}"] = (jax.random.normal(ks[4 + idx], (n_heads, dh, dh))
                       * sh).astype(dtype)
    p["b_f"] = (3.0 * jnp.ones((d_model,))).astype(dtype)
    p["w_out"] = (jax.random.normal(ks[8], (d_model, d_model))
                  * s).astype(dtype)
    return p


def slstm_block(x, p, n_heads: int, chunk: int = 256, state=None):
    """Sequential sLSTM: outer rematerialized chunks, inner step scan."""
    B, S, D = x.shape
    dh = D // n_heads
    from .layers import rms_norm
    xn = rms_norm(x, p["norm"])
    pre = {g: (xn @ p[f"w_{g}"]).astype(jnp.float32)
           for g in ("z", "i", "f", "o")}
    pre["f"] = pre["f"] + p["b_f"].astype(jnp.float32)
    ch = min(chunk, S)
    nc = -(-S // ch)
    Sp = nc * ch

    def pad(t):
        return jnp.pad(t, ((0, 0), (0, Sp - S), (0, 0)))

    xs = {g: pad(pre[g]).reshape(B, nc, ch, D).transpose(1, 0, 2, 3)
          for g in pre}
    R = {g: p[f"r_{g}"].astype(jnp.float32) for g in ("z", "i", "f", "o")}

    if state is None:
        zeros = jnp.zeros((B, D), jnp.float32)
        state = (zeros, zeros, zeros + 1e-6, zeros - 1e30)  # h, c, n, m

    def chunk_body(carry, inp):
        def step(carry, gates_t):
            h, c, n, m = carry
            hH = h.reshape(B, n_heads, dh)
            rec = {g: jnp.einsum("bhd,hde->bhe", hH, R[g]).reshape(B, D)
                   for g in R}
            z = jnp.tanh(gates_t["z"] + rec["z"])
            i_p = gates_t["i"] + rec["i"]
            f_p = jax.nn.log_sigmoid(gates_t["f"] + rec["f"])
            o = jax.nn.sigmoid(gates_t["o"] + rec["o"])
            m_new = jnp.maximum(f_p + m, i_p)
            c = c * jnp.exp(f_p + m - m_new) + jnp.exp(i_p - m_new) * z
            n = n * jnp.exp(f_p + m - m_new) + jnp.exp(i_p - m_new)
            h = o * c / jnp.maximum(n, 1e-6)
            return (h, c, n, m_new), h

        gates_seq = {g: inp[g].transpose(1, 0, 2) for g in inp}
        carry, hs = jax.lax.scan(
            step, carry,
            jax.tree_util.tree_map(lambda t: t, gates_seq))
        return carry, hs.transpose(1, 0, 2)

    state, hch = jax.lax.scan(jax.checkpoint(chunk_body), state, xs)
    h = hch.transpose(1, 0, 2, 3).reshape(B, Sp, D)[:, :S]
    return (h.astype(x.dtype) @ p["w_out"]), state


def slstm_init_state(batch: int, d_model: int):
    z = jnp.zeros((batch, d_model), jnp.float32)
    return (z, z, z + 1e-6, z - 1e30)
