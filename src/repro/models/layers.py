"""Shared neural-net layers (pure-JAX, shard_map/pjit friendly).

Conventions:
* activations ``x``: [B, S, D]; attention heads ``q``: [B, S, H, hd];
  GQA k/v: [B, S, Hkv, hd]. Params are plain dict pytrees.
* matmuls run in the param dtype (bf16); softmax statistics and norms
  accumulate in f32.
* long sequences (>= ``dense_threshold``) use *chunked online-softmax
  attention* (a pure-JAX flash-attention: O(S) memory instead of the
  O(S^2) score matrix) — at 32k x 32k a dense score tensor would be
  terabytes, so this is a correctness requirement for the dry-run, not
  just an optimization. ``repro.kernels.flash_attention`` is the Pallas
  TPU version of the same algorithm; ``attention.impl`` selects.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import AttentionConfig


# ---------------------------------------------------------------------------
def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [B, S, H, hd]; positions: [S] or [B, S] absolute indices."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
        ang = ang[None, :, None, :]                     # [1, S, 1, hd/2]
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs
        ang = ang[:, :, None, :]                        # [B, S, 1, hd/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


# ---------------------------------------------------------------------------
def _mask_bias(q_pos, kv_pos, *, causal, window, is_global):
    """Additive f32 bias: 0 where attendable, -inf where masked.

    ``is_global`` may be a traced scalar bool (scan-carried per-layer
    flag) — sliding-window layers apply ``window``; global layers do not.
    """
    d = q_pos[:, None] - kv_pos[None, :]
    ok = jnp.ones(d.shape, dtype=bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        local_ok = d < window
        if is_global is None:
            ok &= local_ok
        else:
            ok &= local_ok | is_global
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _grouped_scores(q, k):
    """q: [B, Sq, Hkv, G, hd], k: [B, Sk, Hkv, hd] -> [B, Hkv, G, Sq, Sk]
    without materializing repeated KV heads."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                      k.astype(jnp.float32))


def dense_attention(q, k, v, *, causal=True, window=None, is_global=None,
                    q_offset: int = 0):
    """Reference O(S^2) attention (short sequences / oracle)."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = _grouped_scores(qg, k) * scale                 # [B,Hkv,G,Sq,Sk]
    q_pos = jnp.arange(Sq) + q_offset
    kv_pos = jnp.arange(k.shape[1])
    s = s + _mask_bias(q_pos, kv_pos, causal=causal, window=window,
                       is_global=is_global)[None, None, None]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, window=None, is_global=None,
                      chunk_q: int = 512, chunk_kv: int = 1024):
    """Online-softmax attention over KV chunks: O(S * chunk) memory.

    Grid: scan over q chunks (rematerialized), inner scan over kv chunks
    carrying (acc, running max m, denominator l) in f32 — the exact
    algorithm the Pallas kernel implements on TPU VMEM tiles.
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    nq = -(-S // chunk_q)
    nkv = -(-k.shape[1] // chunk_kv)
    Sp_q, Sp_kv = nq * chunk_q, nkv * chunk_kv
    qp = jnp.pad(q, ((0, 0), (0, Sp_q - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sp_kv - k.shape[1]), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sp_kv - v.shape[1]), (0, 0), (0, 0)))
    qb = qp.reshape(B, nq, chunk_q, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = kp.reshape(B, nkv, chunk_kv, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nkv, chunk_kv, Hkv, hd).transpose(1, 0, 2, 3, 4)
    kv_valid = k.shape[1]

    def q_body(_, q_in):
        qc, iq = q_in                                   # [B,cq,Hkv,G,hd]
        q_pos = iq * chunk_q + jnp.arange(chunk_q)

        def kv_body(carry, kv_in):
            acc, m, l = carry
            kc, vc, ik = kv_in
            kv_pos = ik * chunk_kv + jnp.arange(chunk_kv)
            s = _grouped_scores(qc, kc) * scale          # [B,Hkv,G,cq,ckv]
            bias = _mask_bias(q_pos, kv_pos, causal=causal, window=window,
                              is_global=is_global)
            bias = jnp.where(kv_pos[None, :] < kv_valid, bias, -jnp.inf)
            s = s + bias[None, None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows (exp(-inf - -inf))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        init = (
            jnp.zeros((B, Hkv, G, chunk_q, hd), jnp.float32),
            jnp.full((B, Hkv, G, chunk_q), -jnp.inf, jnp.float32),
            jnp.zeros((B, Hkv, G, chunk_q), jnp.float32),
        )
        (acc, m, l), _ = jax.lax.scan(
            kv_body, init, (kb, vb, jnp.arange(nkv)))
        o = acc / jnp.maximum(l[..., None], 1e-30)
        return None, o.transpose(0, 3, 1, 2, 4)          # [B,cq,Hkv,G,hd]

    _, ob = jax.lax.scan(jax.checkpoint(q_body), None,
                         (qb, jnp.arange(nq)))
    o = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sp_q, H, hd)
    return o[:, :S].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len: int, *,
                     window=None, is_global=None):
    """One new query token vs a cache of ``cache_len`` valid positions.
    q: [B, 1, H, hd]; caches: [B, Smax, Hkv, hd]. O(S) — no S x S."""
    B, _, H, hd = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, 1, Hkv, G, hd)
    s = _grouped_scores(qg, k_cache)[..., 0, :] * scale  # [B,Hkv,G,Sk]
    kv_pos = jnp.arange(k_cache.shape[1])
    ok = kv_pos < cache_len
    if window is not None:
        local_ok = kv_pos >= (cache_len - window)
        ok &= (local_ok | is_global) if is_global is not None else local_ok
    s = jnp.where(ok[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
def attention_forward(q, k, v, acfg: AttentionConfig, *, causal=True,
                      window=None, is_global=None):
    """Dispatch on sequence length / configured implementation."""
    from ..distributed.act_sharding import constrain
    if acfg.repeat_kv_for_tp and k.shape[2] != q.shape[2]:
        # §Perf: broadcast KV to full H so the head dim shards on TP
        # (GQA head counts rarely divide a 16-way axis); the grouped
        # einsum otherwise leaves heads unshardable and GSPMD inserts
        # per-chunk gathers *inside* the attention scan.
        G = q.shape[2] // k.shape[2]
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "heads", None))
    v = constrain(v, ("batch", None, "heads", None))
    S = q.shape[1]
    impl = acfg.impl
    if impl == "pallas":
        from ..kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    if impl == "dense" or (impl == "auto" and S <= acfg.dense_threshold):
        return dense_attention(q, k, v, causal=causal, window=window,
                               is_global=is_global)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             is_global=is_global, chunk_q=acfg.chunk_q,
                             chunk_kv=acfg.chunk_kv)


# ---------------------------------------------------------------------------
def attention_block_params(key, d_model, n_heads, n_kv_heads, hd, dtype,
                           qk_norm=False):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    p = {
        "wq": (jax.random.normal(k1, (d_model, n_heads * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, n_kv_heads * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, n_kv_heads * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (n_heads * hd, d_model)) * s).astype(dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention_block(x, params, acfg: AttentionConfig, n_heads, n_kv_heads,
                    hd, *, positions=None, is_global=None, window=None):
    B, S, D = x.shape
    q = (x @ params["wq"]).reshape(B, S, n_heads, hd)
    k = (x @ params["wk"]).reshape(B, S, n_kv_heads, hd)
    v = (x @ params["wv"]).reshape(B, S, n_kv_heads, hd)
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if positions is None:
        positions = jnp.arange(S)
    q = apply_rope(q, positions, acfg.rope_theta)
    k = apply_rope(k, positions, acfg.rope_theta)
    o = attention_forward(q, k, v, acfg, causal=True, window=window,
                          is_global=is_global)
    return o.reshape(B, S, n_heads * hd) @ params["wo"], (k, v)


def attention_decode_block(x, params, acfg: AttentionConfig, n_heads,
                           n_kv_heads, hd, k_cache, v_cache, cache_len,
                           *, window=None, is_global=None):
    """Decode one token; returns output + updated caches."""
    B, S, D = x.shape  # S == 1
    q = (x @ params["wq"]).reshape(B, S, n_heads, hd)
    k = (x @ params["wk"]).reshape(B, S, n_kv_heads, hd)
    v = (x @ params["wv"]).reshape(B, S, n_kv_heads, hd)
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    pos = jnp.full((S,), cache_len, dtype=jnp.int32)
    q = apply_rope(q, pos, acfg.rope_theta)
    k = apply_rope(k, pos, acfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, cache_len, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, cache_len, 1)
    o = decode_attention(q, k_cache, v_cache, cache_len + 1, window=window,
                         is_global=is_global)
    return (o.reshape(B, S, n_heads * hd) @ params["wo"],
            k_cache, v_cache)


def mlp_params(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }


def cross_entropy_loss(logits, labels, ignore_index: int = -1):
    """Mean token cross-entropy in f32; labels == ignore_index masked.

    The label term uses a one-hot contraction, NOT take_along_axis: a
    gather along the vocab axis forces GSPMD to all-gather vocab-sharded
    logits (tens of GB at 200k vocab), while the one-hot einsum reduces
    over the sharded axis with a cheap all-reduce.
    """
    from ..distributed.act_sharding import constrain
    ldims = ("batch",) + (None,) * (logits.ndim - 2) + ("vocab",)
    logits = constrain(logits.astype(jnp.float32), ldims)
    lse = jax.nn.logsumexp(logits, axis=-1)
    one_hot = jax.nn.one_hot(jnp.maximum(labels, 0), logits.shape[-1],
                             dtype=jnp.float32)
    one_hot = constrain(one_hot, ldims)
    gather = (logits * one_hot).sum(axis=-1)
    nll = lse - gather
    mask = (labels != ignore_index).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
