"""Unified LM covering all assigned families (dense/MoE/hybrid/SSM/VLM/
audio) with scan-over-layers + remat — compile cost independent of depth,
which is what makes 61–72-layer trillion-parameter dry-runs feasible.

Families map to scan templates:
* dense / moe / vlm / audio — homogeneous decoder layers, one scan over
  the stacked [L, ...] params; per-layer static flags (gemma3's 5:1
  local:global pattern) ride along as scanned xs.
* hybrid (jamba) — scan over *periods* of ``attn_every`` layers; the
  period body unrolls 1 attention + (N-1) Mamba sublayers with the
  dense/MoE FFN alternation baked into the template.
* ssm (xlstm) — scan over periods of ``slstm_every`` blocks: (N-1)
  stacked mLSTM + 1 sLSTM.

Serving: ``init_cache`` + ``decode_step`` implement one-token decode with
per-family persistent state (KV caches / Mamba (h, conv) / mLSTM (C,n,m)).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import mamba as mam
from . import moe as moe_mod
from . import xlstm as xl
from .layers import (attention_block, attention_block_params,
                     attention_decode_block, cross_entropy_loss, mlp_params,
                     rms_norm, swiglu)


# ---------------------------------------------------------------------------
# parameter init
def _layer_params(key, cfg: ModelConfig, moe_layer: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
        "attn": attention_block_params(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            cfg.dtype, qk_norm=cfg.attention.qk_norm),
    }
    if moe_layer:
        p["moe"] = moe_mod.moe_params(k2, cfg.d_model, cfg.d_ff,
                                      cfg.moe.num_experts, cfg.dtype)
    elif cfg.d_ff:
        p["mlp"] = mlp_params(k3, cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def _mamba_layer_params(key, cfg: ModelConfig, moe_layer: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
        "mamba": mam.mamba_params(k1, cfg.d_model, cfg.mamba, cfg.dtype),
    }
    if moe_layer:
        p["moe"] = moe_mod.moe_params(k2, cfg.d_model, cfg.d_ff,
                                      cfg.moe.num_experts, cfg.dtype)
    elif cfg.d_ff:
        p["mlp"] = mlp_params(k3, cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def _stack(key, n, fn):
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def _is_moe_layer(cfg: ModelConfig, i: int) -> bool:
    return (cfg.moe is not None
            and i % cfg.moe.every_n_layers == cfg.moe.every_n_layers - 1)


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kh, kl = jax.random.split(key, 3)
    s = 0.02
    params: dict = {"final_norm": jnp.ones((cfg.d_model,), cfg.dtype)}
    V = cfg.padded_vocab   # §Perf: shardable padded vocab (base.py)
    if cfg.num_codebooks:
        params["embed"] = (jax.random.normal(
            ke, (cfg.num_codebooks, V, cfg.d_model)) * s
        ).astype(cfg.dtype)
        params["head"] = (jax.random.normal(
            kh, (cfg.d_model, cfg.num_codebooks * V)) * s
        ).astype(cfg.dtype)
    else:
        params["embed"] = (jax.random.normal(
            ke, (V, cfg.d_model)) * s).astype(cfg.dtype)
        if not cfg.tie_embeddings:
            params["head"] = (jax.random.normal(
                kh, (cfg.d_model, V)) * s).astype(cfg.dtype)

    if cfg.family == "ssm":
        x = cfg.xlstm
        n_periods = cfg.n_layers // x.slstm_every
        n_m = x.slstm_every - 1
        k1, k2 = jax.random.split(kl)
        params["layers"] = {
            "mlstm": _stack(k1, n_periods, lambda k: _stack(
                k, n_m, lambda kk: xl.mlstm_params(
                    kk, cfg.d_model, cfg.n_heads, x, cfg.dtype))),
            "slstm": _stack(k2, n_periods, lambda k: xl.slstm_params(
                k, cfg.d_model, cfg.n_heads, cfg.dtype)),
        }
    elif cfg.family == "hybrid":
        period = cfg.attention.attn_every
        n_periods = cfg.n_layers // period
        ks = jax.random.split(kl, period)
        stacked = {}
        for pos in range(period):
            moe_l = cfg.moe is not None and pos % cfg.moe.every_n_layers \
                == cfg.moe.every_n_layers - 1
            if pos == 0:
                stacked[f"pos{pos}"] = _stack(
                    ks[pos], n_periods,
                    lambda k, m=moe_l: _layer_params(k, cfg, m))
            else:
                stacked[f"pos{pos}"] = _stack(
                    ks[pos], n_periods,
                    lambda k, m=moe_l: _mamba_layer_params(k, cfg, m))
        params["layers"] = stacked
    else:
        moe_l = cfg.moe is not None and cfg.moe.every_n_layers == 1
        if cfg.moe is not None and cfg.moe.every_n_layers > 1:
            # alternating moe/dense: scan over pairs
            n_pairs = cfg.n_layers // cfg.moe.every_n_layers
            k1, k2 = jax.random.split(kl)
            params["layers"] = {
                "dense": _stack(k1, n_pairs,
                                lambda k: _layer_params(k, cfg, False)),
                "moe": _stack(k2, n_pairs,
                              lambda k: _layer_params(k, cfg, True)),
            }
        else:
            params["layers"] = _stack(
                kl, cfg.n_layers, lambda k: _layer_params(k, cfg, moe_l))
    return params


def abstract_params(cfg: ModelConfig) -> dict:
    """Parameter ShapeDtypeStructs without any allocation (dry-run)."""
    return jax.eval_shape(partial(init_params, cfg), jax.random.key(0))


# ---------------------------------------------------------------------------
# forward
def _global_flags(cfg: ModelConfig) -> jnp.ndarray | None:
    ge = cfg.attention.global_every
    if ge is None:
        return None
    return jnp.array([(i % ge) == ge - 1 for i in range(cfg.n_layers)])


def _decoder_layer(x, lp, cfg: ModelConfig, *, is_global=None,
                   positions=None):
    window = cfg.attention.sliding_window
    h, _ = attention_block(
        rms_norm(x, lp["ln1"]), lp["attn"], cfg.attention, cfg.n_heads,
        cfg.n_kv_heads, cfg.hd, positions=positions, is_global=is_global,
        window=window)
    x = x + h
    xn = rms_norm(x, lp["ln2"])
    if "moe" in lp:
        x = x + moe_mod.moe_ffn(xn, lp["moe"], cfg.moe)
    elif "mlp" in lp:
        x = x + swiglu(xn, **lp["mlp"])
    return x


def _mamba_layer(x, lp, cfg: ModelConfig):
    h, _ = mam.mamba_block(rms_norm(x, lp["ln1"]), lp["mamba"], cfg.mamba)
    x = x + h
    xn = rms_norm(x, lp["ln2"])
    if "moe" in lp:
        x = x + moe_mod.moe_ffn(xn, lp["moe"], cfg.moe)
    elif "mlp" in lp:
        x = x + swiglu(xn, **lp["mlp"])
    return x


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def backbone(params, x, cfg: ModelConfig, positions=None):
    """x: [B, S, D] embedded inputs -> final hidden states."""
    if cfg.family == "ssm":
        xcfg = cfg.xlstm

        def period_body(h, pp):
            def m_body(hh, mp):
                return hh + xl.mlstm_block(hh, mp, cfg.n_heads, xcfg), None
            h, _ = jax.lax.scan(_remat(m_body, cfg), h, pp["mlstm"])
            s_out, _ = xl.slstm_block(h, pp["slstm"], cfg.n_heads,
                                      chunk=xcfg.chunk)
            return h + s_out, None

        x, _ = jax.lax.scan(_remat(period_body, cfg), x, params["layers"])
    elif cfg.family == "hybrid":
        period = cfg.attention.attn_every

        def period_body(h, pp):
            h = _decoder_layer(h, pp["pos0"], cfg, positions=positions)
            for pos in range(1, period):
                h = _mamba_layer(h, pp[f"pos{pos}"], cfg)
            return h, None

        x, _ = jax.lax.scan(_remat(period_body, cfg), x, params["layers"])
    elif cfg.moe is not None and cfg.moe.every_n_layers > 1:
        def pair_body(h, pp):
            h = _decoder_layer(h, pp["dense"], cfg, positions=positions)
            h = _decoder_layer(h, pp["moe"], cfg, positions=positions)
            return h, None

        x, _ = jax.lax.scan(_remat(pair_body, cfg), x, params["layers"])
    else:
        flags = _global_flags(cfg)
        xs = (params["layers"], flags) if flags is not None \
            else (params["layers"],)

        def body(h, inp):
            lp = inp[0]
            ig = inp[1] if len(inp) > 1 else None
            return _decoder_layer(h, lp, cfg, is_global=ig,
                                  positions=positions), None

        x, _ = jax.lax.scan(_remat(body, cfg), x, xs)
    return rms_norm(x, params["final_norm"])


def embed_inputs(params, batch: dict, cfg: ModelConfig):
    """Family-specific input embedding. Modality frontends are stubs:
    VLM patch embeddings / audio EnCodec tokens arrive precomputed."""
    if cfg.family == "vlm":
        text = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = jnp.concatenate(
            [batch["patch_embeds"].astype(cfg.dtype), text], axis=1)
        return x
    if cfg.family == "audio":
        # sum of per-codebook embeddings (delay pattern applied upstream)
        emb = jax.vmap(lambda cb, tok: jnp.take(cb, tok, axis=0),
                       in_axes=(0, 2), out_axes=2)(
            params["embed"], batch["codes"])      # [B,S,K,D]
        return emb.sum(axis=2)
    return jnp.take(params["embed"], batch["tokens"], axis=0)


def logits_fn(params, h, cfg: ModelConfig):
    from ..distributed.act_sharding import constrain
    if cfg.tie_embeddings:
        if cfg.num_codebooks:
            return jnp.einsum("bsd,kvd->bskv", h, params["embed"])
        return constrain(h @ params["embed"].T, ("batch", None, "vocab"))
    if cfg.num_codebooks:
        B, S, D = h.shape
        out = constrain(h @ params["head"], ("batch", None, "vocab"))
        return out.reshape(B, S, cfg.num_codebooks, cfg.padded_vocab)
    return constrain(h @ params["head"], ("batch", None, "vocab"))


def loss_fn(params, batch: dict, cfg: ModelConfig):
    x = embed_inputs(params, batch, cfg)
    positions = jnp.arange(x.shape[1])
    h = backbone(params, x, cfg, positions=positions)
    if cfg.family == "vlm":
        h = h[:, batch["patch_embeds"].shape[1]:]  # loss on text positions
    logits = logits_fn(params, h, cfg)
    if cfg.num_codebooks:
        return cross_entropy_loss(logits, batch["labels"])
    return cross_entropy_loss(logits, batch["labels"])


# ---------------------------------------------------------------------------
# serving: cache init + one-token decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Persistent decode state, family-specific."""
    kv = lambda: jnp.zeros(  # noqa: E731
        (batch, max_len, cfg.n_kv_heads, cfg.hd), cfg.dtype)
    if cfg.family == "ssm":
        x = cfg.xlstm
        n_periods = cfg.n_layers // x.slstm_every
        n_m = x.slstm_every - 1
        dv = cfg.d_model // cfg.n_heads
        dk = max(int(dv * x.qk_dim_factor), 8)
        return {
            "mlstm_C": jnp.zeros((n_periods, n_m, batch, cfg.n_heads,
                                  dk, dv), jnp.float32),
            "mlstm_n": jnp.zeros((n_periods, n_m, batch, cfg.n_heads, dk),
                                 jnp.float32),
            "mlstm_m": jnp.full((n_periods, n_m, batch, cfg.n_heads),
                                -1e30, jnp.float32),
            "slstm": jnp.zeros((n_periods, 4, batch, cfg.d_model),
                               jnp.float32),
        }
    if cfg.family == "hybrid":
        period = cfg.attention.attn_every
        n_periods = cfg.n_layers // period
        m = cfg.mamba
        d_inner = m.expand * cfg.d_model
        return {
            "k": jnp.zeros((n_periods, batch, max_len, cfg.n_kv_heads,
                            cfg.hd), cfg.dtype),
            "v": jnp.zeros((n_periods, batch, max_len, cfg.n_kv_heads,
                            cfg.hd), cfg.dtype),
            "mamba_h": jnp.zeros((n_periods, period - 1, batch, d_inner,
                                  m.d_state), jnp.float32),
            "mamba_conv": jnp.zeros((n_periods, period - 1, batch,
                                     m.d_conv - 1, d_inner), jnp.float32),
        }
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd),
                       cfg.dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd),
                       cfg.dtype),
    }


def decode_step(params, cache: dict, batch: dict, cache_len: int,
                cfg: ModelConfig):
    """One new token for every sequence. Returns (logits, new_cache)."""
    if cfg.family == "vlm":
        # image patches were consumed at prefill; decode is text-only
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    else:
        x = embed_inputs(params, batch, cfg)       # [B, 1, D]
    window = cfg.attention.sliding_window

    if cfg.family == "ssm":
        xcfg = cfg.xlstm

        def period_body(h, st):
            def m_body(hh, mst):
                mp, (C, n, m) = mst
                out, (C2, n2, m2) = xl.mlstm_decode_step(
                    hh, (C, n, m), mp, cfg.n_heads)
                return hh + out, (C2, n2, m2)
            h, new_m = jax.lax.scan(
                m_body, h, (st["p"]["mlstm"],
                            (st["C"], st["n"], st["m"])))
            hs, cs, ns, ms = st["slstm"]
            s_out, sstate = xl.slstm_block(
                h, st["p"]["slstm"], cfg.n_heads, chunk=1,
                state=(hs, cs, ns, ms))
            return h + s_out, {"m": new_m, "s": jnp.stack(sstate)}

        def outer(h, st):
            return period_body(h, st)

        h, news = jax.lax.scan(
            outer, x,
            {"p": params["layers"],
             "C": cache["mlstm_C"], "n": cache["mlstm_n"],
             "m": cache["mlstm_m"],
             "slstm": cache["slstm"]})
        new_cache = {
            "mlstm_C": news["m"][0], "mlstm_n": news["m"][1],
            "mlstm_m": news["m"][2], "slstm": news["s"],
        }
    elif cfg.family == "hybrid":
        period = cfg.attention.attn_every

        def period_body(h, st):
            pp = st["p"]
            hn = rms_norm(h, pp["pos0"]["ln1"])
            a_out, ck, cv = attention_decode_block(
                hn, pp["pos0"]["attn"], cfg.attention, cfg.n_heads,
                cfg.n_kv_heads, cfg.hd, st["k"], st["v"], cache_len,
                window=window)
            h = h + a_out
            xn = rms_norm(h, pp["pos0"]["ln2"])
            if "moe" in pp["pos0"]:
                h = h + moe_mod.moe_ffn(xn, pp["pos0"]["moe"],
                                        _decode_moe(cfg))
            elif "mlp" in pp["pos0"]:
                h = h + swiglu(xn, **pp["pos0"]["mlp"])
            new_h, new_conv = [], []
            for pos in range(1, period):
                lp = pp[f"pos{pos}"]
                m_out, mstate = mam.mamba_decode_step(
                    rms_norm(h, lp["ln1"]),
                    {"h": st["mh"][pos - 1], "conv": st["mc"][pos - 1]},
                    lp["mamba"], cfg.mamba)
                h = h + m_out
                xn = rms_norm(h, lp["ln2"])
                if "moe" in lp:
                    h = h + moe_mod.moe_ffn(xn, lp["moe"], _decode_moe(cfg))
                elif "mlp" in lp:
                    h = h + swiglu(xn, **lp["mlp"])
                new_h.append(mstate["h"])
                new_conv.append(mstate["conv"])
            return h, {"k": ck, "v": cv, "mh": jnp.stack(new_h),
                       "mc": jnp.stack(new_conv)}

        h, news = jax.lax.scan(
            period_body, x,
            {"p": params["layers"], "k": cache["k"], "v": cache["v"],
             "mh": cache["mamba_h"], "mc": cache["mamba_conv"]})
        new_cache = {"k": news["k"], "v": news["v"],
                     "mamba_h": news["mh"], "mamba_conv": news["mc"]}
    else:
        flags = _global_flags(cfg)

        def body(h, st):
            lp = st["p"]
            ig = st.get("g")
            hn = rms_norm(h, lp["ln1"])
            a_out, ck, cv = attention_decode_block(
                hn, lp["attn"], cfg.attention, cfg.n_heads, cfg.n_kv_heads,
                cfg.hd, st["k"], st["v"], cache_len, window=window,
                is_global=ig)
            h = h + a_out
            xn = rms_norm(h, lp["ln2"])
            if "moe" in lp:
                h = h + moe_mod.moe_ffn(xn, lp["moe"], _decode_moe(cfg))
            elif "mlp" in lp:
                h = h + swiglu(xn, **lp["mlp"])
            return h, {"k": ck, "v": cv}

        layers = params["layers"]
        if cfg.moe is not None and cfg.moe.every_n_layers > 1:
            def pair_body(h, st):
                h, kv1 = body(h, {"p": st["pd"], "k": st["k1"],
                                  "v": st["v1"]})
                h, kv2 = body(h, {"p": st["pm"], "k": st["k2"],
                                  "v": st["v2"]})
                return h, {"k": jnp.stack([kv1["k"], kv2["k"]]),
                           "v": jnp.stack([kv1["v"], kv2["v"]])}
            n_pairs = cache["k"].shape[0] // 2
            kp = cache["k"].reshape((n_pairs, 2) + cache["k"].shape[1:])
            vp = cache["v"].reshape((n_pairs, 2) + cache["v"].shape[1:])
            h, news = jax.lax.scan(
                pair_body, x,
                {"pd": layers["dense"], "pm": layers["moe"],
                 "k1": kp[:, 0], "v1": vp[:, 0],
                 "k2": kp[:, 1], "v2": vp[:, 1]})
            nk = news["k"].reshape(cache["k"].shape)
            nv = news["v"].reshape(cache["v"].shape)
            new_cache = {"k": nk, "v": nv}
        else:
            xs = {"p": layers, "k": cache["k"], "v": cache["v"]}
            if flags is not None:
                xs["g"] = flags
            h, news = jax.lax.scan(body, x, xs)
            new_cache = {"k": news["k"], "v": news["v"]}

    h = rms_norm(h, params["final_norm"])
    return logits_fn(params, h, cfg), new_cache


def _decode_moe(cfg: ModelConfig):
    return dataclasses.replace(cfg.moe, num_groups=1)
