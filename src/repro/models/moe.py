"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

Design (TPU/GSPMD-native, DESIGN.md §5):
* tokens are reshaped into ``num_groups`` dispatch groups (the launcher
  sets groups = data-parallel size) so expert routing stays data-local
  until the single all-to-all that GSPMD inserts between the
  group-sharded token tensor and the expert-sharded weights;
* per group, top-k assignments are sorted by expert id; position-in-
  expert comes from a searchsorted over the sorted ids (O(T k log Tk),
  no [T, E] one-hot matrix — at 1M tokens x 384 experts that matrix
  alone would be ~1.5 GB/device);
* each expert processes a fixed ``capacity`` of tokens (tokens over
  capacity are dropped, standard Switch/GShard semantics with
  ``capacity_factor`` headroom), giving static shapes [G, E, C, D] that
  compile and shard cleanly;
* combine scatters expert outputs back with the renormalized gate
  weights.

FLOPs scale with top_k (N_active), not num_experts — the property the
roofline's MODEL_FLOPS/HLO_FLOPs ratio checks.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import MoEConfig


def moe_params(key, d_model: int, d_ff: int, n_experts: int, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "router": (jax.random.normal(k1, (d_model, n_experts))
                   * s_in).astype(jnp.float32),
        "we_gate": (jax.random.normal(k2, (n_experts, d_model, d_ff))
                    * s_in).astype(dtype),
        "we_up": (jax.random.normal(k3, (n_experts, d_model, d_ff))
                  * s_in).astype(dtype),
        "we_down": (jax.random.normal(k4, (n_experts, d_ff, d_model))
                    * s_out).astype(dtype),
    }


def expert_capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    c = math.ceil(cfg.top_k * tokens_per_group * cfg.capacity_factor
                  / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # pad to multiple of 8 for tiling


def _dispatch_one_group(xg, topi, topv, n_experts: int, capacity: int):
    """xg: [Tg, D]; topi/topv: [Tg, k] -> (xe [E, C, D], gmap, weights)."""
    Tg, k = topi.shape
    flat_e = topi.reshape(-1)                      # [Tg*k]
    flat_w = topv.reshape(-1)
    order = jnp.argsort(flat_e)                    # stable sort by expert
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(n_experts))
    pos = jnp.arange(Tg * k) - first[sorted_e]     # rank within expert
    valid = pos < capacity
    ge = jnp.where(valid, sorted_e, n_experts)     # overflow -> dummy row
    gp = jnp.where(valid, pos, 0)
    tok = order // k                               # token id of assignment
    gmap = jnp.full((n_experts + 1, capacity), Tg, dtype=jnp.int32)
    gmap = gmap.at[ge, gp].set(tok.astype(jnp.int32))[:n_experts]
    wmap = jnp.zeros((n_experts + 1, capacity), jnp.float32)
    wmap = wmap.at[ge, gp].set(flat_w[order])[:n_experts]
    x_pad = jnp.concatenate([xg, jnp.zeros((1, xg.shape[1]), xg.dtype)], 0)
    return x_pad[gmap], gmap, wmap                 # xe: [E, C, D]


def moe_ffn(x, params, cfg: MoEConfig):
    """x: [B, S, D] -> [B, S, D]."""
    B, S, D = x.shape
    T = B * S
    G = cfg.num_groups
    assert T % G == 0, f"tokens {T} not divisible by groups {G}"
    Tg = T // G
    E, k = cfg.num_experts, cfg.top_k
    C = expert_capacity(Tg, cfg)

    xf = x.reshape(G, Tg, D)
    logits = (xf.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))  # [G, Tg, E] f32
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    xe, gmap, wmap = jax.vmap(
        lambda xg, ti, tv: _dispatch_one_group(xg, ti, tv, E, C)
    )(xf, topi, topv)                              # xe: [G, E, C, D]

    # expert SwiGLU: FLOPs = G*E*C*D*F*3*2 = top_k-scaled active compute
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["we_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, params["we_up"])
    out = jnp.einsum("gecf,efd->gecd", h, params["we_down"])

    # combine: scatter-add weighted expert outputs back to token slots
    def _combine(out_g, gmap_g, wmap_g):
        y = jnp.zeros((Tg + 1, D), jnp.float32)
        y = y.at[gmap_g.reshape(-1)].add(
            (out_g * wmap_g[..., None]).reshape(-1, D).astype(jnp.float32))
        return y[:Tg]

    y = jax.vmap(_combine)(out, gmap, wmap)        # [G, Tg, D]
    return y.reshape(B, S, D).astype(x.dtype)


def aux_load_balance_loss(x, params, cfg: MoEConfig) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss (fraction * probability)."""
    B, S, D = x.shape
    logits = (x.reshape(-1, D).astype(jnp.float32)
              @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.num_experts, dtype=jnp.float32),
                    axis=0)
    prob = jnp.mean(probs, axis=0)
    return cfg.num_experts * jnp.sum(frac * prob)
