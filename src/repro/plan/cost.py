"""Throughput cost model for remediation plans (ISSUE 5).

"Cheapest feasible" must mean *lowest modeled slowdown*, not smallest
memory — a counter-offer that fits by quartering the batch is worthless
if a microbatch split would have fit at a fraction of the cost. The
planner therefore scores every candidate plan with the same analytic
roofline terms the launch CLIs print (``launch/analytic.py``):

* compute time = analytic FLOPs / peak FLOPs (remat-aware: full remat
  pays the re-forward);
* memory time = analytic HBM traffic / HBM bandwidth (microbatch-aware:
  every microbatch re-reads the parameters; remat-aware: fewer
  activation passes without remat);
* step time = max of the two (the roofline);
* **cost = device-seconds per trained token** — step time x device
  count / tokens per step.  Device-seconds keeps topology offers honest
  (a bigger mesh lowers per-device time but is not free hardware) and
  batch offers honest (a smaller batch amortizes the fixed
  parameter/optimizer traffic over fewer tokens).

Offers are ranked by this cost; ``slowdown`` is the ratio against the
rejected plan's cost, so ``slowdown=1.12`` reads as "12% more
device-time per token than what you asked for".
"""
from __future__ import annotations

from ..configs.base import ModelConfig, ShapeSpec
from ..launch.analytic import analytic_bytes, analytic_flops

# v5e-class chip constants — identical to launch/hillclimb.py (not
# imported from there: that module sets XLA_FLAGS at import time)
PEAK_FLOPS, HBM_BW = 197e12, 819e9

# Host<->device interconnect bandwidth charged for offload transfers
# (PCIe Gen4 x16-class, the v5e host link). Offload staging overlaps
# compute, so it enters the roofline as a third ceiling rather than a
# serial add — an offload plan is "free" until its transfer time
# becomes the binding term.
PCIE_BW = 32e9

# HBM passes over materialized activations per remat policy: full remat
# writes, rewrites on the re-forward, and reads; no remat writes + reads
ACT_PASSES = {"full": 3.0, "dots": 2.5, "none": 2.0}


def plan_cost(cfg: ModelConfig, shape: ShapeSpec, *,
              microbatches: int = 1, topology=None,
              offload_transfer_bytes: int = 0) -> dict:
    """Roofline terms + device-seconds-per-token for one plan.

    ``topology`` is a ``MeshTopology`` (or None for the single-device
    plan); ``cfg.remat`` selects the re-forward FLOPs and activation
    traffic; ``microbatches`` multiplies the parameter re-reads;
    ``offload_transfer_bytes`` is the per-device host<->device traffic
    one iteration moves (from the orchestrator's offload stats), charged
    over PCIe as a third roofline ceiling — this is what makes offload
    counter-offers read "fits at X% modeled slowdown".
    """
    n_dev = topology.n_devices if topology is not None else 1
    model_shards = topology.model if topology is not None else 1
    fsdp_shards = (topology.pod * topology.data
                   if topology is not None and topology.fsdp else 1)
    refwd = cfg.remat == "full"
    flops_dev = analytic_flops(cfg, shape, remat_refwd=refwd) / n_dev
    bytes_dev = analytic_bytes(
        cfg, shape, n_devices=n_dev, model_shards=model_shards,
        fsdp_shards=max(fsdp_shards, 1),
        microbatches=max(int(microbatches), 1),
        act_passes=ACT_PASSES.get(cfg.remat, 3.0))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_transfer = max(int(offload_transfer_bytes), 0) / PCIE_BW
    t_step = max(t_compute, t_memory, t_transfer)
    out = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "step_time_s": t_step,
        "device_s_per_token": n_dev * t_step / max(shape.tokens, 1),
    }
    if offload_transfer_bytes:
        out["t_transfer_s"] = t_transfer
    return out


def serving_cost(*, params_bytes: int, kv_bytes_per_token: int, knobs,
                 avg_seq_len: float, shared_prefix_len: int = 0,
                 flops_per_token: float | None = None) -> dict:
    """Roofline terms + device-seconds-per-token for one serving plan.

    A decode step over ``c = knobs.max_concurrent`` sequences streams
    the parameters once plus every active sequence's paged KV cache —
    page-quantized (a 512-token prompt at page 16 reads 32 full pages;
    larger pages waste tail bytes), dtype-scaled (fp8 KV halves the
    traffic), prefix-shared pages counted ONCE instead of per sequence,
    and speculative drafts adding ``k`` extra KV columns per sequence.
    The step emits ``c`` tokens, so concurrency amortizes the fixed
    parameter read — exactly the tension the planner must price: bigger
    ``c`` lowers device-s/token until the KV traffic term (or capacity)
    binds.
    """
    c = max(int(knobs.max_concurrent), 1)
    page = max(int(knobs.page_size), 1)
    tok_b = max(int(kv_bytes_per_token), 1) * knobs.kv_dtype_bytes / 2.0
    pages_per_seq = -(-max(avg_seq_len, 1.0) // page)
    seq_bytes = pages_per_seq * page * tok_b
    shared_bytes = 0.0
    if knobs.prefix_cache and shared_prefix_len > 0:
        shared_pages = int(shared_prefix_len) // page
        shared_bytes = shared_pages * page * tok_b
    kv_traffic = c * (seq_bytes - shared_bytes) + shared_bytes \
        + c * knobs.speculative_k * tok_b
    if flops_per_token is None:
        # bf16 params: n_params ~ params_bytes/2; ~2 FLOPs per param
        # per token — the standard dense-decoder estimate
        flops_per_token = float(params_bytes)
    t_compute = c * flops_per_token / PEAK_FLOPS
    t_memory = (params_bytes + kv_traffic) / HBM_BW
    t_step = max(t_compute, t_memory)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "step_time_s": t_step,
        "device_s_per_token": t_step / c,
        "kv_traffic_bytes": kv_traffic,
    }
