"""Remediation planner: rejections become cheapest-feasible counter-offers.

Given a job the admission service just bounced, search the plan space —
per-replica batch size, gradient-accumulation microbatches, remat
policy, mesh topology, optional vocab padding — and return a ranked
list of :class:`CounterOffer`\\ s that *do* fit the capacity, each
scored by the analytic roofline cost model (``plan/cost.py``) so the
first offer is the cheapest modeled slowdown, not merely the smallest
memory.

The search is **trace-frugal** by construction: every knob is routed
through the cheapest estimation machinery that is exact for it.

* **topology** — program structure is topology-independent, so the
  whole (pod, data, model, fsdp) grid replays from ONE cached trace
  (``SweepService.estimate_mesh_sweep``): zero fresh traces.
* **batch size** — only avals change, so candidates ride
  ``AdmissionService.decide_sweep``'s exact-or-bust affine
  interpolation; the rejected batch itself is swept along as the warm
  max-probe anchor, leaving ~2 fresh probe traces for the whole axis.
* **microbatches / remat / pad_vocab** — these change the traced
  program, so each distinct candidate costs one fresh forward trace
  (optimizer phases stay warm through the content-addressed cache);
  the default space keeps these axes small.

A default search over ≥30 candidate plans costs ≤6 fresh traces
(bench-asserted in ``benchmarks/perf_estimator.py``).

Every offer is *reproducible*: ``CounterOffer.admission_request``
rebuilds the exact (hooks, params, batch, shard factors, collective
specs) tuple, and a direct ``AdmissionService.decide`` on it yields the
offer's estimate bit-identically (pinned by tests/test_planner.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

from ..configs.base import ModelConfig, ShapeSpec
from ..core.sweep import MeshTopology, topology_grid
from ..obs import spans as obs_spans
from ..service.admission import (AdmissionDecision, AdmissionRequest,
                                 AdmissionService)
from ..train.train_step import TrainPolicy, make_estimator_hooks
from .cost import plan_cost

_REMAT_ORDER = ("none", "dots", "full")     # ascending memory savings


@dataclasses.dataclass(frozen=True)
class PlanSpace:
    """Which knobs the planner may turn, and how far.

    ``None`` means "derive a default grid from the rejected plan";
    an empty tuple switches the axis off.
    """

    batches: tuple | None = None        # explicit per-replica batch grid
    microbatches: tuple | None = None   # explicit accumulation factors
    remat: tuple | None = None          # explicit remat rungs to try
    devices: tuple = ()                 # device counts for the mesh grid
    pods: tuple = (1,)                  # pod counts forwarded to the grid
    base_topology: MeshTopology | None = None  # fixed mesh for ALL plans
    pad_vocab_multiple: int | None = None      # padded-vocab mesh variants
    batch_halvings: int = 3             # default batch grid depth
    mb_doublings: int = 2               # default microbatch grid depth
    max_offers: int = 5                 # ranked offers returned
    early_stop: bool = False            # stop fresh-trace singles at the
    #                                     first feasible offer (replan path)
    # -- host-offload axes (ISSUE 8) -- candidates change only the
    # orchestrator's offload pass, never the traced program, so the
    # whole axis costs ZERO fresh traces (warm after the baseline)
    offload_opt_state: bool = False     # try optimizer-state offload
    offload_activations: tuple = ()     # activation fractions to try
    #                                     (each combined with opt-state
    #                                     offload when that is enabled)
    # -- serving axes (ISSUE 9) -- knobs only change the CPU-side
    # request-stream lowering and the allocator replay, never the traced
    # decode step, so the whole grid shares the baseline's cached trace
    # (SERVING_TRACE_BUDGET-asserted). Empty tuple = keep the rejected
    # plan's value for that knob.
    page_sizes: tuple = ()              # KV page sizes (tokens) to try
    max_concurrents: tuple = ()         # in-flight sequence caps to try
    kv_dtypes: tuple = ()               # KV element widths (bytes)
    prefix_cache: tuple = ()            # (True, False) toggles to try


@dataclasses.dataclass(frozen=True)
class PlanContext:
    """The structured job description a planner search needs — attach as
    ``AdmissionRequest.meta["plan"]`` and a rejection comes back with
    ``counter_offers`` populated."""

    cfg: ModelConfig
    policy: TrainPolicy
    shape: ShapeSpec
    space: PlanSpace = PlanSpace()


@dataclasses.dataclass(frozen=True)
class ServingPlanContext:
    """The serving job description a ``plan_serving`` search needs —
    pass as ``AdmissionService.decide_serving(..., plan=ctx)`` and a
    request-driven rejection comes back with serving counter-offers.

    Carries the exact decode tuple so every probe shares the rejected
    request's cached trace, plus the request mix the offers must serve
    and the knob axes (``space.page_sizes`` / ``max_concurrents`` /
    ``kv_dtypes`` / ``prefix_cache``) the planner may turn."""

    decode_fn: Any
    params: Any
    cache: Any
    batch: Any
    mix: Any                            # RequestMix (or RequestStream)
    knobs: Any = None                   # base ServingKnobs (rejected plan)
    kv_bytes_per_token: int = 0
    resident_bytes_per_request: int = 0
    space: PlanSpace = PlanSpace()


@dataclasses.dataclass
class CounterOffer:
    """One feasible alternative plan for a rejected job."""

    job_id: str
    knob: str                       # axis that produced it
    global_batch: int
    microbatches: int
    remat: str
    topology: MeshTopology | None
    pad_vocab_multiple: int | None
    capacity: int
    peak_bytes: int
    safe_threshold: int             # Eq. 5: the estimate as memory cap
    cost: dict                      # roofline terms (plan/cost.py)
    slowdown: float                 # cost ratio vs the rejected plan
    source: str                     # estimate provenance
    report: Any = None              # EstimateReport (in-process use)
    # host-offload knobs (ISSUE 8)
    offload_opt_state: bool = False
    offload_activations: float = 0.0
    space_peaks: dict | None = None     # per-space peak bytes
    # serving knobs (ISSUE 9) — the offered ServingKnobs as a dict plus
    # the ServingEstimate summary; None for training offers
    serving: dict | None = None

    @property
    def n_devices(self) -> int:
        return self.topology.n_devices if self.topology is not None else 1

    @property
    def headroom_bytes(self) -> int:
        return self.capacity - self.peak_bytes

    def offload_plan(self):
        """The :class:`~repro.core.orchestrator.OffloadPlan` this offer
        promises, or None for a device-only offer."""
        if not (self.offload_opt_state or self.offload_activations):
            return None
        from ..core.orchestrator import OffloadPlan
        return OffloadPlan(optimizer_state=self.offload_opt_state,
                           activations=float(self.offload_activations))

    def to_json(self) -> dict:
        d = {
            "knob": self.knob,
            "global_batch": self.global_batch,
            "microbatches": self.microbatches,
            "remat": self.remat,
            "topology": (self.topology.label
                         if self.topology is not None else None),
            "n_devices": self.n_devices,
            "pad_vocab_multiple": self.pad_vocab_multiple,
            "peak_bytes": self.peak_bytes,
            "safe_threshold": self.safe_threshold,
            "headroom_bytes": self.headroom_bytes,
            "slowdown": round(self.slowdown, 4),
            "device_s_per_token": self.cost["device_s_per_token"],
            "source": self.source,
            "offload_opt_state": self.offload_opt_state,
            "offload_activations": self.offload_activations,
        }
        if self.space_peaks:
            d["space_peaks"] = dict(self.space_peaks)
        if self.serving is not None:
            d["serving"] = dict(self.serving)
        return d

    def serving_knobs(self):
        """The :class:`~repro.core.orchestrator.ServingKnobs` this offer
        promises, or None for a training offer."""
        if self.serving is None:
            return None
        from ..core.orchestrator import ServingKnobs
        k = self.serving["knobs"]
        return ServingKnobs(page_size=k["page_size"],
                            max_concurrent=k["max_concurrent"],
                            kv_dtype_bytes=k["kv_dtype_bytes"],
                            prefix_cache=k["prefix_cache"],
                            speculative_k=k["speculative_k"])

    # -- reproduction --------------------------------------------------------
    def apply(self, cfg: ModelConfig, policy: TrainPolicy,
              shape: ShapeSpec) -> tuple[ModelConfig, TrainPolicy,
                                         ShapeSpec]:
        """The offered (cfg, policy, shape) — the rejected job's tuple
        with this offer's knobs applied."""
        if self.remat != cfg.remat:
            cfg = dataclasses.replace(cfg, remat=self.remat)
        if self.pad_vocab_multiple != cfg.pad_vocab_multiple \
                and self.pad_vocab_multiple is not None:
            cfg = dataclasses.replace(
                cfg, pad_vocab_multiple=self.pad_vocab_multiple)
        if self.microbatches != policy.microbatches:
            policy = dataclasses.replace(
                policy, microbatches=self.microbatches)
        if self.global_batch != shape.global_batch:
            shape = dataclasses.replace(
                shape, global_batch=self.global_batch)
        return cfg, policy, shape

    def admission_request(self, cfg: ModelConfig, policy: TrainPolicy,
                          shape: ShapeSpec, *, capacity: int | None = None,
                          job_id: str | None = None, shard_factor_fn=None,
                          collective_specs=()) -> AdmissionRequest:
        """The exact admission request this offer promises will fit —
        ``AdmissionService.decide`` on it reproduces ``peak_bytes``
        bit-identically (topology offers carry the same spec-driven
        shard factors and collective specs the mesh sweep used; pass
        ``shard_factor_fn``/``collective_specs`` when the original
        request pinned its own execution model)."""
        from ..configs.registry import input_specs
        from ..models import model as M
        cfg2, policy2, shape2 = self.apply(cfg, policy, shape)
        fwd, upd, init = make_estimator_hooks(cfg2, policy2)
        params = M.abstract_params(cfg2)
        batch = input_specs(cfg2, shape2)
        kw = _factor_kwargs(cfg2, params, batch, self.topology, init,
                            shard_factor_fn, collective_specs)
        return AdmissionRequest(
            job_id or f"{self.job_id}+offer", fwd, params, batch,
            update_fn=upd, opt_init_fn=init,
            capacity=self.capacity if capacity is None else capacity,
            offload=self.offload_plan(), **kw)


@dataclasses.dataclass
class PlanResult:
    """Ranked offers + the rejecting baseline + search accounting."""

    offers: list
    baseline: AdmissionDecision
    stats: dict

    def best(self) -> CounterOffer | None:
        return self.offers[0] if self.offers else None

    def __iter__(self):
        return iter(self.offers)

    def __len__(self):
        return len(self.offers)

    def to_json(self) -> dict:
        d = {
            "admit": self.baseline.admit,
            "peak_bytes": self.baseline.peak_bytes,
            "capacity": self.baseline.capacity,
            "counter_offers": [o.to_json() for o in self.offers],
            "stats": self.stats,
        }
        if self.baseline.correlation_id is not None:
            d["correlation_id"] = self.baseline.correlation_id
        return d


# ---------------------------------------------------------------------------
def _factor_kwargs(cfg, params, batch, topo: MeshTopology | None,
                   opt_init_fn, custom_factor_fn=None,
                   custom_collectives=(), opt_state=None) -> dict:
    """shard_factor_fn / collective_specs for a plan's mesh — built the
    way ``estimate_mesh_sweep`` builds them (spec mode, opt state from
    ``eval_shape``), so direct decisions reproduce sweep estimates.
    A caller-supplied factor fn / collective specs (the rejected
    request's own execution model) override the mesh derivation;
    ``opt_state`` short-circuits the per-candidate ``eval_shape`` (the
    optimizer shapes are batch-invariant)."""
    if custom_factor_fn is not None or custom_collectives:
        kw = {}
        if custom_factor_fn is not None:
            kw["shard_factor_fn"] = custom_factor_fn
        if custom_collectives:
            kw["collective_specs"] = tuple(custom_collectives)
        return kw
    if topo is None:
        return {}
    import jax
    from ..distributed.sharding import (mesh_collective_specs,
                                        shard_factor_fn)
    pol = topo.sharding_policy()
    if opt_state is None and opt_init_fn is not None:
        opt_state = jax.eval_shape(opt_init_fn, params)
    return {
        "shard_factor_fn": shard_factor_fn(
            cfg, topo.axis_sizes, pol, params=params,
            opt_state=opt_state, batch=batch),
        "collective_specs": mesh_collective_specs(topo.axis_sizes, pol),
    }


def _batch_candidates(space: PlanSpace, b0: int, m0: int) -> tuple:
    if space.batches is not None:
        return tuple(b for b in space.batches
                     if 0 < b < b0 and b % m0 == 0)
    out, b = [], b0 // 2
    for _ in range(space.batch_halvings):
        if b < max(m0, 1) or b % m0:
            break
        out.append(b)
        b //= 2
    return tuple(out)


def _mb_candidates(space: PlanSpace, b0: int, m0: int) -> tuple:
    if space.microbatches is not None:
        return tuple(m for m in space.microbatches
                     if m > m0 and b0 % m == 0)
    out, m = [], m0 * 2
    for _ in range(space.mb_doublings):
        if m > b0 or b0 % m:
            break
        out.append(m)
        m *= 2
    return tuple(out)


def _remat_candidates(space: PlanSpace, cfg: ModelConfig) -> tuple:
    cur = (_REMAT_ORDER.index(cfg.remat)
           if cfg.remat in _REMAT_ORDER else len(_REMAT_ORDER) - 1)
    if space.remat is not None:
        return tuple(r for r in space.remat
                     if r in _REMAT_ORDER and _REMAT_ORDER.index(r) > cur)
    # default: only the strongest rung — each rung is one fresh trace
    return ("full",) if cur < _REMAT_ORDER.index("full") else ()


def _offload_candidates(space: PlanSpace) -> tuple:
    """Offload ladder: optimizer state first (cheap, bounded transfer),
    then each activation fraction stacked on top of it."""
    from ..core.orchestrator import OffloadPlan
    out = []
    if space.offload_opt_state:
        out.append(OffloadPlan(optimizer_state=True))
    for f in space.offload_activations:
        f = float(f)
        if 0.0 < f <= 1.0:
            out.append(OffloadPlan(
                optimizer_state=space.offload_opt_state, activations=f))
    return tuple(out)


def _topologies(space: PlanSpace) -> tuple:
    if space.base_topology is not None or not space.devices:
        return ()
    return tuple(t for n in space.devices
                 for t in topology_grid(n, pods=space.pods))


# ---------------------------------------------------------------------------
class RemediationPlanner:
    """Search the plan space around a rejected admission request.

    Shares the service's content-addressed trace cache, its batched
    sweep path and its mesh-sweep path, so repeated planner runs (and a
    planner run right after the rejection that triggered it) stay warm.
    """

    def __init__(self, service: AdmissionService | None = None):
        self.service = service or AdmissionService(workers=1)

    # -- request plumbing ----------------------------------------------------
    def _request(self, job_id, fwd, params, batch, upd, init, capacity,
                 factor_kwargs) -> AdmissionRequest:
        return AdmissionRequest(job_id, fwd, params, batch,
                                update_fn=upd, opt_init_fn=init,
                                capacity=capacity, **factor_kwargs)

    # -- the search ----------------------------------------------------------
    def plan(self, cfg: ModelConfig, policy: TrainPolicy,
             shape: ShapeSpec, *, capacity: int,
             space: PlanSpace | None = None, job_id: str = "job",
             baseline: AdmissionDecision | None = None,
             shard_factor_fn=None, collective_specs=()) -> PlanResult:
        """Ranked counter-offers for (cfg, policy, shape) at ``capacity``.

        ``baseline`` short-circuits the initial decision when the caller
        already holds the rejection (the ``AdmissionService.decide``
        wiring); ``shard_factor_fn`` / ``collective_specs`` pin the
        rejected request's own execution model on every candidate — the
        mesh axes (``devices`` / ``pad_vocab_multiple``) are disabled in
        that case, since a topology offer under a foreign execution
        model would quote a peak for the wrong sharding.
        ``stats["fresh_traces"]``
        counts trace-cache misses of the search itself (the baseline
        decision, when the planner has to make it, is accounted
        separately as ``baseline_traces``).
        """
        # ISSUE 10: capture the rejecting decision's correlation ID
        # NOW — candidate probe decides below re-activate their own
        # scoped contexts — so the plan audit record chains to the
        # rejection it remediates
        cid = obs_spans.current_correlation_id()
        with obs_spans.span("planner.plan", job_id=job_id):
            result = self._plan_search(
                cfg, policy, shape, capacity=capacity, space=space,
                job_id=job_id, baseline=baseline,
                shard_factor_fn=shard_factor_fn,
                collective_specs=collective_specs)
        self._audit_plan("training", job_id, cid, result)
        return result

    def _audit_plan(self, mode: str, job_id: str, cid: str | None,
                    result: "PlanResult") -> None:
        """One audit record per planner search (kind="plan")."""
        obs = getattr(self.service, "obs", None)
        if obs is None or obs.audit is None:
            return
        obs.record(
            "plan", correlation_id=cid, mode=mode, job_id=job_id,
            offers=[{"knob": o.knob, "global_batch": o.global_batch,
                     "peak_bytes": o.peak_bytes,
                     "slowdown": o.slowdown}
                    for o in result.offers[:5]],
            stats={k: result.stats.get(k) for k in
                   ("candidates", "feasible", "offers",
                    "fresh_traces", "already_fits")})

    def _plan_search(self, cfg: ModelConfig, policy: TrainPolicy,
                     shape: ShapeSpec, *, capacity: int,
                     space: PlanSpace | None = None,
                     job_id: str = "job",
                     baseline: AdmissionDecision | None = None,
                     shard_factor_fn=None,
                     collective_specs=()) -> PlanResult:
        from ..configs.registry import input_specs
        from ..models import model as M
        space = space or PlanSpace()
        svc = self.service
        cache = svc.cache
        t0 = time.perf_counter()
        b0, m0 = shape.global_batch, max(policy.microbatches, 1)
        base_topo = space.base_topology
        fwd, upd, init = make_estimator_hooks(cfg, policy)
        params = M.abstract_params(cfg)
        batch0 = input_specs(cfg, shape)
        # optimizer shapes are batch-invariant: resolve once for every
        # candidate's spec factors instead of per-request
        opt_state0 = None
        if base_topo is not None and shard_factor_fn is None \
                and init is not None:
            import jax
            opt_state0 = jax.eval_shape(init, params)

        def factor_kw(c, b):
            return _factor_kwargs(c, params, b, base_topo, init,
                                  shard_factor_fn, collective_specs,
                                  opt_state=opt_state0)

        base_kw = factor_kw(cfg, batch0)
        before = cache.thread_stats()
        if baseline is None:
            baseline = svc.decide(self._request(
                f"{job_id}/baseline", fwd, params, batch0, upd, init,
                capacity, base_kw))
        baseline_traces = cache.thread_stats()["misses"] \
            - before["misses"]

        stats = {"capacity": capacity, "candidates": 0, "feasible": 0,
                 "axes": {}, "baseline_traces": baseline_traces,
                 "already_fits": bool(baseline.admit)}
        if baseline.admit:
            stats.update(fresh_traces=0, offers=0,
                         wall_s=time.perf_counter() - t0)
            return PlanResult([], baseline, stats)

        before = cache.thread_stats()
        base_cost = plan_cost(cfg, shape, microbatches=m0,
                              topology=base_topo)
        offers: list[CounterOffer] = []

        def add(knob, peak, source, report, *, gb=b0, mb=m0, topo=base_topo,
                cfg2=None, pad=None, offload=None):
            stats["candidates"] += 1
            if peak > capacity:
                return
            stats["feasible"] += 1
            c2 = cfg2 if cfg2 is not None else cfg
            shape2 = (dataclasses.replace(shape, global_batch=gb)
                      if gb != shape.global_batch else shape)
            transfer = 0
            space_peaks = None
            if report is not None:
                bd = getattr(report, "breakdown", None) or {}
                transfer = bd.get("offload", {}).get(
                    "transfer_bytes_per_iter", 0)
                space_peaks = bd.get("space_peaks")
            cost = plan_cost(c2, shape2, microbatches=mb, topology=topo,
                             offload_transfer_bytes=transfer)
            offers.append(CounterOffer(
                job_id=job_id, knob=knob, global_batch=gb,
                microbatches=mb, remat=c2.remat, topology=topo,
                pad_vocab_multiple=pad if pad is not None
                else c2.pad_vocab_multiple,
                capacity=capacity, peak_bytes=peak, safe_threshold=peak,
                cost=cost,
                slowdown=(cost["device_s_per_token"]
                          / max(base_cost["device_s_per_token"], 1e-30)),
                source=source, report=report,
                offload_opt_state=(offload.optimizer_state
                                   if offload is not None else False),
                offload_activations=(offload.activations
                                     if offload is not None else 0.0),
                space_peaks=space_peaks))

        # --- topology axis: trace-free replays of the cached phases ----
        # a caller-pinned execution model (custom factors / collectives)
        # describes the job's CURRENT placement; the planner cannot
        # reason about how it composes with a different mesh, so the
        # mesh axes are disabled rather than answered under the wrong
        # model (enforces the documented mutual exclusivity)
        custom_model = shard_factor_fn is not None \
            or bool(collective_specs)
        topos = () if custom_model else _topologies(space)
        if topos:
            res = svc.mesh_sweep(fwd, params, batch0, topos,
                                 update_fn=upd, opt_init_fn=init, cfg=cfg)
            for topo, rep in res:
                add("topology", rep.peak_bytes, "mesh-sweep", rep,
                    topo=topo)
            stats["axes"]["topology"] = len(topos)

        # --- padded-vocab mesh variants (only useful with model>1) -----
        if (space.pad_vocab_multiple and not custom_model
                and cfg.pad_vocab_multiple is None
                and cfg.vocab % space.pad_vocab_multiple):
            mp = tuple(t for t in topos if t.model > 1)
            if mp:
                cfgp = dataclasses.replace(
                    cfg, pad_vocab_multiple=space.pad_vocab_multiple)
                fwdp, updp, initp = make_estimator_hooks(cfgp, policy)
                paramsp = M.abstract_params(cfgp)
                batchp = input_specs(cfgp, shape)
                resp = svc.mesh_sweep(fwdp, paramsp, batchp, mp,
                                      update_fn=updp, opt_init_fn=initp,
                                      cfg=cfgp)
                for topo, rep in resp:
                    add("pad_vocab", rep.peak_bytes, "mesh-sweep", rep,
                        topo=topo, cfg2=cfgp,
                        pad=space.pad_vocab_multiple)
                stats["axes"]["pad_vocab"] = len(mp)

        # --- batch axis: interpolated sweep, rejected batch as warm
        # max-probe anchor (excluded from the offers) -------------------
        batches = _batch_candidates(space, b0, m0)
        if batches:
            grid = (b0,) + batches
            reqs = []
            for b in grid:
                shape_b = dataclasses.replace(shape, global_batch=b)
                batch_b = input_specs(cfg, shape_b)
                reqs.append(self._request(
                    f"{job_id}/b{b}", fwd, params, batch_b, upd, init,
                    capacity, factor_kw(cfg, batch_b)))
            decisions = svc.decide_sweep(reqs)
            for b, d in zip(grid, decisions):
                if b == b0:
                    continue
                add("batch", d.peak_bytes, d.provenance["source"],
                    d.report, gb=b)
            stats["axes"]["batch"] = len(batches)
            stats["sweep"] = decisions[0].provenance.get("sweep", {})

        # --- microbatch / remat singles: each changes the traced
        # program, so each candidate is one fresh forward trace ---------
        singles: list[tuple] = []
        for m in _mb_candidates(space, b0, m0):
            singles.append(("microbatch", cfg,
                            dataclasses.replace(policy, microbatches=m),
                            {"mb": m}))
        for r in _remat_candidates(space, cfg):
            singles.append(("remat", dataclasses.replace(cfg, remat=r),
                            policy, {}))
        stats["axes"]["microbatch"] = sum(
            1 for s in singles if s[0] == "microbatch")
        stats["axes"]["remat"] = sum(1 for s in singles
                                     if s[0] == "remat")
        singles.sort(key=lambda s: plan_cost(
            s[1], shape, microbatches=s[3].get("mb", m0),
            topology=base_topo)["device_s_per_token"])
        for knob, cfg2, pol2, meta in singles:
            if space.early_stop and offers:
                break
            f2, u2, i2 = make_estimator_hooks(cfg2, pol2)
            d = svc.decide(self._request(
                f"{job_id}/{knob}{meta.get('mb', cfg2.remat)}", f2,
                params, batch0, u2, i2, capacity, factor_kw(cfg2, batch0)))
            add(knob, d.peak_bytes, d.provenance["source"], d.report,
                mb=meta.get("mb", m0), cfg2=cfg2)

        # --- offload axis: the traced program is offload-independent —
        # only the orchestrator pass and replay differ, so every
        # candidate replays from the baseline's warm traces (zero fresh
        # traces; bench-asserted) --------------------------------------
        offload_plans = _offload_candidates(space)
        stats["axes"]["offload"] = len(offload_plans)
        for op in offload_plans:
            if space.early_stop and offers:
                break
            tag = (f"opt{int(op.optimizer_state)}"
                   f"-act{op.activations:g}")
            d = svc.decide(AdmissionRequest(
                f"{job_id}/offload-{tag}", fwd, params, batch0,
                update_fn=upd, opt_init_fn=init, capacity=capacity,
                offload=op, **base_kw))
            add("offload", d.peak_bytes, d.provenance["source"],
                d.report, offload=op)

        after = cache.thread_stats()
        offers.sort(key=lambda o: (o.cost["device_s_per_token"],
                                   o.n_devices, o.peak_bytes,
                                   o.knob, o.global_batch))
        offers = offers[:max(space.max_offers, 0)]
        stats.update(offers=len(offers),
                     fresh_traces=after["misses"] - before["misses"],
                     wall_s=time.perf_counter() - t0)
        return PlanResult(offers, baseline, stats)

    # -- the serving search (ISSUE 9) ----------------------------------------
    def plan_serving(self, ctx: ServingPlanContext, *, capacity: int,
                     job_id: str = "serve",
                     baseline: AdmissionDecision | None = None
                     ) -> PlanResult:
        """Ranked serving counter-offers for a rejected request mix.

        Every candidate only re-lowers the CPU request stream and
        replays — the decode trace is shared across the whole page-size
        x concurrency x KV-dtype x prefix-cache grid, so the search
        costs at most the baseline's one fresh trace
        (``stats["fresh_traces"]``, bench-asserted against
        ``SERVING_TRACE_BUDGET``). Offers are ranked by the serving
        roofline (``plan/cost.py:serving_cost``) so the first offer is
        the cheapest modeled device-time per generated token, and each
        reproduces bit-identically via a direct ``decide_serving`` with
        ``CounterOffer.serving_knobs()``."""
        cid = obs_spans.current_correlation_id()
        with obs_spans.span("planner.plan_serving", job_id=job_id):
            result = self._plan_serving_search(
                ctx, capacity=capacity, job_id=job_id,
                baseline=baseline)
        self._audit_plan("serving", job_id, cid, result)
        return result

    def _plan_serving_search(self, ctx: ServingPlanContext, *,
                             capacity: int, job_id: str = "serve",
                             baseline: AdmissionDecision | None = None
                             ) -> PlanResult:
        from ..core.orchestrator import ServingKnobs
        from .cost import serving_cost
        svc = self.service
        cache = svc.cache
        t0 = time.perf_counter()
        space = ctx.space or PlanSpace()
        base_knobs = ctx.knobs or ServingKnobs()

        def decide(tag, knobs):
            return svc.decide_serving(
                f"{job_id}/{tag}", ctx.decode_fn, ctx.params, ctx.cache,
                ctx.batch, capacity=capacity, mix=ctx.mix, knobs=knobs,
                kv_bytes_per_token=ctx.kv_bytes_per_token,
                resident_bytes_per_request=ctx.resident_bytes_per_request)

        before = cache.thread_stats()
        if baseline is None:
            baseline = decide("baseline", base_knobs)
        baseline_traces = cache.thread_stats()["misses"] \
            - before["misses"]
        avg_seq, shared_prefix = _mix_profile(ctx.mix)
        stats = {"capacity": capacity, "candidates": 0, "feasible": 0,
                 "axes": {}, "baseline_traces": baseline_traces,
                 "already_fits": bool(baseline.admit)}
        if baseline.admit:
            stats.update(fresh_traces=0, offers=0,
                         wall_s=time.perf_counter() - t0)
            return PlanResult([], baseline, stats)

        before = cache.thread_stats()
        grid = _serving_grid(space, base_knobs)
        stats["axes"]["serving"] = len(grid)
        params_bytes = baseline.persistent_bytes
        base_cost = serving_cost(
            params_bytes=params_bytes,
            kv_bytes_per_token=ctx.kv_bytes_per_token, knobs=base_knobs,
            avg_seq_len=avg_seq, shared_prefix_len=shared_prefix)
        offers: list[CounterOffer] = []
        for knobs in grid:
            stats["candidates"] += 1
            tag = (f"pg{knobs.page_size}-c{knobs.max_concurrent}"
                   f"-kv{knobs.kv_dtype_bytes}"
                   f"-px{int(knobs.prefix_cache)}")
            d = decide(tag, knobs)
            if not d.admit or d.degraded:
                continue
            stats["feasible"] += 1
            cost = serving_cost(
                params_bytes=params_bytes,
                kv_bytes_per_token=ctx.kv_bytes_per_token, knobs=knobs,
                avg_seq_len=avg_seq, shared_prefix_len=shared_prefix)
            serving = dict(d.breakdown.get("serving", {}))
            serving["knobs"] = dataclasses.asdict(knobs)
            offers.append(CounterOffer(
                job_id=job_id, knob="serving",
                global_batch=knobs.max_concurrent, microbatches=1,
                remat="none", topology=None, pad_vocab_multiple=None,
                capacity=capacity, peak_bytes=d.peak_bytes,
                safe_threshold=d.safe_threshold, cost=cost,
                slowdown=(cost["device_s_per_token"]
                          / max(base_cost["device_s_per_token"], 1e-30)),
                source=d.provenance["source"], report=d.report,
                serving=serving))
        after = cache.thread_stats()
        offers.sort(key=lambda o: (o.cost["device_s_per_token"],
                                   o.peak_bytes, o.global_batch))
        offers = offers[:max(space.max_offers, 0)]
        stats.update(offers=len(offers),
                     fresh_traces=after["misses"] - before["misses"],
                     wall_s=time.perf_counter() - t0)
        return PlanResult(offers, baseline, stats)


# ---------------------------------------------------------------------------
def _serving_grid(space: PlanSpace, base) -> list:
    """The ServingKnobs candidates of a plan space — full product over
    the enabled axes (base value where an axis is empty), base point
    excluded (it is the rejected plan)."""
    import itertools
    pages = space.page_sizes or (base.page_size,)
    concs = space.max_concurrents or (base.max_concurrent,)
    dtypes = space.kv_dtypes or (base.kv_dtype_bytes,)
    prefixes = space.prefix_cache or (base.prefix_cache,)
    out = []
    for p, c, d, x in itertools.product(pages, concs, dtypes, prefixes):
        k = dataclasses.replace(base, page_size=p, max_concurrent=c,
                                kv_dtype_bytes=d, prefix_cache=x)
        if k != base:
            out.append(k)
    return out


def _mix_profile(mix) -> tuple[float, int]:
    """(average total sequence length, shared prefix tokens) of a
    RequestMix or a concrete RequestStream — the serving cost model's
    traffic inputs."""
    buckets = getattr(mix, "buckets", None)
    if buckets is not None:
        total = sum(c for _p, _d, c in buckets)
        avg = (sum((p + d) * c for p, d, c in buckets)
               / max(total, 1))
        return avg, int(getattr(mix, "shared_prefix_len", 0))
    reqs = getattr(mix, "requests", ())
    if reqs:
        avg = sum(r.prompt_len + r.decode_len for r in reqs) / len(reqs)
        shared = min(r.shared_prefix_len for r in reqs)
        return avg, int(shared)
    return 1.0, 0


# ---------------------------------------------------------------------------
def run_plan_search(arch: str, hbm_bytes: int, *, seq: int = 48,
                    batch: int = 32, microbatches: int = 1,
                    remat: str | None = None,
                    devices: tuple = (4, 8, 16), smoke: bool = True,
                    offload: bool = True,
                    space: PlanSpace | None = None,
                    service: AdmissionService | None = None,
                    verbose: bool = True) -> dict:
    """CLI/bench entry: plan a smoke-scale training job of ``arch`` that
    does not fit ``hbm_bytes`` and print/return the ranked offers —
    shared by ``hillclimb --xmem-plan`` and ``dryrun --xmem-plan``.
    ``offload`` adds the host-offload axes (optimizer state + half the
    activations) to the default plan space; offload offers print their
    per-space peaks."""
    from ..configs import get_config, get_smoke
    from ..configs.base import smoke_shape
    cfg = get_smoke(arch) if smoke else get_config(arch)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    policy = TrainPolicy(optimizer="adamw",
                         microbatches=max(int(microbatches), 1))
    shape = smoke_shape(seq_len=seq, global_batch=batch)
    if space is None:
        space = PlanSpace(devices=tuple(devices),
                          offload_opt_state=bool(offload),
                          offload_activations=(0.5,) if offload else ())
    planner = RemediationPlanner(service)
    res = planner.plan(cfg, policy, shape, capacity=hbm_bytes,
                       job_id=f"{cfg.name}-plan", space=space)
    record = {"arch": cfg.name, "kind": "xmem_plan",
              "hbm_bytes": hbm_bytes, "seq": seq, "batch": batch,
              "microbatches": policy.microbatches, "remat": cfg.remat,
              **res.to_json()}
    if verbose:
        if res.baseline.admit:
            print(f"[xmem-plan] {cfg.name}: already fits "
                  f"({res.baseline.peak_bytes/2**20:.2f} MiB <= "
                  f"{hbm_bytes/2**20:.2f} MiB) — nothing to remediate",
                  flush=True)
        else:
            print(f"[xmem-plan] {cfg.name}: rejected at "
                  f"{res.baseline.peak_bytes/2**20:.2f} MiB vs "
                  f"{hbm_bytes/2**20:.2f} MiB — "
                  f"{res.stats['candidates']} candidates, "
                  f"{res.stats['feasible']} feasible, "
                  f"{res.stats['fresh_traces']} fresh traces, "
                  f"{res.stats['wall_s']*1e3:.0f} ms", flush=True)
            for i, o in enumerate(res.offers):
                topo = o.topology.label if o.topology else "1dev"
                line = (f"[xmem-plan]   #{i+1} {o.knob:10s} "
                        f"b={o.global_batch:<4d} mb={o.microbatches:<3d} "
                        f"remat={o.remat:5s} {topo:12s} "
                        f"peak={o.peak_bytes/2**20:7.2f} MiB "
                        f"slowdown=x{o.slowdown:.2f}")
                if o.space_peaks:
                    line += "  spaces[" + " ".join(
                        f"{k}={v/2**20:.2f}MiB"
                        for k, v in sorted(o.space_peaks.items())) + "]"
                print(line, flush=True)
    return record
