"""Remediation-planner subsystem (ISSUE 5).

xMem's estimates are only as valuable as what a scheduler can *do* with
them. Before this package, ``AdmissionService.decide`` answered a job
that does not fit with a bare rejection even though every knob needed
to make it fit already existed in the codebase (microbatches, remat,
batch size, mesh topology, vocab padding). The planner closes that
loop: given a rejected request and a capacity it searches the plan
space — trace-frugally, on CPU — and returns ranked
:class:`CounterOffer`\\ s, each carrying its per-device peak estimate,
its safe threshold (Eq. 5) and a throughput cost from the analytic
roofline terms, so "cheapest feasible" means lowest modeled slowdown.

Entry points:

* :class:`RemediationPlanner` — the search engine (shares the admission
  service's trace cache / sweep paths);
* :class:`PlanContext` — attach to ``AdmissionRequest.meta["plan"]``
  and rejections come back with ``counter_offers`` populated;
* ``CounterOffer.admission_request`` — rebuilds the exact request an
  offer promises will fit (decisions reproduce bit-identically);
* :func:`run_plan_search` — the ``--xmem-plan`` CLI / bench entry.
"""
from ..core.orchestrator import OffloadPlan  # noqa: F401
from .cost import plan_cost, serving_cost  # noqa: F401
from .planner import (CounterOffer, PlanContext, PlanResult,  # noqa: F401
                      PlanSpace, RemediationPlanner, ServingPlanContext,
                      run_plan_search)

__all__ = ["CounterOffer", "OffloadPlan", "PlanContext", "PlanResult",
           "PlanSpace", "RemediationPlanner", "ServingPlanContext",
           "plan_cost", "run_plan_search", "serving_cost"]
