"""StarCoder2-3B — GQA kv=2, RoPE. [arXiv:2402.19173; hf]"""
from .base import AttentionConfig, ModelConfig

FULL = ModelConfig(
    name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
    n_heads=24, n_kv_heads=2, d_ff=12288, vocab=49152, head_dim=128,
    attention=AttentionConfig(),
)

SMOKE = ModelConfig(
    name="starcoder2-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
)
