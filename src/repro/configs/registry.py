"""Architecture registry: ``--arch <id>`` resolution + input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of a given (architecture x shape) cell — weak-type-correct,
shardable, zero allocation — the dry-run contract. Modality frontends
are stubs: the VLM receives precomputed patch embeddings, the audio
model receives EnCodec token codes.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from .base import ModelConfig, ShapeSpec

_MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b_a66b",
    "qwen3-32b": "qwen3_32b",
    "phi4-mini-3.8b": "phi4_mini_38b",
    "starcoder2-3b": "starcoder2_3b",
    "gemma3-4b": "gemma3_4b",
    "jamba-1.5-large-398b": "jamba_15_large_398b",
    "internvl2-1b": "internvl2_1b",
    "xlstm-1.3b": "xlstm_13b",
    "musicgen-medium": "musicgen_medium",
}
ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f".{_MODULES[arch_id]}", __package__)


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).FULL


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def input_specs(cfg: ModelConfig, shape: ShapeSpec, *,
                batch_override: int | None = None) -> dict:
    """Training/prefill batch as ShapeDtypeStructs (no allocation)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)  # noqa: E731
    if cfg.family == "vlm":
        P = cfg.num_patches
        S_text = max(S - P, 8)
        return {
            "patch_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                 cfg.dtype),
            "tokens": tok(B, S_text),
            "labels": tok(B, S_text),
        }
    if cfg.family == "audio":
        return {"codes": tok(B, S, cfg.num_codebooks),
                "labels": tok(B, S, cfg.num_codebooks)}
    return {"tokens": tok(B, S), "labels": tok(B, S)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec, *,
                       batch_override: int | None = None) -> dict:
    """Single-token decode batch (serve_step input)."""
    B = batch_override or shape.global_batch
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)  # noqa: E731
    if cfg.family == "audio":
        return {"codes": tok(B, 1, cfg.num_codebooks)}
    return {"tokens": tok(B, 1)}


def abstract_cache(cfg: ModelConfig, shape: ShapeSpec, *,
                   batch_override: int | None = None):
    """Decode cache ShapeDtypeStructs for a given context length."""
    from ..models import model as M
    B = batch_override or shape.global_batch
    return jax.eval_shape(lambda: M.init_cache(cfg, B, shape.seq_len))
