"""Phi-4-mini 3.8B — dense RoPE SwiGLU GQA. [arXiv:2412.08905; hf]"""
from .base import AttentionConfig, ModelConfig

FULL = ModelConfig(
    name="phi4-mini-3.8b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv_heads=8, d_ff=8192, vocab=200064, head_dim=128,
    attention=AttentionConfig(),
)

SMOKE = ModelConfig(
    name="phi4-mini-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
)
