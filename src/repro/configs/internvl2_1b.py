"""InternVL2-1B — InternViT (stub frontend) + 0.5B LM backbone.
[arXiv:2404.16821; hf] Frontend is a STUB: input_specs() provides
precomputed patch embeddings (assignment requirement)."""
from .base import AttentionConfig, ModelConfig

FULL = ModelConfig(
    name="internvl2-1b", family="vlm", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, d_ff=4864, vocab=151655, head_dim=64,
    num_patches=256,
    attention=AttentionConfig(),
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, num_patches=8,
)
