"""Qwen3-32B — dense, qk_norm, GQA. [hf:Qwen/Qwen3-8B family; hf]"""
from .base import AttentionConfig, ModelConfig

FULL = ModelConfig(
    name="qwen3-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=64, n_kv_heads=8, d_ff=25600, vocab=151936, head_dim=128,
    attention=AttentionConfig(qk_norm=True, rope_theta=1_000_000.0),
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    attention=AttentionConfig(qk_norm=True),
)
