"""Phi-3.5-MoE — 16 experts top-2 (42B total / 6.6B active).
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from .base import AttentionConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=6400, vocab=32064,
    moe=MoEConfig(num_experts=16, top_k=2, capacity_factor=1.25),
    attention=AttentionConfig(),
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=64, vocab=256,
    moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=1.5),
)
