"""Gemma-3 4B — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]"""
from .base import AttentionConfig, ModelConfig

FULL = ModelConfig(
    name="gemma3-4b", family="dense", n_layers=34, d_model=2560,
    n_heads=8, n_kv_heads=4, d_ff=10240, vocab=262144, head_dim=256,
    attention=AttentionConfig(sliding_window=1024, global_every=6,
                              rope_theta=1_000_000.0),
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    attention=AttentionConfig(sliding_window=8, global_every=2),
)
