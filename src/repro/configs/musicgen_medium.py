"""MusicGen-medium — decoder-only over EnCodec tokens (4 codebooks).
[arXiv:2306.05284; hf] EnCodec frontend is a STUB: input_specs()
provides token codes directly (assignment requirement)."""
from .base import AttentionConfig, ModelConfig

FULL = ModelConfig(
    name="musicgen-medium", family="audio", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, d_ff=6144, vocab=2048, num_codebooks=4,
    attention=AttentionConfig(),
)

SMOKE = ModelConfig(
    name="musicgen-smoke", family="audio", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=64, num_codebooks=4,
)
