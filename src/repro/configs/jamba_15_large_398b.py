"""Jamba-1.5-Large 398B — Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]"""
from .base import AttentionConfig, MambaConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72,
    d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576, vocab=65536,
    head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=2, every_n_layers=2,
                  capacity_factor=1.25),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=256),
    attention=AttentionConfig(attn_every=8),
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid", n_layers=8, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    moe=MoEConfig(num_experts=4, top_k=2, every_n_layers=2,
                  capacity_factor=1.5),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2, chunk=16),
    attention=AttentionConfig(attn_every=4),
)
