"""Architecture configs: one module per assigned architecture.

Each module exports FULL (the published config, exact) and SMOKE (a
reduced same-family config for CPU tests). ``registry`` maps ids.
"""
from . import base
from .registry import ARCH_IDS, get_config, get_smoke, input_specs

__all__ = ["base", "ARCH_IDS", "get_config", "get_smoke", "input_specs"]
