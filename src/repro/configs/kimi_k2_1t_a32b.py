"""Kimi K2 — trillion-parameter MoE (61L, 384 experts top-8).
[arXiv:2501.kimi2; unverified]"""
from .base import AttentionConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, d_ff=2048, vocab=163840,
    moe=MoEConfig(num_experts=384, top_k=8, capacity_factor=1.25),
    attention=AttentionConfig(),
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=32, vocab=256,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.5),
)
