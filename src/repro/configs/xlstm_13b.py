"""xLSTM-1.3B — sLSTM + mLSTM blocks (recurrent, no KV cache).
[arXiv:2405.04517; unverified]"""
from .base import ModelConfig, XLSTMConfig

FULL = ModelConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
    xlstm=XLSTMConfig(slstm_every=8, chunk=256, qk_dim_factor=0.5),
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm", n_layers=8, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=256,
    xlstm=XLSTMConfig(slstm_every=4, chunk=16),
)
