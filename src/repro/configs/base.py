"""Model/shape configuration schema shared by the whole framework.

One ``ModelConfig`` describes any architecture in the assigned pool
(dense / MoE / hybrid Mamba / xLSTM / VLM / audio). Each
``src/repro/configs/<arch>.py`` exports ``FULL`` (the exact published
config) and ``SMOKE`` (a reduced same-family config for CPU tests), plus
the standard shape grid.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

import jax.numpy as jnp

Family = Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    every_n_layers: int = 1      # 1 = every layer is MoE; 2 = alternate
    num_groups: int = 1          # dispatch groups (launcher sets = dp size)


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2              # d_inner = expand * d_model
    chunk: int = 256             # chunked-scan block size


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8         # 1 sLSTM per N blocks (rest mLSTM)
    chunk: int = 256
    qk_dim_factor: float = 0.5   # mLSTM k/q head dim = factor * v dim


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    sliding_window: int | None = None    # window size for local layers
    global_every: int | None = None      # 1 global layer per N (gemma3: 6)
    attn_every: int | None = None        # hybrid: 1 attn layer per N (jamba: 8)
    qk_norm: bool = False                # qwen3
    rope_theta: float = 10_000.0
    # §Perf knob: broadcast KV heads to full H inside attention so the
    # head dim shards cleanly on a TP axis that Hkv (2-8) doesn't divide;
    # costs a small per-chunk KV repeat, removes inner-loop collectives.
    repeat_kv_for_tp: bool = False
    chunk_q: int = 512                   # chunked-attention block sizes
    chunk_kv: int = 1024
    # use plain softmax below this S; above it the O(S^2) score tensor
    # (e.g. 68 GB/device for qwen3 at 4k) forces the chunked path
    dense_threshold: int = 2048
    impl: str = "auto"                   # auto|dense|chunked|pallas


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    xlstm: XLSTMConfig | None = None
    attention: AttentionConfig = AttentionConfig()
    # modality frontends are STUBS: input_specs() yields precomputed
    # patch/frame embeddings (assignment requirement)
    num_patches: int = 0                 # vlm: image patch embeddings
    num_codebooks: int = 0               # audio: EnCodec codebooks
    tie_embeddings: bool = False
    # §Perf knob: pad embedding/head vocab up to a multiple (e.g. 16) so
    # the vocab dim shards on the model axis; non-divisible vocabs
    # (internvl2's 151655) otherwise replicate the [B,S,V] logits.
    pad_vocab_multiple: int | None = None
    param_dtype: str = "bfloat16"
    # training-time policies (overridable by the launcher)
    remat: str = "full"                  # full|dots|none
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        if self.pad_vocab_multiple:
            m = self.pad_vocab_multiple
            return ((self.vocab + m - 1) // m) * m
        return self.vocab

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    # ---- parameter counting (roofline MODEL_FLOPS = 6*N*D) -------------
    def _layer_param_counts(self) -> tuple[int, int]:
        """(total_per_layer_avg, active_per_layer_avg) over the stack."""
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) * 2 \
            + d * (self.n_kv_heads * hd) * 2          # q,o + k,v
        dense_ffn = 3 * d * self.d_ff                 # swiglu
        total = active = 0.0
        for i in range(self.n_layers):
            is_attn_layer = True
            if self.family == "hybrid" and self.attention.attn_every:
                is_attn_layer = (i % self.attention.attn_every == 0)
            if self.family == "ssm":
                x = self.xlstm or XLSTMConfig()
                d_in = d  # mLSTM internal projections ~4*d*d
                total += 4 * d * d_in + 2 * d_in * d
                active += 4 * d * d_in + 2 * d_in * d
                continue
            mix = attn
            if self.family == "hybrid" and not is_attn_layer:
                m = self.mamba or MambaConfig()
                d_inner = m.expand * d
                mix = 2 * d * d_inner + d_inner * d + d_inner * m.d_state * 2
            total += mix
            active += mix
            is_moe = (self.moe is not None
                      and i % self.moe.every_n_layers
                      == (self.moe.every_n_layers - 1))
            if is_moe:
                total += self.moe.num_experts * 3 * d * self.d_ff \
                    + d * self.moe.num_experts
                active += self.moe.top_k * 3 * d * self.d_ff \
                    + d * self.moe.num_experts
            elif self.d_ff:
                total += dense_ffn
                active += dense_ffn
            total += 2 * d  # norms
            active += 2 * d
        return int(total), int(active)

    def param_count(self) -> int:
        layers, _ = self._layer_param_counts()
        emb = self.vocab * self.d_model
        head = 0 if self.tie_embeddings else self.vocab * self.d_model
        if self.num_codebooks:
            emb = self.num_codebooks * self.vocab * self.d_model
            head = self.num_codebooks * self.vocab * self.d_model
        return layers + emb + head + self.d_model

    def active_param_count(self) -> int:
        _, active = self._layer_param_counts()
        emb = self.vocab * self.d_model
        head = 0 if self.tie_embeddings else self.vocab * self.d_model
        if self.num_codebooks:
            emb = self.num_codebooks * self.vocab * self.d_model
            head = self.num_codebooks * self.vocab * self.d_model
        return active + emb + head + self.d_model


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape (seq_len x global_batch + step kind)."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The assigned LM shape grid (identical for all 10 archs).
TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def smoke_shape(seq_len: int = 64, global_batch: int = 2,
                kind: str = "train") -> ShapeSpec:
    return ShapeSpec("smoke", seq_len, global_batch, kind)


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6 * N(active) * D  (training); 2*N*D for inference."""
    n = cfg.active_param_count()
    d = shape.tokens if shape.kind == "train" else (
        shape.tokens if shape.kind == "prefill" else shape.global_batch)
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n * d


def supports_long_context(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic families (DESIGN.md §4)."""
    return cfg.family in ("hybrid", "ssm")
