"""Admission service: request/response estimation over a worker pool.

The scheduler-facing API of the estimator (ISSUE 4 tentpole). An
:class:`AdmissionRequest` names one training (or serving) job by the
exact callables the runtime would execute; the service answers with an
:class:`AdmissionDecision` carrying the estimate, the safe threshold
(Eq. 5: the estimate usable as max-runnable-memory), the per-device
breakdown and cache provenance (memory-warm / disk-warm / traced).

Estimates are produced by the same ``XMemEstimator`` pipeline as the
one-shot CLIs — the equivalence test pins the service bit-identical to
direct calls. What the service adds:

* a shared thread-safe :class:`~repro.core.cache.TraceCache`, optionally
  layered over a persistent :class:`~repro.service.store.TraceStore`
  (content-addressed keys, so re-created but structurally identical
  step functions are warm — across decisions AND process restarts);
* concurrent serving: ``submit`` fans decisions out over a thread pool,
  one estimator per worker thread (the orchestrator mutates per-call
  policy state, so estimator instances are not shared across threads;
  the trace cache is);
* batched decisions: ``decide_sweep`` routes a family of requests that
  differ in one scalar (the batch-size admission sweep) through
  ``SweepService.estimate_many`` — probe traces + affine interpolation
  + vectorized replay instead of N full estimates;
* **robustness (ISSUE 6)**: a graceful-degradation ladder (exact
  replay -> cached/interpolated sweep point -> analytic upper bound,
  each degraded rung with a widened safety margin — see
  :mod:`repro.service.degrade`), per-request deadline budgets with
  capped-backoff retries on transient failures, and fault injection
  via :mod:`repro.service.faults`. A rung failure (tracer raise, store
  corruption, timeout) falls to the next rung instead of propagating:
  the service answers 100% of requests, and every decision reports
  which rung answered and the margin applied.

The fault-free, deadline-free path runs the exact rung inline with no
extra threads — bit-identical decisions and throughput within the
existing bench gate.
"""
from __future__ import annotations

import contextlib
import contextvars
import copy
import dataclasses
import math
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Sequence

from ..core.cache import GLOBAL_TRACE_CACHE, TraceCache
from ..core.estimator import EstimateReport, XMemEstimator
from ..core.sweep import SweepPoint, SweepService
from ..obs import CounterDict, Observability
from ..obs import spans as obs_spans
from .degrade import (RUNG_ANALYTIC, RUNG_EXACT, RUNG_SWEEP, DecisionLog,
                      DegradePolicy, RungTimeout, analytic_request_bound,
                      backoff_delays, request_family, request_scalar)
from .faults import TransientFaultError


@dataclasses.dataclass
class AdmissionRequest:
    """One job to gate: the ``estimate_training`` argument tuple plus
    the device capacity the scheduler would place it on.
    ``deadline_s`` is this request's answer budget — a slow or hung
    exact estimate is abandoned at the deadline and answered from a
    lower rung (None defers to the service-wide default)."""

    job_id: str
    fwd_bwd_fn: Callable
    params: Any
    batch: Any
    update_fn: Callable | None = None
    opt_init_fn: Callable | None = None
    shard_factor_fn: Callable | None = None
    collective_specs: Sequence = ()
    capacity: int = 16 * 2**30          # device HBM bytes
    probe_min_capacity: bool = False    # also compute min feasible capacity
    deadline_s: float | None = None     # per-request budget (ISSUE 6)
    # host-offload schedule (core.orchestrator.OffloadPlan) — the
    # estimate runs with the orchestrator's offload pass enabled and the
    # decision carries per-space peaks in its breakdown
    offload: Any | None = None
    # serving-knob signature (ServingKnobs.signature()) — separates
    # degradation-ladder evidence families per serving configuration
    serving: Any | None = None
    meta: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class AdmissionDecision:
    """The service's answer. ``safe_threshold`` is the (margin-widened)
    estimate — the value round 2 of the paper's protocol validates as a
    max-runnable-memory cap (Eq. 5). ``provenance["source"]`` records
    where stage 1 came from: "memory" (warm cache), "disk" (persistent
    store after a restart), "traced" (cold), or "degraded" (a lower
    rung answered — ``rung``/``margin`` say which and at what widening;
    ``provenance["rung_errors"]`` records why the upper rungs fell)."""

    job_id: str
    admit: bool
    capacity: int
    peak_bytes: int
    peak_tensor_bytes: int
    persistent_bytes: int
    safe_threshold: int
    breakdown: dict
    provenance: dict
    wall_s: float
    min_feasible_capacity: int | None = None
    report: EstimateReport | None = None     # full report (in-process use)
    # ranked feasible alternatives (ISSUE 5) — populated on rejection
    # when the request carries a ``meta["plan"]`` PlanContext
    counter_offers: list | None = None
    # degradation provenance (ISSUE 6)
    rung: str = RUNG_EXACT          # which ladder rung answered
    margin: float = 1.0             # safety widening applied to the peak
    raw_peak_bytes: int | None = None   # rung estimate before widening
    deadline_s: float | None = None     # budget this answer honored
    # per-request correlation ID (ISSUE 10) — set only when the
    # service runs with observability enabled; the same ID appears on
    # every span and audit record this decision produced
    correlation_id: str | None = None

    @property
    def degraded(self) -> bool:
        return self.rung != RUNG_EXACT

    def to_json(self) -> dict:
        d = {k: getattr(self, k) for k in (
            "job_id", "admit", "capacity", "peak_bytes",
            "peak_tensor_bytes", "persistent_bytes", "safe_threshold",
            "provenance", "wall_s", "min_feasible_capacity",
            "rung", "margin", "raw_peak_bytes", "deadline_s")}
        d["degraded"] = self.degraded
        d["breakdown"] = {k: v for k, v in self.breakdown.items()
                          if k in ("phase_peaks", "num_blocks",
                                   "liveness_peak", "degraded",
                                   "space_peaks", "offload", "serving")}
        if self.counter_offers is not None:
            d["counter_offers"] = [o.to_json()
                                   for o in self.counter_offers]
        if self.correlation_id is not None:
            d["correlation_id"] = self.correlation_id
        return d


def _provenance(cache: TraceCache | None, before: dict) -> dict:
    """Provenance from the calling thread's OWN counter deltas —
    concurrent decisions on other threads do not bleed into this
    request's hits/misses (``TraceCache.thread_stats``)."""
    if cache is None:
        return {"source": "traced", "trace_cache": {}}
    after = cache.thread_stats()
    hits = after["hits"] - before["hits"]
    misses = after["misses"] - before["misses"]
    store_hits = after["store_hits"] - before["store_hits"]
    source = ("traced" if misses else
              "disk" if store_hits else "memory")
    return {"source": source,
            "trace_cache": {"hits": hits, "misses": misses,
                            "store_hits": store_hits}}


def _call_with_deadline(fn: Callable[[], Any], timeout: float | None):
    """Run ``fn`` bounded by ``timeout`` seconds. ``None`` runs inline
    (zero overhead). Otherwise ``fn`` runs on a fresh daemon thread and
    a late result is abandoned: the thread finishes into the void (its
    side effects — e.g. a trace landing in the shared cache — are kept,
    so a later retry may be warm), and :class:`RungTimeout` is raised
    here. A per-call thread (not a pool) so a hung rung can never
    starve other requests' rung execution."""
    if timeout is None:
        return fn()
    box: dict = {}
    done = threading.Event()
    # ContextVars don't follow a fresh thread — copy the caller's
    # context so the observability span/correlation state (and any
    # other contextvar) survives onto the rung thread
    ctx = contextvars.copy_context()

    def run():
        try:
            box["value"] = ctx.run(fn)
        except BaseException as e:   # noqa: BLE001 — re-raised below
            box["error"] = e
        finally:
            done.set()

    t = threading.Thread(target=run, daemon=True,
                         name="xmem-rung")
    t.start()
    if not done.wait(timeout):
        raise RungTimeout(f"rung exceeded {timeout:.3f}s budget")
    if "error" in box:
        raise box["error"]
    return box["value"]


class AdmissionService:
    """Long-running estimation service (see module docstring).

    ``store_dir`` enables the persistent trace store; ``workers`` sizes
    the thread pool behind ``submit``; ``processes`` is forwarded to the
    underlying ``SweepService`` replay fan-out. ``degrade`` configures
    the degradation ladder (margins, retries, default deadline);
    ``deadline_s`` is shorthand for its ``default_deadline_s``.
    ``faults`` attaches a :class:`~repro.service.faults.FaultPlan`
    (tests / chaos replay; see also :meth:`inject_faults`).
    """

    def __init__(self, estimator_factory: Callable[..., XMemEstimator]
                 | None = None, *, store_dir: str | None = None,
                 workers: int = 2, processes: int = 0,
                 cache: TraceCache | None = None,
                 store_max_entries: int = 256,
                 degrade: DegradePolicy | None = None,
                 deadline_s: float | None = None,
                 faults=None, obs: Observability | None = None):
        self._factory = estimator_factory or XMemEstimator.for_tpu
        store = None
        if store_dir is not None:
            from .store import TraceStore
            store = TraceStore(store_dir, max_entries=store_max_entries)
        if cache is not None and store is not None:
            # attaching the service's store to a caller-owned (possibly
            # process-global) cache would silently make every estimator
            # in the process disk-backed — refuse instead
            raise ValueError(
                "pass either cache= (bring your own, optionally with its "
                "own store) or store_dir=, not both")
        if cache is not None:
            self.cache = cache
        elif store is not None:
            self.cache = TraceCache(store=store)
        else:
            # no explicit cache/store: share the process-global cache so
            # one-off service instances (per-gate construction) stay warm
            self.cache = GLOBAL_TRACE_CACHE
        self.degrade = degrade or DegradePolicy()
        if deadline_s is not None:
            self.degrade = dataclasses.replace(
                self.degrade, default_deadline_s=deadline_s)
        self.faults = faults
        self.log = DecisionLog()
        self.workers = max(int(workers), 1)
        self._processes = processes
        self._pool: ThreadPoolExecutor | None = None
        self._tls = threading.local()
        self._lock = threading.Lock()
        # decide_sweep runs on ONE estimator (SweepService is stateful)
        # — serialize it; decide()/submit() stay concurrent
        self._sweep_lock = threading.Lock()
        # ISSUE 10: every service owns an Observability handle. The
        # metrics registry is the SINGLE source for the service
        # counters — stats()/health() and the daemon's metrics kind
        # all read the same objects; spans/audit/correlation IDs only
        # activate when the handle is enabled (default: disabled).
        self.obs = obs if obs is not None else Observability(enabled=False)
        reg = self.obs.registry
        self._m_requests = reg.counter(
            "xmem_service_requests_total", "decisions served")
        self.rung_counts = CounterDict(
            (RUNG_EXACT, RUNG_SWEEP, RUNG_ANALYTIC), registry=reg,
            name="xmem_service_rung_total", label="rung",
            help="decisions answered per degradation-ladder rung")
        self._m_retries = reg.counter(
            "xmem_service_retries_total",
            "transient-fault retries on the exact rung")
        self._m_timeouts = reg.counter(
            "xmem_service_timeouts_total", "rung deadline expiries")
        self._m_abandoned = reg.counter(
            "xmem_service_abandoned_rungs_total",
            "rungs abandoned at the deadline")
        self._m_in_flight = reg.gauge(
            "xmem_service_in_flight", "decisions currently executing")
        self._m_decide_s = reg.histogram(
            "xmem_service_decide_seconds", "decide wall time")
        reg.register_collector("xmem_trace_cache",
                               lambda: self.cache.stats())
        reg.register_collector("xmem_decision_log",
                               lambda: self.log.stats())
        reg.register_collector(
            "xmem_faults",
            lambda: self.faults.stats() if self.faults is not None
            else {})
        self.sweep = SweepService(self._make_estimator(),
                                  processes=processes)

    # legacy counter surface — reads delegate to the registry so the
    # stats/health dict shapes (pinned by tests and old callers) can
    # never drift from the metrics export
    @property
    def requests_served(self) -> int:
        return self._m_requests.value

    @property
    def retry_count(self) -> int:
        return self._m_retries.value

    @property
    def timeout_count(self) -> int:
        return self._m_timeouts.value

    @property
    def abandoned_rungs(self) -> int:
        return self._m_abandoned.value

    @property
    def _in_flight(self) -> int:
        return self._m_in_flight.value

    # -- estimator plumbing --------------------------------------------------
    def _make_estimator(self) -> XMemEstimator:
        est = self._factory(trace_cache=self.cache)
        if est.trace_cache is not self.cache:
            raise ValueError("admission service needs a fastpath "
                             "estimator sharing the service cache")
        # route the estimator's stage checkpoints through the service's
        # (swappable) fault plan — a no-op attribute read when unset
        est.checkpoint = self._fault_site
        return est

    def _fault_site(self, site: str) -> None:
        plan = self.faults
        if plan is not None:
            plan.check(site)

    @property
    def estimator(self) -> XMemEstimator:
        """Per-thread estimator over the shared trace cache."""
        est = getattr(self._tls, "est", None)
        if est is None:
            est = self._tls.est = self._make_estimator()
        return est

    def _get_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.workers,
                    thread_name_prefix="xmem-admit")
            return self._pool

    def close(self) -> None:
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown()
        self.sweep.close()
        self.obs.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- fault plumbing ------------------------------------------------------
    def set_faults(self, plan) -> None:
        """Attach/detach a fault plan on the service AND its persistent
        store (if any)."""
        self.faults = plan
        store = getattr(self.cache, "store", None)
        if store is not None:
            store.faults = plan

    @contextlib.contextmanager
    def inject_faults(self, plan):
        """Scoped fault injection — chaos replays wrap themselves here
        so a failed assertion never leaves the service poisoned. Exit
        cancels the plan: workers stranded in an injected hang (their
        rung was abandoned at the deadline) wake immediately instead of
        sleeping out the full ``hang_s``."""
        prev = self.faults
        store = getattr(self.cache, "store", None)
        prev_store = store.faults if store is not None else None
        if plan is not None and hasattr(plan, "arm"):
            plan.arm()
        self.set_faults(plan)
        try:
            yield self
        finally:
            self.faults = prev
            if store is not None:
                store.faults = prev_store
            if plan is not None and hasattr(plan, "cancel"):
                plan.cancel()

    def _deadline_for(self, req: AdmissionRequest) -> float | None:
        if req.deadline_s is not None:
            return req.deadline_s
        return self.degrade.default_deadline_s

    def _count_rung(self, rung: str, served: int = 1) -> None:
        self._m_requests.inc(served)
        self.rung_counts.inc(rung, served)

    def _audit_decision(self, decision: AdmissionDecision,
                        via: str = "decide") -> None:
        """One audit record per decision (kind="decide") carrying the
        correlation ID, cache provenance, rung, and chosen offer — the
        offline reject→plan→retry reconstruction substrate."""
        obs = self.obs
        if obs.audit is None:
            return
        rec = {"via": via, "job_id": decision.job_id,
               "admit": decision.admit,
               "capacity": decision.capacity,
               "peak_bytes": decision.peak_bytes,
               "safe_threshold": decision.safe_threshold,
               "rung": decision.rung, "margin": decision.margin,
               "degraded": decision.degraded,
               "source": decision.provenance.get("source"),
               "wall_s": decision.wall_s}
        offers = decision.counter_offers
        if offers is not None:
            rec["n_offers"] = len(offers)
            if offers:
                top = offers[0].to_json()
                rec["chosen_offer"] = {
                    k: top.get(k) for k in
                    ("knob", "global_batch", "microbatches",
                     "peak_bytes", "slowdown")}
        obs.record("decide", correlation_id=decision.correlation_id,
                   **rec)

    # -- decisions -----------------------------------------------------------
    def decide(self, req: AdmissionRequest) -> AdmissionDecision:
        """Synchronous decision for one request. Never raises for
        estimator/store/timeout failures — those degrade down the rung
        ladder; only caller errors (bad request shapes on every rung)
        can propagate."""
        t0 = time.perf_counter()
        deadline_s = self._deadline_for(req)
        self._m_in_flight.inc()
        try:
            # ISSUE 10: mint the per-request correlation ID and open
            # the root span. decide() executes ON the worker thread
            # for submit()/decide_many(), so the context var reaches
            # every layer this decision touches. Observers never feed
            # back into the decision — the instrumented path stays
            # bit-identical.
            with self.obs.request("decide", job_id=req.job_id) as cid:
                if deadline_s is None and self.faults is None:
                    # fault-free fast path: exact rung inline, no
                    # extra threads — bit-identical to the pre-ladder
                    # service
                    decision = self._decide_exact(req, t0, None)
                    decision = self._attach_counter_offers(req, decision)
                else:
                    decision = self._decide_ladder(req, deadline_s, t0)
                    if not decision.degraded:
                        decision = self._attach_counter_offers(req,
                                                               decision)
                if cid is not None:
                    decision.correlation_id = cid
                self._m_decide_s.observe(decision.wall_s)
                self._audit_decision(decision)
                return decision
        finally:
            self._m_in_flight.dec()

    def _decide_exact(self, req: AdmissionRequest, t0: float,
                      deadline_s: float | None,
                      timeout: float | None = None) -> AdmissionDecision:
        """The exact rung: full-fidelity estimate (optionally bounded by
        ``timeout`` on a side thread), decision-log recording for the
        sweep rung's future evidence."""
        def run():
            est = self.estimator
            cache = est.trace_cache
            before = cache.thread_stats()
            # an offload request runs with the orchestrator's offload
            # pass swapped in for exactly this estimate (per-thread
            # estimator, so no cross-request bleed; restored either way)
            prev_policy = est.orchestrator.policy
            if req.offload is not None:
                est.orchestrator.policy = dataclasses.replace(
                    prev_policy, offload=req.offload)
            try:
                rep = est.estimate_training(
                    req.fwd_bwd_fn, req.params, req.batch,
                    update_fn=req.update_fn, opt_init_fn=req.opt_init_fn,
                    shard_factor_fn=req.shard_factor_fn,
                    collective_specs=req.collective_specs)
                min_cap = None
                if req.probe_min_capacity:
                    min_cap = est.min_feasible_capacity(
                        req.fwd_bwd_fn, req.params, req.batch, report=rep)
            finally:
                est.orchestrator.policy = prev_policy
            return rep, _provenance(cache, before), min_cap

        with obs_spans.span("rung.exact", job_id=req.job_id):
            rep, prov, min_cap = _call_with_deadline(run, timeout)
        self._count_rung(RUNG_EXACT)
        self._record_exact(req, rep)
        decision = self._decision(req, rep, prov,
                                  time.perf_counter() - t0, min_cap)
        decision.deadline_s = deadline_s
        return decision

    def _record_exact(self, req: AdmissionRequest,
                      rep: EstimateReport) -> None:
        try:
            self.log.record(request_family(req), request_scalar(req),
                            rep.peak_bytes, rep.persistent_bytes)
        except Exception:   # noqa: BLE001 — evidence is best-effort
            pass

    def _decide_ladder(self, req: AdmissionRequest,
                       deadline_s: float | None,
                       t0: float) -> AdmissionDecision:
        """Walk the rungs: exact (with capped-backoff retries on
        transient faults, abandoned at the deadline) -> sweep-log ->
        analytic. See module docstring of ``degrade``."""
        deadline_at = None if deadline_s is None else t0 + deadline_s
        errors: list[str] = []
        delays = backoff_delays(self.degrade, req.job_id)
        attempt = 0
        while True:
            remaining = None
            if deadline_at is not None:
                remaining = deadline_at - time.perf_counter()
                if remaining <= 0:
                    errors.append("deadline exhausted before exact replay")
                    break
            try:
                return self._decide_exact(req, t0, deadline_s,
                                          timeout=remaining)
            except TransientFaultError as e:
                errors.append(f"transient: {e}")
                if attempt >= len(delays):
                    errors.append("retries exhausted")
                    break
                delay = delays[attempt]
                attempt += 1
                if remaining is not None:
                    # never sleep past the budget — keep enough of it to
                    # still answer from a lower rung
                    delay = max(min(delay, remaining * 0.5), 0.0)
                self._m_retries.inc()
                obs_spans.event("rung.retry", attempt=attempt)
                time.sleep(delay)
            except RungTimeout as e:
                errors.append(f"timeout: {e}")
                self._m_timeouts.inc()
                self._m_abandoned.inc()
                break
            except Exception as e:   # noqa: BLE001 — rung falls, never propagates
                errors.append(f"{type(e).__name__}: {e}")
                break
        return self._decide_degraded(req, errors, t0, deadline_s)

    def _decide_degraded(self, req: AdmissionRequest, errors: list[str],
                         t0: float, deadline_s: float | None
                         ) -> AdmissionDecision:
        """Rungs 2-3: answer from the decision log or the analytic
        bound. Pure CPU arithmetic — never traces, never raises."""
        got = None
        try:
            got = self.log.lookup(request_family(req), request_scalar(req))
        except Exception as e:   # noqa: BLE001 — evidence lookup is best-effort
            errors.append(f"sweep-log: {type(e).__name__}: {e}")
        if got is not None:
            raw, how = got
            return self._degraded_decision(req, raw, RUNG_SWEEP, how,
                                           errors, t0, deadline_s)
        errors.append("sweep-log: no evidence for this job family")
        try:
            raw = analytic_request_bound(req, self.log)
            how = "bound"
        except Exception as e:   # noqa: BLE001 — last rung must answer
            errors.append(f"analytic: {type(e).__name__}: {e}")
            raw, how = req.capacity + 1, "refuse"  # unknowable: never admit
        return self._degraded_decision(req, raw, RUNG_ANALYTIC, how,
                                       errors, t0, deadline_s)

    def _degraded_decision(self, req: AdmissionRequest, raw_peak: int,
                           rung: str, how: str, errors: list[str],
                           t0: float, deadline_s: float | None
                           ) -> AdmissionDecision:
        margin = self.degrade.margin_for(rung)
        peak = int(math.ceil(raw_peak * margin))
        obs_spans.event(f"rung.{rung}", derived=how, margin=margin)
        prov = {"source": "degraded", "rung": rung, "margin": margin,
                "derived": how, "rung_errors": list(errors),
                "trace_cache": {}}
        self._count_rung(rung)
        return AdmissionDecision(
            job_id=req.job_id,
            admit=peak <= req.capacity,
            capacity=req.capacity,
            peak_bytes=peak,
            peak_tensor_bytes=int(raw_peak),
            persistent_bytes=0,
            safe_threshold=peak,
            breakdown={"degraded": True},
            provenance=prov,
            wall_s=time.perf_counter() - t0,
            rung=rung, margin=margin, raw_peak_bytes=int(raw_peak),
            deadline_s=deadline_s)

    def _attach_counter_offers(self, req: AdmissionRequest,
                               decision: AdmissionDecision
                               ) -> AdmissionDecision:
        """ISSUE 5: a rejection whose request carries a structured plan
        context (``meta["plan"]`` = ``repro.plan.PlanContext``) comes
        back with ranked counter-offers instead of a bare no. Planner-
        internal probe requests carry no context, so this cannot
        recurse. Degraded decisions skip planning (the search's probe
        estimates would hit the same failing rungs)."""
        ctx = req.meta.get("plan") if req.meta else None
        if ctx is None or decision.admit or decision.degraded:
            return decision
        from ..plan import RemediationPlanner
        # candidates must be estimated under the request's OWN execution
        # model — a per-device rejection (custom shard factors /
        # collective specs) must not be answered with whole-model offers
        result = RemediationPlanner(self).plan(
            ctx.cfg, ctx.policy, ctx.shape, capacity=req.capacity,
            space=ctx.space, job_id=req.job_id, baseline=decision,
            shard_factor_fn=req.shard_factor_fn,
            collective_specs=req.collective_specs)
        decision.counter_offers = result.offers
        decision.provenance["plan"] = result.stats
        return decision

    def decide_serving(self, job_id: str, decode_fn: Callable, params,
                       cache_tree, batch, *, capacity: int,
                       shard_factor_fn=None,
                       deadline_s: float | None = None,
                       mix=None, stream=None, knobs=None,
                       kv_bytes_per_token: int | None = None,
                       resident_bytes_per_request: int = 0,
                       plan=None) -> AdmissionDecision:
        """Serving decision — the ``launch/serve.py`` gate.

        Two modes share one cached decode trace:

        * **static** (no ``mix``/``stream``): the original single-phase
          estimate of a decode step with a persistent monolithic cache;
        * **request-driven** (ISSUE 9): pass a ``RequestMix`` (or a
          concrete ``RequestStream``) plus ``knobs``/
          ``kv_bytes_per_token`` and the decision gates on the
          continuous-batching worst-case peak, with the full
          :class:`~repro.core.estimator.ServingEstimate` under
          ``breakdown["serving"]`` (whitelisted onto the wire).

        Degrades like ``decide``: a failed or over-deadline estimate is
        answered from the analytic rung over (params + cache + batch)
        avals, with serving knobs separating evidence families. A
        request-driven rejection carrying a ``plan``
        (``repro.plan.ServingPlanContext``) comes back with ranked
        serving counter-offers."""
        t0 = time.perf_counter()
        if deadline_s is None:
            deadline_s = self.degrade.default_deadline_s
        if stream is None and mix is not None:
            stream = mix.stream()
        if stream is not None and kv_bytes_per_token is None:
            raise ValueError(
                "request-driven serving decisions need kv_bytes_per_token")
        if stream is not None and knobs is None:
            from ..core.orchestrator import ServingKnobs
            knobs = ServingKnobs()
        knob_sig = knobs.signature() if knobs is not None else None

        def run():
            est = self.estimator
            cache = est.trace_cache
            before = cache.thread_stats()
            if stream is not None:
                se = est.estimate_request_stream(
                    decode_fn, params, cache_tree, batch, stream=stream,
                    knobs=knobs, kv_bytes_per_token=kv_bytes_per_token,
                    resident_bytes_per_request=resident_bytes_per_request,
                    shard_factor_fn=shard_factor_fn, capacity=capacity)
                rep = EstimateReport(
                    peak_bytes=se.worst_case_peak_bytes,
                    peak_tensor_bytes=se.steady_state_peak_bytes,
                    persistent_bytes=se.persistent_bytes,
                    oom=se.oom, sim=se.sim,
                    breakdown={"num_blocks": se.breakdown["num_blocks"],
                               "serving": se.to_json()},
                    wall_time_s=se.wall_time_s,
                    num_events=se.num_events)
            else:
                rep = est.estimate_serving(decode_fn, params, cache_tree,
                                           batch,
                                           shard_factor_fn=shard_factor_fn)
            return rep, _provenance(cache, before)

        req = AdmissionRequest(job_id, decode_fn, params, batch,
                               capacity=capacity, deadline_s=deadline_s,
                               serving=knob_sig)
        self._m_in_flight.inc()
        try:
            with self.obs.request("serve", job_id=job_id) as cid:
                decision = None
                if deadline_s is None and self.faults is None:
                    rep, prov = run()
                else:
                    try:
                        rep, prov = _call_with_deadline(run, deadline_s)
                    except Exception as e:   # noqa: BLE001 — degrade, never fail
                        errors = [f"{type(e).__name__}: {e}"]
                        if isinstance(e, RungTimeout):
                            self._m_timeouts.inc()
                            self._m_abandoned.inc()
                        # the resident KV cache is persistent state:
                        # count it with the params for the aval bound
                        proxy = AdmissionRequest(
                            job_id, decode_fn, (params, cache_tree),
                            batch, capacity=capacity, serving=knob_sig)
                        decision = self._decide_degraded(proxy, errors,
                                                         t0, deadline_s)
                if decision is None:
                    self._count_rung(RUNG_EXACT)
                    decision = self._decision(req, rep, prov,
                                              time.perf_counter() - t0,
                                              None)
                    decision.deadline_s = deadline_s
                    if plan is not None and not decision.admit \
                            and not decision.degraded:
                        decision = self._attach_serving_offers(
                            plan, decision, capacity)
                if cid is not None:
                    decision.correlation_id = cid
                self._m_decide_s.observe(decision.wall_s)
                self._audit_decision(decision, via="serve")
                return decision
        finally:
            self._m_in_flight.dec()

    def _attach_serving_offers(self, ctx, decision: AdmissionDecision,
                               capacity: int) -> AdmissionDecision:
        """A request-driven serving rejection with a
        ``ServingPlanContext`` comes back with ranked serving
        counter-offers (page size / concurrency / KV dtype /
        prefix-cache) — trace-free against the already-cached decode
        trace. Planning failures leave the bare rejection intact."""
        from ..plan import RemediationPlanner
        try:
            result = RemediationPlanner(self).plan_serving(
                ctx, capacity=capacity, job_id=decision.job_id,
                baseline=decision)
            decision.counter_offers = result.offers
            decision.provenance["plan"] = result.stats
        except Exception as e:   # noqa: BLE001 — offers are best-effort
            decision.provenance["plan"] = {
                "error": f"{type(e).__name__}: {e}"}
        return decision

    def _decision(self, req: AdmissionRequest, rep: EstimateReport,
                  provenance: dict, wall_s: float,
                  min_cap: int | None) -> AdmissionDecision:
        provenance.setdefault("rung", RUNG_EXACT)
        provenance.setdefault("margin", 1.0)
        return AdmissionDecision(
            job_id=req.job_id,
            admit=rep.peak_bytes <= req.capacity,
            capacity=req.capacity,
            peak_bytes=rep.peak_bytes,
            peak_tensor_bytes=rep.peak_tensor_bytes,
            persistent_bytes=rep.persistent_bytes,
            safe_threshold=rep.peak_bytes,
            breakdown=rep.breakdown,
            provenance=provenance,
            wall_s=wall_s,
            min_feasible_capacity=min_cap,
            report=rep,
            raw_peak_bytes=rep.peak_bytes)

    def submit(self, req: AdmissionRequest) -> "Future[AdmissionDecision]":
        """Concurrent decision: runs on the service's worker pool."""
        return self._get_pool().submit(self.decide, req)

    def decide_many(self, reqs: Sequence[AdmissionRequest]
                    ) -> list[AdmissionDecision]:
        """Fan a batch of independent requests over the worker pool.
        Each request keeps its own deadline budget (measured from when
        its decision starts executing)."""
        return [f.result() for f in [self.submit(r) for r in reqs]]

    def decide_sweep(self, reqs: Sequence[AdmissionRequest]
                     ) -> list[AdmissionDecision]:
        """Batched decisions through ``SweepService.estimate_many`` —
        requests sharing structure (a batch-size admission sweep) pay
        three probe traces, the rest interpolate. ``meta["plan"]``
        contexts are ignored on this path (a planner search per
        rejected point would defeat the batching); route individual
        rejections through ``decide`` for counter-offers.

        Deadline budget: the tightest request deadline bounds the whole
        batched sweep; a sweep that fails or runs past it is abandoned
        (the sweep estimator is rebuilt — the stranded worker finishes
        into the void) and EVERY point is answered from the degraded
        rungs instead."""
        t0 = time.perf_counter()
        cache = self.cache
        deadlines = [self._deadline_for(r) for r in reqs]
        bounded = [d for d in deadlines if d is not None]
        timeout = min(bounded) if bounded else None
        points = [SweepPoint(
            r.fwd_bwd_fn, r.params, r.batch, update_fn=r.update_fn,
            opt_init_fn=r.opt_init_fn, shard_factor_fn=r.shard_factor_fn,
            collective_specs=r.collective_specs, label=r.job_id)
            for r in reqs]

        def run_sweep():
            before = cache.thread_stats()
            result = self.sweep.estimate_many(points)
            return result, _provenance(cache, before)

        # one correlation ID covers the whole batched sweep — the
        # points share probe traces, so their spans and audit records
        # genuinely belong to one operation
        with self.obs.request("sweep") as cid:
            decisions = None
            with self._sweep_lock:
                if timeout is None and self.faults is None:
                    result, prov = run_sweep()
                else:
                    try:
                        result, prov = _call_with_deadline(run_sweep,
                                                           timeout)
                    except Exception as e:   # noqa: BLE001 — degrade every point
                        errors = [f"{type(e).__name__}: {e}"]
                        if isinstance(e, RungTimeout):
                            self._m_timeouts.inc()
                            self._m_abandoned.inc()
                            # the abandoned worker still owns the old
                            # sweep estimator — swap in a fresh one for
                            # later calls
                            self.sweep = SweepService(
                                self._make_estimator(),
                                processes=self._processes)
                        decisions = [
                            self._decide_degraded(r, list(errors), t0, d)
                            for r, d in zip(reqs, deadlines)]
            if decisions is None:
                prov["sweep"] = {k: result.stats[k] for k in
                                 ("points", "traced", "interpolated",
                                  "fallback", "pooled")}
                # per-decision wall_s is the AMORTIZED share of the
                # batched sweep (summing per-job costs must not
                # over-count the sweep N times); each decision gets its
                # own provenance copy so callers mutating one cannot
                # alter siblings
                wall = (time.perf_counter() - t0) / max(len(reqs), 1)
                self._count_rung(RUNG_EXACT, served=len(reqs))
                decisions = []
                for r, rep, d in zip(reqs, result.reports, deadlines):
                    self._record_exact(r, rep)
                    dec = self._decision(r, rep, copy.deepcopy(prov),
                                         wall, None)
                    dec.deadline_s = d
                    decisions.append(dec)
            for dec in decisions:
                if cid is not None:
                    dec.correlation_id = cid
                self._audit_decision(dec, via="sweep")
            return decisions

    def mesh_sweep(self, fwd_bwd_fn, params, batch, topologies, *,
                   update_fn=None, opt_init_fn=None, cfg=None,
                   shard_factors: str = "spec", collectives: bool = True,
                   capacity: int | None = None):
        """Per-device estimates over a mesh-topology grid from ONE
        cached trace (``SweepService.estimate_mesh_sweep``), serialized
        on the service's single sweep estimator like ``decide_sweep`` —
        the remediation planner's trace-free topology axis."""
        with self.obs.request("mesh_sweep"), self._sweep_lock:
            result = self.sweep.estimate_mesh_sweep(
                fwd_bwd_fn, params, batch, topologies,
                update_fn=update_fn, opt_init_fn=opt_init_fn, cfg=cfg,
                shard_factors=shard_factors, collectives=collectives,
                capacity=capacity)
        self._m_requests.inc(len(result))
        return result

    def stats(self) -> dict:
        return {"requests_served": self.requests_served,
                "workers": self.workers,
                "rungs": dict(self.rung_counts),
                "trace_cache": self.cache.stats()}

    def health(self) -> dict:
        """Liveness/diagnostics surface for the daemon's ``health``
        request kind: rung counters, degradation totals, store state
        (incl. quarantine/recovery), queue depth and in-flight count."""
        with self._lock:
            pool = self._pool
            d = {
                "status": "ok",
                "requests_served": self.requests_served,
                "in_flight": self._in_flight,
                "queue_depth": (pool._work_queue.qsize()
                                if pool is not None else 0),
                "workers": self.workers,
                "rungs": dict(self.rung_counts),
                "degraded": (self.rung_counts[RUNG_SWEEP]
                             + self.rung_counts[RUNG_ANALYTIC]),
                "retries": self.retry_count,
                "timeouts": self.timeout_count,
                "abandoned_rungs": self.abandoned_rungs,
                "deadline_s": self.degrade.default_deadline_s,
            }
        d["decision_log"] = self.log.stats()
        d["trace_cache"] = self.cache.stats()
        if self.faults is not None:
            d["faults"] = self.faults.stats()
        return d
