"""Admission service: request/response estimation over a worker pool.

The scheduler-facing API of the estimator (ISSUE 4 tentpole). An
:class:`AdmissionRequest` names one training (or serving) job by the
exact callables the runtime would execute; the service answers with an
:class:`AdmissionDecision` carrying the estimate, the safe threshold
(Eq. 5: the estimate usable as max-runnable-memory), the per-device
breakdown and cache provenance (memory-warm / disk-warm / traced).

Estimates are produced by the same ``XMemEstimator`` pipeline as the
one-shot CLIs — the equivalence test pins the service bit-identical to
direct calls. What the service adds:

* a shared thread-safe :class:`~repro.core.cache.TraceCache`, optionally
  layered over a persistent :class:`~repro.service.store.TraceStore`
  (content-addressed keys, so re-created but structurally identical
  step functions are warm — across decisions AND process restarts);
* concurrent serving: ``submit`` fans decisions out over a thread pool,
  one estimator per worker thread (the orchestrator mutates per-call
  policy state, so estimator instances are not shared across threads;
  the trace cache is);
* batched decisions: ``decide_sweep`` routes a family of requests that
  differ in one scalar (the batch-size admission sweep) through
  ``SweepService.estimate_many`` — probe traces + affine interpolation
  + vectorized replay instead of N full estimates.
"""
from __future__ import annotations

import copy
import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Sequence

from ..core.cache import GLOBAL_TRACE_CACHE, TraceCache
from ..core.estimator import EstimateReport, XMemEstimator
from ..core.sweep import SweepPoint, SweepService


@dataclasses.dataclass
class AdmissionRequest:
    """One job to gate: the ``estimate_training`` argument tuple plus
    the device capacity the scheduler would place it on."""

    job_id: str
    fwd_bwd_fn: Callable
    params: Any
    batch: Any
    update_fn: Callable | None = None
    opt_init_fn: Callable | None = None
    shard_factor_fn: Callable | None = None
    collective_specs: Sequence = ()
    capacity: int = 16 * 2**30          # device HBM bytes
    probe_min_capacity: bool = False    # also compute min feasible capacity
    meta: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class AdmissionDecision:
    """The service's answer. ``safe_threshold`` is the estimate itself —
    the value round 2 of the paper's protocol validates as a max-
    runnable-memory cap (Eq. 5). ``provenance["source"]`` records where
    stage 1 came from: "memory" (warm cache), "disk" (persistent store
    after a restart), or "traced" (cold)."""

    job_id: str
    admit: bool
    capacity: int
    peak_bytes: int
    peak_tensor_bytes: int
    persistent_bytes: int
    safe_threshold: int
    breakdown: dict
    provenance: dict
    wall_s: float
    min_feasible_capacity: int | None = None
    report: EstimateReport | None = None     # full report (in-process use)
    # ranked feasible alternatives (ISSUE 5) — populated on rejection
    # when the request carries a ``meta["plan"]`` PlanContext
    counter_offers: list | None = None

    def to_json(self) -> dict:
        d = {k: getattr(self, k) for k in (
            "job_id", "admit", "capacity", "peak_bytes",
            "peak_tensor_bytes", "persistent_bytes", "safe_threshold",
            "provenance", "wall_s", "min_feasible_capacity")}
        d["breakdown"] = {k: v for k, v in self.breakdown.items()
                          if k in ("phase_peaks", "num_blocks",
                                   "liveness_peak")}
        if self.counter_offers is not None:
            d["counter_offers"] = [o.to_json()
                                   for o in self.counter_offers]
        return d


def _provenance(cache: TraceCache | None, before: dict) -> dict:
    """Provenance from the calling thread's OWN counter deltas —
    concurrent decisions on other threads do not bleed into this
    request's hits/misses (``TraceCache.thread_stats``)."""
    if cache is None:
        return {"source": "traced", "trace_cache": {}}
    after = cache.thread_stats()
    hits = after["hits"] - before["hits"]
    misses = after["misses"] - before["misses"]
    store_hits = after["store_hits"] - before["store_hits"]
    source = ("traced" if misses else
              "disk" if store_hits else "memory")
    return {"source": source,
            "trace_cache": {"hits": hits, "misses": misses,
                            "store_hits": store_hits}}


class AdmissionService:
    """Long-running estimation service (see module docstring).

    ``store_dir`` enables the persistent trace store; ``workers`` sizes
    the thread pool behind ``submit``; ``processes`` is forwarded to the
    underlying ``SweepService`` replay fan-out.
    """

    def __init__(self, estimator_factory: Callable[..., XMemEstimator]
                 | None = None, *, store_dir: str | None = None,
                 workers: int = 2, processes: int = 0,
                 cache: TraceCache | None = None,
                 store_max_entries: int = 256):
        self._factory = estimator_factory or XMemEstimator.for_tpu
        store = None
        if store_dir is not None:
            from .store import TraceStore
            store = TraceStore(store_dir, max_entries=store_max_entries)
        if cache is not None and store is not None:
            # attaching the service's store to a caller-owned (possibly
            # process-global) cache would silently make every estimator
            # in the process disk-backed — refuse instead
            raise ValueError(
                "pass either cache= (bring your own, optionally with its "
                "own store) or store_dir=, not both")
        if cache is not None:
            self.cache = cache
        elif store is not None:
            self.cache = TraceCache(store=store)
        else:
            # no explicit cache/store: share the process-global cache so
            # one-off service instances (per-gate construction) stay warm
            self.cache = GLOBAL_TRACE_CACHE
        self.workers = max(int(workers), 1)
        self._pool: ThreadPoolExecutor | None = None
        self._tls = threading.local()
        self._lock = threading.Lock()
        # decide_sweep runs on ONE estimator (SweepService is stateful)
        # — serialize it; decide()/submit() stay concurrent
        self._sweep_lock = threading.Lock()
        self.requests_served = 0
        self.sweep = SweepService(self._make_estimator(),
                                  processes=processes)

    # -- estimator plumbing --------------------------------------------------
    def _make_estimator(self) -> XMemEstimator:
        est = self._factory(trace_cache=self.cache)
        if est.trace_cache is not self.cache:
            raise ValueError("admission service needs a fastpath "
                             "estimator sharing the service cache")
        return est

    @property
    def estimator(self) -> XMemEstimator:
        """Per-thread estimator over the shared trace cache."""
        est = getattr(self._tls, "est", None)
        if est is None:
            est = self._tls.est = self._make_estimator()
        return est

    def _get_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.workers,
                    thread_name_prefix="xmem-admit")
            return self._pool

    def close(self) -> None:
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown()
        self.sweep.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- decisions -----------------------------------------------------------
    def decide(self, req: AdmissionRequest) -> AdmissionDecision:
        """Synchronous decision for one request."""
        t0 = time.perf_counter()
        est = self.estimator
        cache = est.trace_cache
        before = cache.thread_stats()
        rep = est.estimate_training(
            req.fwd_bwd_fn, req.params, req.batch,
            update_fn=req.update_fn, opt_init_fn=req.opt_init_fn,
            shard_factor_fn=req.shard_factor_fn,
            collective_specs=req.collective_specs)
        min_cap = None
        if req.probe_min_capacity:
            min_cap = est.min_feasible_capacity(
                req.fwd_bwd_fn, req.params, req.batch, report=rep)
        with self._lock:
            self.requests_served += 1
        decision = self._decision(req, rep, _provenance(cache, before),
                                  time.perf_counter() - t0, min_cap)
        return self._attach_counter_offers(req, decision)

    def _attach_counter_offers(self, req: AdmissionRequest,
                               decision: AdmissionDecision
                               ) -> AdmissionDecision:
        """ISSUE 5: a rejection whose request carries a structured plan
        context (``meta["plan"]`` = ``repro.plan.PlanContext``) comes
        back with ranked counter-offers instead of a bare no. Planner-
        internal probe requests carry no context, so this cannot
        recurse."""
        ctx = req.meta.get("plan") if req.meta else None
        if ctx is None or decision.admit:
            return decision
        from ..plan import RemediationPlanner
        # candidates must be estimated under the request's OWN execution
        # model — a per-device rejection (custom shard factors /
        # collective specs) must not be answered with whole-model offers
        result = RemediationPlanner(self).plan(
            ctx.cfg, ctx.policy, ctx.shape, capacity=req.capacity,
            space=ctx.space, job_id=req.job_id, baseline=decision,
            shard_factor_fn=req.shard_factor_fn,
            collective_specs=req.collective_specs)
        decision.counter_offers = result.offers
        decision.provenance["plan"] = result.stats
        return decision

    def decide_serving(self, job_id: str, decode_fn: Callable, params,
                       cache_tree, batch, *, capacity: int,
                       shard_factor_fn=None) -> AdmissionDecision:
        """Single-phase serving decision (decode / prefill step with a
        persistent KV cache) — the ``launch/serve.py`` gate."""
        t0 = time.perf_counter()
        est = self.estimator
        cache = est.trace_cache
        before = cache.thread_stats()
        rep = est.estimate_serving(decode_fn, params, cache_tree, batch,
                                   shard_factor_fn=shard_factor_fn)
        req = AdmissionRequest(job_id, decode_fn, params, batch,
                               capacity=capacity)
        with self._lock:
            self.requests_served += 1
        return self._decision(req, rep, _provenance(cache, before),
                              time.perf_counter() - t0, None)

    def _decision(self, req: AdmissionRequest, rep: EstimateReport,
                  provenance: dict, wall_s: float,
                  min_cap: int | None) -> AdmissionDecision:
        return AdmissionDecision(
            job_id=req.job_id,
            admit=rep.peak_bytes <= req.capacity,
            capacity=req.capacity,
            peak_bytes=rep.peak_bytes,
            peak_tensor_bytes=rep.peak_tensor_bytes,
            persistent_bytes=rep.persistent_bytes,
            safe_threshold=rep.peak_bytes,
            breakdown=rep.breakdown,
            provenance=provenance,
            wall_s=wall_s,
            min_feasible_capacity=min_cap,
            report=rep)

    def submit(self, req: AdmissionRequest) -> "Future[AdmissionDecision]":
        """Concurrent decision: runs on the service's worker pool."""
        return self._get_pool().submit(self.decide, req)

    def decide_many(self, reqs: Sequence[AdmissionRequest]
                    ) -> list[AdmissionDecision]:
        """Fan a batch of independent requests over the worker pool."""
        return [f.result() for f in [self.submit(r) for r in reqs]]

    def decide_sweep(self, reqs: Sequence[AdmissionRequest]
                     ) -> list[AdmissionDecision]:
        """Batched decisions through ``SweepService.estimate_many`` —
        requests sharing structure (a batch-size admission sweep) pay
        three probe traces, the rest interpolate. ``meta["plan"]``
        contexts are ignored on this path (a planner search per
        rejected point would defeat the batching); route individual
        rejections through ``decide`` for counter-offers."""
        t0 = time.perf_counter()
        cache = self.cache
        points = [SweepPoint(
            r.fwd_bwd_fn, r.params, r.batch, update_fn=r.update_fn,
            opt_init_fn=r.opt_init_fn, shard_factor_fn=r.shard_factor_fn,
            collective_specs=r.collective_specs, label=r.job_id)
            for r in reqs]
        with self._sweep_lock:
            before = cache.thread_stats()
            result = self.sweep.estimate_many(points)
            prov = _provenance(cache, before)
        prov["sweep"] = {k: result.stats[k] for k in
                         ("points", "traced", "interpolated", "fallback",
                          "pooled")}
        # per-decision wall_s is the AMORTIZED share of the batched
        # sweep (summing per-job costs must not over-count the sweep N
        # times); each decision gets its own provenance copy so callers
        # mutating one cannot alter siblings
        wall = (time.perf_counter() - t0) / max(len(reqs), 1)
        with self._lock:
            self.requests_served += len(reqs)
        return [self._decision(r, rep, copy.deepcopy(prov), wall, None)
                for r, rep in zip(reqs, result.reports)]

    def mesh_sweep(self, fwd_bwd_fn, params, batch, topologies, *,
                   update_fn=None, opt_init_fn=None, cfg=None,
                   shard_factors: str = "spec", collectives: bool = True,
                   capacity: int | None = None):
        """Per-device estimates over a mesh-topology grid from ONE
        cached trace (``SweepService.estimate_mesh_sweep``), serialized
        on the service's single sweep estimator like ``decide_sweep`` —
        the remediation planner's trace-free topology axis."""
        with self._sweep_lock:
            result = self.sweep.estimate_mesh_sweep(
                fwd_bwd_fn, params, batch, topologies,
                update_fn=update_fn, opt_init_fn=opt_init_fn, cfg=cfg,
                shard_factors=shard_factors, collectives=collectives,
                capacity=capacity)
        with self._lock:
            self.requests_served += len(result)
        return result

    def stats(self) -> dict:
        return {"requests_served": self.requests_served,
                "workers": self.workers,
                "trace_cache": self.cache.stats()}
