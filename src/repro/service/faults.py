"""Fault-injection harness for the admission service (ISSUE 6).

A :class:`FaultPlan` is a declarative script of failures to inject at
named sites inside the serving stack. Production code calls
``plan.check(site)`` (or ``plan.check(site, path=...)`` for on-disk
sites) at well-defined points; the plan decides — per site, per hit
count — whether to raise, hang, or corrupt the artifact at ``path``.
With no plan attached every check is a no-op attribute test, so the
fault-free fast path stays bit-identical to the un-instrumented code.

Injection sites wired through the stack:

===============  ============================================================
site             fired from
===============  ============================================================
``tracer``       ``XMemEstimator._trace_phase`` — after a cache miss, right
                 before the real JAX trace (models a tracer exception or
                 hang on an exotic model)
``replay``       ``XMemEstimator._estimate_from_phases`` — before the
                 allocator replay (models a hung / crashed simulation)
``store.load``   ``TraceStore.load`` — before the entry file is read;
                 ``corrupt``/``truncate`` mangle the file on disk first,
                 exercising the quarantine path
``store.save``   ``TraceStore.save`` — after the atomic rename; ``corrupt``
                 /``truncate`` mangle the *persisted* entry (a simulated
                 mid-write crash surfaces at the next load)
``socket``       the admission daemon, once per parsed request line
``node.fail``    polled by ``sched.FleetSimulator`` once per arrival tick
                 — permanently kills a node (ISSUE 7)
``node.flap``    like ``node.fail`` but the node returns after
                 ``down_for`` ticks
``node.shrink``  multiplies a node's effective capacity by
                 ``shrink_frac`` (a partial-HBM loss / MIG re-slice)
===============  ============================================================

Fault kinds: ``raise`` (:class:`FaultError`, non-retryable — the
degradation ladder falls straight to the next rung), ``transient``
(:class:`TransientFaultError` — the ladder retries with backoff before
falling), ``hang`` (waits up to ``hang_s`` on the plan's cancel event;
a deadline abandons the rung, and ``FaultPlan.cancel()`` — called when
``inject_faults`` exits — wakes every stranded sleeper immediately),
``corrupt`` (overwrites a byte range of ``path``), ``truncate`` (cuts
``path`` to half its size), ``event`` (a fleet-level topology event at
one of the ``node.*`` sites above — consumed via :meth:`FaultPlan.poll`
by the fleet simulator, a no-op under :meth:`FaultPlan.check`). Used by
``tests/test_faults.py``, ``ClusterSimulator.replay(faults=...)`` chaos
mode, and ``sched.FleetSimulator.replay(faults=...)`` fleet chaos.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Sequence

#: Fleet-topology event sites, polled (not checked) once per arrival
#: tick by ``repro.sched.FleetSimulator``.
FLEET_SITES = ("node.fail", "node.flap", "node.shrink")


class FaultError(RuntimeError):
    """An injected, non-retryable failure."""


class TransientFaultError(FaultError):
    """An injected failure the caller may retry (backoff applies)."""


class ChaosSafetyViolation(AssertionError):
    """Chaos replay admitted a job whose true peak exceeds its device —
    the one outcome fault injection must never produce."""


@dataclasses.dataclass
class FaultSpec:
    """One scripted failure: fire ``times`` times at ``site``, skipping
    the first ``after`` hits. ``times=None`` fires on every hit.

    Fleet-event fields (``kind="event"`` at a ``node.*`` site): the
    fleet simulator polls each fleet site once per arrival tick, so
    ``after`` is the tick the event fires at. ``node`` names the target
    (None lets the scheduler pick the most-loaded node), ``down_for``
    is how many ticks a flapped node stays down, and ``shrink_frac``
    the capacity multiplier of a ``node.shrink``."""

    site: str                   # "tracer" | "replay" | "node.fail" | ...
    kind: str                   # "raise" | "transient" | "hang" | ... | "event"
    times: int | None = 1
    after: int = 0
    hang_s: float = 30.0
    message: str = ""
    node: str | None = None     # fleet events: target node id
    down_for: int = 2           # node.flap: ticks until the node returns
    shrink_frac: float = 0.5    # node.shrink: capacity multiplier

    _KINDS = ("raise", "transient", "hang", "corrupt", "truncate",
              "event")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {self._KINDS})")
        if self.site in FLEET_SITES and self.kind != "event":
            raise ValueError(
                f"fleet site {self.site!r} takes kind='event', "
                f"got {self.kind!r}")
        if self.kind == "event" and self.site not in FLEET_SITES:
            raise ValueError(
                f"kind='event' is only valid on fleet sites "
                f"{FLEET_SITES}, got site {self.site!r}")


def fleet_event(site: str, *, at: int = 0, node: str | None = None,
                down_for: int = 2, shrink_frac: float = 0.5,
                times: int | None = 1) -> FaultSpec:
    """Shorthand for a fleet-topology event: ``site`` is one of
    ``FLEET_SITES``, ``at`` the arrival tick it fires on."""
    if site not in FLEET_SITES:
        raise ValueError(f"{site!r} is not a fleet site {FLEET_SITES}")
    return FaultSpec(site=site, kind="event", times=times, after=at,
                     node=node, down_for=down_for,
                     shrink_frac=shrink_frac)


def _corrupt_file(path: str) -> None:
    """Overwrite a mid-file byte range with garbage (parse must fail)."""
    try:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.seek(max(size // 3, 0))
            f.write(b"\x00#corrupt#\x00" * 4)
    except OSError:
        pass


def _truncate_file(path: str) -> None:
    """Cut the file to half its size (a mid-write crash)."""
    try:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size // 2)
    except OSError:
        pass


class FaultPlan:
    """Thread-safe collection of :class:`FaultSpec`; counts every site
    hit and every fault actually fired (``stats()``)."""

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs = list(specs)
        self._lock = threading.Lock()
        self.hits: dict[str, int] = {}
        self.fired: dict[str, int] = {}
        self._spec_fired = [0] * len(self.specs)
        # hang sleepers wait on this instead of time.sleep so an
        # exiting inject_faults scope can wake them immediately
        self._cancel = threading.Event()

    def arm(self) -> None:
        """Re-arm the plan for a fresh injection scope (clears a prior
        ``cancel`` so scripted hangs block again)."""
        self._cancel.clear()

    def cancel(self) -> None:
        """Wake every thread currently sleeping in an injected hang —
        called when the injection scope exits, so abandoned rung workers
        stop stranding threads for the full ``hang_s``."""
        self._cancel.set()

    def add(self, *specs: FaultSpec) -> "FaultPlan":
        with self._lock:
            self.specs.extend(specs)
            self._spec_fired.extend([0] * len(specs))
        return self

    def _select(self, site: str) -> FaultSpec | None:
        """Pick the first applicable spec for this hit (under lock)."""
        hit = self.hits.get(site, 0)
        self.hits[site] = hit + 1
        for i, spec in enumerate(self.specs):
            if spec.site != site or hit < spec.after:
                continue
            if spec.times is not None and self._spec_fired[i] >= spec.times:
                continue
            self._spec_fired[i] += 1
            self.fired[site] = self.fired.get(site, 0) + 1
            return spec
        return None

    def poll(self, site: str) -> FaultSpec | None:
        """Event-style selection: return the spec scheduled for this
        ``site`` hit (counting the hit) without raising or blocking —
        how the fleet simulator consumes ``node.*`` topology events."""
        with self._lock:
            return self._select(site)

    def check(self, site: str, path: str | None = None) -> None:
        """Fire any scripted fault for this ``site`` hit. File kinds
        need ``path``; without one they degrade to ``raise``."""
        with self._lock:
            spec = self._select(site)
        if spec is None or spec.kind == "event":
            return
        msg = spec.message or f"injected {spec.kind} at {site}"
        if spec.kind == "hang":
            # interruptible: wakes early when the injection scope exits
            self._cancel.wait(spec.hang_s)
            return
        if spec.kind in ("corrupt", "truncate"):
            if path is None:
                raise FaultError(msg + " (no path at this site)")
            (_corrupt_file if spec.kind == "corrupt"
             else _truncate_file)(path)
            return
        if spec.kind == "transient":
            raise TransientFaultError(msg)
        raise FaultError(msg)

    def stats(self) -> dict:
        with self._lock:
            return {"specs": len(self.specs), "hits": dict(self.hits),
                    "fired": dict(self.fired)}


def plan_raising_at(*sites: str, kind: str = "raise",
                    times: int | None = None) -> FaultPlan:
    """Shorthand for the common every-hit matrix rows in tests."""
    return FaultPlan([FaultSpec(site=s, kind=kind, times=times)
                      for s in sites])
