"""Fault-injection harness for the admission service (ISSUE 6).

A :class:`FaultPlan` is a declarative script of failures to inject at
named sites inside the serving stack. Production code calls
``plan.check(site)`` (or ``plan.check(site, path=...)`` for on-disk
sites) at well-defined points; the plan decides — per site, per hit
count — whether to raise, hang, or corrupt the artifact at ``path``.
With no plan attached every check is a no-op attribute test, so the
fault-free fast path stays bit-identical to the un-instrumented code.

Injection sites wired through the stack:

===============  ============================================================
site             fired from
===============  ============================================================
``tracer``       ``XMemEstimator._trace_phase`` — after a cache miss, right
                 before the real JAX trace (models a tracer exception or
                 hang on an exotic model)
``replay``       ``XMemEstimator._estimate_from_phases`` — before the
                 allocator replay (models a hung / crashed simulation)
``store.load``   ``TraceStore.load`` — before the entry file is read;
                 ``corrupt``/``truncate`` mangle the file on disk first,
                 exercising the quarantine path
``store.save``   ``TraceStore.save`` — after the atomic rename; ``corrupt``
                 /``truncate`` mangle the *persisted* entry (a simulated
                 mid-write crash surfaces at the next load)
``socket``       the admission daemon, once per parsed request line
===============  ============================================================

Fault kinds: ``raise`` (:class:`FaultError`, non-retryable — the
degradation ladder falls straight to the next rung), ``transient``
(:class:`TransientFaultError` — the ladder retries with backoff before
falling), ``hang`` (sleeps ``hang_s``; a deadline abandons the rung),
``corrupt`` (overwrites a byte range of ``path``), ``truncate`` (cuts
``path`` to half its size). Used by ``tests/test_faults.py`` and by
``ClusterSimulator.replay(faults=...)`` chaos mode.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Sequence


class FaultError(RuntimeError):
    """An injected, non-retryable failure."""


class TransientFaultError(FaultError):
    """An injected failure the caller may retry (backoff applies)."""


class ChaosSafetyViolation(AssertionError):
    """Chaos replay admitted a job whose true peak exceeds its device —
    the one outcome fault injection must never produce."""


@dataclasses.dataclass
class FaultSpec:
    """One scripted failure: fire ``times`` times at ``site``, skipping
    the first ``after`` hits. ``times=None`` fires on every hit."""

    site: str                   # "tracer" | "replay" | "store.load" | ...
    kind: str                   # "raise" | "transient" | "hang" | "corrupt" | "truncate"
    times: int | None = 1
    after: int = 0
    hang_s: float = 30.0
    message: str = ""

    _KINDS = ("raise", "transient", "hang", "corrupt", "truncate")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {self._KINDS})")


def _corrupt_file(path: str) -> None:
    """Overwrite a mid-file byte range with garbage (parse must fail)."""
    try:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.seek(max(size // 3, 0))
            f.write(b"\x00#corrupt#\x00" * 4)
    except OSError:
        pass


def _truncate_file(path: str) -> None:
    """Cut the file to half its size (a mid-write crash)."""
    try:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size // 2)
    except OSError:
        pass


class FaultPlan:
    """Thread-safe collection of :class:`FaultSpec`; counts every site
    hit and every fault actually fired (``stats()``)."""

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs = list(specs)
        self._lock = threading.Lock()
        self.hits: dict[str, int] = {}
        self.fired: dict[str, int] = {}
        self._spec_fired = [0] * len(self.specs)

    def add(self, *specs: FaultSpec) -> "FaultPlan":
        with self._lock:
            self.specs.extend(specs)
            self._spec_fired.extend([0] * len(specs))
        return self

    def _select(self, site: str) -> FaultSpec | None:
        """Pick the first applicable spec for this hit (under lock)."""
        hit = self.hits.get(site, 0)
        self.hits[site] = hit + 1
        for i, spec in enumerate(self.specs):
            if spec.site != site or hit < spec.after:
                continue
            if spec.times is not None and self._spec_fired[i] >= spec.times:
                continue
            self._spec_fired[i] += 1
            self.fired[site] = self.fired.get(site, 0) + 1
            return spec
        return None

    def check(self, site: str, path: str | None = None) -> None:
        """Fire any scripted fault for this ``site`` hit. File kinds
        need ``path``; without one they degrade to ``raise``."""
        with self._lock:
            spec = self._select(site)
        if spec is None:
            return
        msg = spec.message or f"injected {spec.kind} at {site}"
        if spec.kind == "hang":
            time.sleep(spec.hang_s)
            return
        if spec.kind in ("corrupt", "truncate"):
            if path is None:
                raise FaultError(msg + " (no path at this site)")
            (_corrupt_file if spec.kind == "corrupt"
             else _truncate_file)(path)
            return
        if spec.kind == "transient":
            raise TransientFaultError(msg)
        raise FaultError(msg)

    def stats(self) -> dict:
        with self._lock:
            return {"specs": len(self.specs), "hits": dict(self.hits),
                    "fired": dict(self.fired)}


def plan_raising_at(*sites: str, kind: str = "raise",
                    times: int | None = None) -> FaultPlan:
    """Shorthand for the common every-hit matrix rows in tests."""
    return FaultPlan([FaultSpec(site=s, kind=kind, times=times)
                      for s in sites])
