"""Estimation-as-a-service subsystem (ISSUE 4).

The paper's point is that a-priori CPU-only estimates let a cluster
scheduler make admission decisions without burning GPU time. This
package turns the one-shot estimator into that scheduler-facing
service:

* :mod:`repro.service.store` — disk-backed, content-addressed trace
  store layered under ``core/cache.py`` (schema-v3 columnar payloads,
  LRU + version invalidation) so warm estimates survive process
  restarts and are shared across workers;
* :mod:`repro.service.admission` — ``AdmissionRequest`` ->
  ``AdmissionDecision`` over a worker pool that reuses ``SweepService``;
* :mod:`repro.service.cluster` — a cluster-admission simulator that
  replays a job-arrival trace through the service and scores
  OOM/underutilization outcomes with the ``core/metrics.py`` two-round
  machinery;
* ``repro.launch.served`` — the line-JSON TCP daemon exposing the
  service to schedulers.

Rejections are not dead ends: a request carrying a structured
``meta["plan"]`` context (``repro.plan.PlanContext``) and decided via
``decide``/``submit`` comes back with ranked feasible counter-offers
(ISSUE 5; the batched ``decide_sweep`` path does not plan — one search
per rejected point would defeat the batching), and the cluster
simulator's ``retry_rejections`` round re-admits bounced jobs on their
best offer.

Failures are not dead ends either (ISSUE 6): a rung failure — tracer
raise, store corruption, estimate past its deadline budget — degrades
down the ladder in :mod:`repro.service.degrade` (exact -> sweep-log ->
analytic bound, widened margins) instead of propagating, and
:mod:`repro.service.faults` provides the injection harness the chaos
tests and ``ClusterSimulator.replay(faults=...)`` drive it with.
"""
from .admission import (AdmissionDecision, AdmissionRequest,  # noqa: F401
                        AdmissionService)
from .cluster import ClusterSimulator, JobArrival  # noqa: F401
from .degrade import (DecisionLog, DegradePolicy, RungTimeout,  # noqa: F401
                      RUNG_ANALYTIC, RUNG_EXACT, RUNG_SWEEP, RUNGS)
from .faults import (ChaosSafetyViolation, FaultError, FaultPlan,  # noqa: F401
                     FaultSpec, FLEET_SITES, TransientFaultError,
                     fleet_event, plan_raising_at)
from .store import TraceStore  # noqa: F401
