"""Cluster-admission simulator: replay a job-arrival trace through the
admission service and score outcomes (ISSUE 4 tentpole).

The paper validates estimates with a two-round protocol (§4.1.4): round
1 checks the OOM prediction on a full-capacity device (Eq. 1/4), round 2
re-runs with max runnable memory = the estimate (Eq. 5) and scores the
memory conserved (Eq. 7/8). This module replays a synthetic cluster's
arrival trace through :class:`~repro.service.admission.AdmissionService`
and aggregates exactly those metrics via ``core/metrics.py`` — the
scheduler-integration experiment a GPU cluster would run, done entirely
on CPU.

Each :class:`JobArrival` carries the job's callables, the capacity of
the device the scheduler would place it on, and optionally the "true"
peak (an oracle measurement, or a perturbed estimate for sensitivity
studies). Without a truth the estimator is scored against itself —
useful for exercising the admission logic (OOM rejections,
underutilization accounting) deterministically.

**Chaos mode (ISSUE 6)**: ``replay(faults=...)`` re-runs the trace with
a :class:`~repro.service.faults.FaultPlan` injected into the service —
the decisions-served-under-failure experiment. The summary gains
degradation accounting (``served`` / ``degraded`` / per-rung counts),
and a faulted replay that OOM-admits ANY job raises
:class:`~repro.service.faults.ChaosSafetyViolation`: the degradation
ladder's contract is that failures cost headroom, never safety.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

from ..core import metrics
from ..obs.metrics import Counter, CounterDict
from .admission import AdmissionDecision, AdmissionRequest, AdmissionService


@dataclasses.dataclass
class JobArrival:
    """One job in the arrival trace."""

    job_id: str
    fwd_bwd_fn: Callable
    params: Any
    batch: Any
    update_fn: Callable | None = None
    opt_init_fn: Callable | None = None
    capacity: int = 16 * 2**30
    truth_bytes: int | None = None      # oracle peak; None -> estimate
    family: str = "workload"
    device: str = "sim"
    arrival_s: float = 0.0
    # structured job description (repro.plan.PlanContext) — enables
    # counter-offers on rejection and the simulator's retry round
    plan: Any | None = None
    deadline_s: float | None = None     # per-job answer budget
    # fleet-scheduler fields (repro.sched, ISSUE 7): preemption rank and
    # how many arrival ticks the job occupies its device(s) before
    # departing (None = runs for the rest of the replay)
    priority: int = 0
    duration_ticks: int | None = None

    def request(self) -> AdmissionRequest:
        return AdmissionRequest(
            self.job_id, self.fwd_bwd_fn, self.params, self.batch,
            update_fn=self.update_fn, opt_init_fn=self.opt_init_fn,
            capacity=self.capacity, deadline_s=self.deadline_s,
            meta={"plan": self.plan} if self.plan is not None else {})


@dataclasses.dataclass
class ClusterOutcome:
    """Decisions + two-round records + headline summary."""

    decisions: list[AdmissionDecision]
    records: list[metrics.RunRecord]
    summary: dict
    # (job_id, CounterOffer) per job that was re-admitted on a
    # counter-offer during the retry round (ISSUE 5)
    retries: list = dataclasses.field(default_factory=list)

    def __iter__(self):
        return iter(zip(self.decisions, self.records))


class ClusterSimulator:
    """Replays arrivals through a service and scores the outcomes."""

    def __init__(self, service: AdmissionService,
                 truth_fn: Callable[[AdmissionDecision], int] | None = None):
        self.service = service
        self.truth_fn = truth_fn

    def replay(self, arrivals: Sequence[JobArrival],
               retry_rejections: bool = False, faults=None,
               deadline_s: float | None = None) -> ClusterOutcome:
        """Replay the arrival trace; with ``retry_rejections`` every
        rejection that came back with counter-offers (the arrival must
        carry a ``plan`` context) is re-submitted on its best offer, and
        the retry decision is what gets scored — the two-round metrics
        then quantify planning vs. plain rejection on the same trace.

        Truth accounting: ``truth_bytes`` describes the job *as
        requested*; a job re-admitted on a counter-offer runs a
        different plan, so its truth falls back to ``truth_fn`` (called
        on the retry decision) or to the offer's own estimate.

        Chaos mode: pass ``faults`` (a ``FaultPlan``) and optionally a
        per-job ``deadline_s`` default. The plan is injected for the
        duration of the replay; the returned summary reports how many
        decisions were served degraded and from which rung, and the
        replay RAISES ``ChaosSafetyViolation`` if any faulted decision
        OOM-admits — degraded answers must widen, never thin, the
        safety margin."""
        if faults is not None:
            with self.service.inject_faults(faults):
                return self._replay(arrivals, retry_rejections,
                                    deadline_s, chaos=True)
        return self._replay(arrivals, retry_rejections, deadline_s,
                            chaos=False)

    def _replay(self, arrivals: Sequence[JobArrival],
                retry_rejections: bool, deadline_s: float | None,
                chaos: bool) -> ClusterOutcome:
        t0 = time.perf_counter()
        decisions: list[AdmissionDecision] = []
        records: list[metrics.RunRecord] = []
        retries: list = []
        for job in arrivals:
            req = job.request()
            if req.deadline_s is None:
                req.deadline_s = deadline_s
            if not retry_rejections:
                # plain-rejection round: do not pay for a planner search
                # whose offers would be discarded anyway
                req.meta.pop("plan", None)
            d = self.service.decide(req)
            offer = None
            if retry_rejections and not d.admit and d.counter_offers \
                    and job.plan is not None:
                best = d.counter_offers[0]
                retry_req = best.admission_request(
                    job.plan.cfg, job.plan.policy, job.plan.shape,
                    capacity=job.capacity,
                    job_id=f"{job.job_id}+offer")
                # the retry must honor the same deadline contract as the
                # original decision — without this a hang fault on the
                # retry path would block the replay past every budget
                retry_req.deadline_s = (job.deadline_s
                                        if job.deadline_s is not None
                                        else deadline_s)
                retry = self.service.decide(retry_req)
                if retry.admit:
                    d, offer = retry, best
                    retries.append((job.job_id, best))
            truth = job.truth_bytes if offer is None else None
            if truth is None and self.truth_fn is not None:
                truth = self.truth_fn(d)
            if truth is None:
                truth = d.peak_bytes
            decisions.append(d)
            records.append(metrics.RunRecord(
                config=job.job_id, family=job.family,
                estimator="admission_service", device=job.device,
                capacity=job.capacity, estimate=d.peak_bytes,
                truth=int(truth), runtime_s=d.wall_s))
        wall = time.perf_counter() - t0
        summary = score(records)
        # per-replay chaos accounting through the registry counter
        # types (ISSUE 10): the summary keys/values stay bit-for-bit
        # with the old hand-rolled dict — CounterDict preserves
        # first-seen rung order and plain-int values
        served_c = Counter("xmem_replay_served_total")
        degraded_c = Counter("xmem_replay_degraded_total")
        rung_counts = CounterDict(name="xmem_replay_rung_total",
                                  label="rung")
        for d in decisions:
            served_c.inc()
            if d.degraded:
                degraded_c.inc()
            rung_counts.inc(d.rung)
        summary.update(
            wall_s=wall,
            replanned=len(retries),
            served=served_c.value,
            degraded=degraded_c.value,
            rungs=dict(rung_counts.items()),
            requests_per_s=(len(arrivals) / wall if wall > 0
                            and arrivals else 0.0))
        if chaos and summary["oom_admitted"]:
            from .faults import ChaosSafetyViolation
            bad = [r.config for r in records
                   if not r.oom_pred and r.oom_actual]
            raise ChaosSafetyViolation(
                f"chaos replay OOM-admitted {summary['oom_admitted']} "
                f"job(s) under fault injection: {bad}")
        return ClusterOutcome(decisions, records, summary, retries)


def score(records: Sequence[metrics.RunRecord]) -> dict:
    """Two-round scoring of an admission run (Eq. 3/6/8 plus scheduler
    outcome counts). ``oom_admitted`` are round-1 failures where the
    service admitted a job whose true peak exceeds the device
    (catastrophic for a scheduler); ``underutilized_rejected`` are jobs
    the service bounced although they would have fit (wasted capacity);
    ``round2_oom`` are admitted jobs whose true peak exceeds the
    estimate-as-threshold (Eq. 5 failures)."""
    admitted = [r for r in records if not r.oom_pred]
    rejected = [r for r in records if r.oom_pred]
    return {
        "jobs": len(records),
        "admitted": len(admitted),
        "rejected": len(rejected),
        "oom_admitted": sum(1 for r in admitted if r.oom_actual),
        "underutilized_rejected": sum(
            1 for r in rejected if not r.oom_actual),
        "round2_oom": sum(1 for r in admitted
                          if not r.oom_actual and r.oom_round2),
        "mre": metrics.mre(records),
        "pef": metrics.pef(records),
        "mcp_gb": metrics.mcp(records) / 1e9 if records else 0.0,
        "mean_runtime_s": metrics.mean_runtime(records),
    }
