"""Cluster-admission simulator: replay a job-arrival trace through the
admission service and score outcomes (ISSUE 4 tentpole).

The paper validates estimates with a two-round protocol (§4.1.4): round
1 checks the OOM prediction on a full-capacity device (Eq. 1/4), round 2
re-runs with max runnable memory = the estimate (Eq. 5) and scores the
memory conserved (Eq. 7/8). This module replays a synthetic cluster's
arrival trace through :class:`~repro.service.admission.AdmissionService`
and aggregates exactly those metrics via ``core/metrics.py`` — the
scheduler-integration experiment a GPU cluster would run, done entirely
on CPU.

Each :class:`JobArrival` carries the job's callables, the capacity of
the device the scheduler would place it on, and optionally the "true"
peak (an oracle measurement, or a perturbed estimate for sensitivity
studies). Without a truth the estimator is scored against itself —
useful for exercising the admission logic (OOM rejections,
underutilization accounting) deterministically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

from ..core import metrics
from .admission import AdmissionDecision, AdmissionRequest, AdmissionService


@dataclasses.dataclass
class JobArrival:
    """One job in the arrival trace."""

    job_id: str
    fwd_bwd_fn: Callable
    params: Any
    batch: Any
    update_fn: Callable | None = None
    opt_init_fn: Callable | None = None
    capacity: int = 16 * 2**30
    truth_bytes: int | None = None      # oracle peak; None -> estimate
    family: str = "workload"
    device: str = "sim"
    arrival_s: float = 0.0

    def request(self) -> AdmissionRequest:
        return AdmissionRequest(
            self.job_id, self.fwd_bwd_fn, self.params, self.batch,
            update_fn=self.update_fn, opt_init_fn=self.opt_init_fn,
            capacity=self.capacity)


@dataclasses.dataclass
class ClusterOutcome:
    """Decisions + two-round records + headline summary."""

    decisions: list[AdmissionDecision]
    records: list[metrics.RunRecord]
    summary: dict

    def __iter__(self):
        return iter(zip(self.decisions, self.records))


class ClusterSimulator:
    """Replays arrivals through a service and scores the outcomes."""

    def __init__(self, service: AdmissionService,
                 truth_fn: Callable[[AdmissionDecision], int] | None = None):
        self.service = service
        self.truth_fn = truth_fn

    def replay(self, arrivals: Sequence[JobArrival]) -> ClusterOutcome:
        t0 = time.perf_counter()
        decisions: list[AdmissionDecision] = []
        records: list[metrics.RunRecord] = []
        for job in arrivals:
            d = self.service.decide(job.request())
            truth = job.truth_bytes
            if truth is None and self.truth_fn is not None:
                truth = self.truth_fn(d)
            if truth is None:
                truth = d.peak_bytes
            decisions.append(d)
            records.append(metrics.RunRecord(
                config=job.job_id, family=job.family,
                estimator="admission_service", device=job.device,
                capacity=job.capacity, estimate=d.peak_bytes,
                truth=int(truth), runtime_s=d.wall_s))
        wall = time.perf_counter() - t0
        summary = score(records)
        summary.update(
            wall_s=wall,
            requests_per_s=(len(arrivals) / wall if wall > 0
                            and arrivals else 0.0))
        return ClusterOutcome(decisions, records, summary)


def score(records: Sequence[metrics.RunRecord]) -> dict:
    """Two-round scoring of an admission run (Eq. 3/6/8 plus scheduler
    outcome counts). ``oom_admitted`` are round-1 failures where the
    service admitted a job whose true peak exceeds the device
    (catastrophic for a scheduler); ``underutilized_rejected`` are jobs
    the service bounced although they would have fit (wasted capacity);
    ``round2_oom`` are admitted jobs whose true peak exceeds the
    estimate-as-threshold (Eq. 5 failures)."""
    admitted = [r for r in records if not r.oom_pred]
    rejected = [r for r in records if r.oom_pred]
    return {
        "jobs": len(records),
        "admitted": len(admitted),
        "rejected": len(rejected),
        "oom_admitted": sum(1 for r in admitted if r.oom_actual),
        "underutilized_rejected": sum(
            1 for r in rejected if not r.oom_actual),
        "round2_oom": sum(1 for r in admitted
                          if not r.oom_actual and r.oom_round2),
        "mre": metrics.mre(records),
        "pef": metrics.pef(records),
        "mcp_gb": metrics.mcp(records) / 1e9 if records else 0.0,
        "mean_runtime_s": metrics.mean_runtime(records),
    }
