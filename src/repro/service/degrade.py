"""Graceful-degradation ladder for admission decisions (ISSUE 6).

A production admission service must never turn "the estimator broke"
into "no admission decision": SchedTune-style historical predictors
degrade to coarse answers instead of failing, and xMem's service does
the same. The ladder has three rungs, tried in order:

1. **exact** — the normal columnar-replay estimate. Margin 1.0; the
   fault-free path is bit-identical to a direct estimator call.
2. **sweep** — a cached/interpolated point from the
   :class:`DecisionLog`: every successful exact decision records its
   (structural family, batch-bytes scalar, peak) triple, and a later
   failure on the same family answers from an affine fit over those
   points — the same piecewise-affine-in-batch structure the sweep
   service's exact interpolation exploits. Margin ``sweep_margin``.
3. **analytic** — a closed-form upper bound: from the job's
   ``PlanContext`` via :func:`repro.launch.analytic.analytic_peak_bytes`
   when the request carries one, else from the request avals alone
   (:func:`analytic_request_bound`), scaled by observed transient
   ratios when the log has any evidence. Margin ``analytic_margin``.

Degraded rungs multiply their raw estimate by a **widened safety
margin** (>1) before the admit comparison, per the paper's threshold
methodology: a degraded answer must stay OOM-safe, trading admission
headroom (possible underutilized-rejections) for zero OOM-admitted.
Every decision reports the rung that answered and the margin applied.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any

from ..obs import spans as obs_spans

#: Rung names, in degradation order.
RUNG_EXACT = "exact"
RUNG_SWEEP = "sweep"
RUNG_ANALYTIC = "analytic"
RUNGS = (RUNG_EXACT, RUNG_SWEEP, RUNG_ANALYTIC)

#: Transient-bytes-per-input-byte bound used by the aval-only analytic
#: rung when the decision log holds no evidence yet. Deliberately
#: conservative — a degraded overestimate costs headroom, a degraded
#: underestimate costs an OOM.
DEFAULT_TRANSIENT_RATIO = 64.0


@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """Knobs of the ladder (see module docstring)."""

    sweep_margin: float = 1.15      # widened margin for rung-2 answers
    analytic_margin: float = 1.50   # widened margin for rung-3 answers
    retries: int = 2                # rung-1 retries on transient faults
    backoff_s: float = 0.05         # first-retry backoff
    backoff_cap_s: float = 0.5      # exponential backoff cap
    jitter: float = 0.25            # +/- fraction of the backoff step
    default_deadline_s: float | None = None   # per-request budget

    def margin_for(self, rung: str) -> float:
        if rung == RUNG_SWEEP:
            return self.sweep_margin
        if rung == RUNG_ANALYTIC:
            return self.analytic_margin
        return 1.0


class RungTimeout(Exception):
    """A rung exceeded the request's deadline budget and was abandoned."""


# -- request fingerprints ----------------------------------------------------
def request_family(req) -> tuple | None:
    """Structural family of a request: the function identities plus the
    parameter avals and the batch *structure* (treedef, leaf ranks and
    dtypes — not the dims, which carry the sweep scalar). Two requests
    in one family differ only by batch sizing, the precondition for the
    rung-2 affine fit. None when the forward fn has no safe identity."""
    import jax
    from ..core.cache import _aval_sig, fn_identity

    ident = fn_identity(req.fwd_bwd_fn)
    if ident is None:
        return None
    idents = (ident,
              fn_identity(req.update_fn) if req.update_fn else None,
              fn_identity(req.opt_init_fn) if req.opt_init_fn else None)
    params_sig = tuple(_aval_sig(leaf) for leaf
                       in jax.tree_util.tree_leaves(req.params))
    batch_leaves = jax.tree_util.tree_leaves(req.batch)
    batch_sig = (str(jax.tree_util.tree_structure(req.batch)),
                 tuple((len(getattr(l, "shape", ())),
                        str(getattr(l, "dtype", None)))
                       for l in batch_leaves))
    # per-device execution models must not cross-pollinate families;
    # neither may offload plans — an offloaded peak is lower, and using
    # it as evidence for a non-offload request would under-answer.
    # Serving knobs separate too: a paged fp8 small-page peak is no
    # evidence for a monolithic bf16 request (ISSUE 9)
    shard_sig = (req.shard_factor_fn is not None,
                 bool(req.collective_specs),
                 getattr(req, "offload", None),
                 getattr(req, "serving", None))
    return (idents, params_sig, batch_sig, shard_sig)


def _tree_bytes(tree) -> int:
    import jax
    from ..core.tracer import aval_bytes
    return sum(aval_bytes(l) for l in jax.tree_util.tree_leaves(tree))


def request_scalar(req) -> int:
    """The 1-D sweep scalar of a request: total batch input bytes."""
    return _tree_bytes(req.batch)


@dataclasses.dataclass
class _LogPoint:
    scalar: int
    peak: int
    persistent: int


class DecisionLog:
    """Rung-2 evidence: recent exact decisions per structural family.

    Thread-safe; bounded per family (newest points win). ``lookup``
    answers a scalar from the family's points — exact cached hit,
    affine interpolation through the two nearest points, or
    transient-proportional scaling from a single point."""

    def __init__(self, max_families: int = 64,
                 max_points_per_family: int = 32):
        self.max_families = max_families
        self.max_points = max_points_per_family
        self._lock = threading.Lock()
        self._data: dict[tuple, dict[int, _LogPoint]] = {}
        # global transient evidence for the analytic rung
        self.max_transient_ratio = 0.0
        self.max_persistent = 0
        self.records = 0

    def record(self, family: tuple | None, scalar: int, peak: int,
               persistent: int) -> None:
        if family is None:
            return
        with self._lock:
            pts = self._data.get(family)
            if pts is None:
                if len(self._data) >= self.max_families:
                    self._data.pop(next(iter(self._data)))
                pts = self._data[family] = {}
            pts[scalar] = _LogPoint(scalar, peak, persistent)
            while len(pts) > self.max_points:
                pts.pop(next(iter(pts)))
            if scalar > 0:
                ratio = max(peak - persistent, 0) / scalar
                if ratio > self.max_transient_ratio:
                    self.max_transient_ratio = ratio
            if persistent > self.max_persistent:
                self.max_persistent = persistent
            self.records += 1

    def lookup(self, family: tuple | None, scalar: int
               ) -> tuple[int, str] | None:
        """Raw (un-margined) peak for ``scalar`` from this family's
        evidence, plus how it was derived ("cached" / "interpolated" /
        "scaled"). None when the family has no points."""
        if family is None:
            return None
        with self._lock:
            pts = self._data.get(family)
            if not pts:
                obs_spans.event("decision_log.miss")
                return None
            points = sorted(pts.values(), key=lambda p: p.scalar)
        exact = next((p for p in points if p.scalar == scalar), None)
        if exact is not None:
            obs_spans.event("decision_log.hit", derived="cached")
            return exact.peak, "cached"
        if len(points) >= 2:
            # the two nearest points bracket (or best-effort flank) the
            # query; peak is piecewise affine in batch bytes, so a line
            # through them is the sweep-service interpolation done coarse
            lo = max((p for p in points if p.scalar <= scalar),
                     key=lambda p: p.scalar, default=points[0])
            hi = min((p for p in points if p.scalar >= scalar),
                     key=lambda p: p.scalar, default=points[-1])
            if lo.scalar == hi.scalar:
                lo = points[0] if hi is not points[0] else points[1]
            slope = (hi.peak - lo.peak) / (hi.scalar - lo.scalar)
            peak = lo.peak + slope * (scalar - lo.scalar)
            floor = max(lo.persistent, hi.persistent)
            obs_spans.event("decision_log.hit", derived="interpolated")
            return max(int(peak), floor), "interpolated"
        p = points[0]
        obs_spans.event("decision_log.hit", derived="scaled")
        if p.scalar <= 0:
            return p.peak, "scaled"
        # one point: persistent stays, transients scale with the batch
        transient = max(p.peak - p.persistent, 0)
        peak = p.persistent + int(transient * (scalar / p.scalar))
        return max(peak, p.persistent), "scaled"

    def stats(self) -> dict:
        with self._lock:
            return {"families": len(self._data),
                    "points": sum(len(v) for v in self._data.values()),
                    "records": self.records,
                    "max_transient_ratio": round(
                        self.max_transient_ratio, 3)}


# -- rung 3: analytic upper bounds -------------------------------------------
def analytic_request_bound(req, log: DecisionLog | None = None) -> int:
    """Closed-form peak upper bound from the request alone.

    With a ``meta["plan"]`` context the bound comes from the config-
    level roofline accounting (``launch/analytic.analytic_peak_bytes``
    — full activation materialization, no remat credit). Without one,
    from the avals: params + grads + fp32 optimizer moments + a
    conservative transient-per-input-byte ratio (the log's observed
    maximum when any exact decision has landed, else
    ``DEFAULT_TRANSIENT_RATIO``)."""
    ctx = req.meta.get("plan") if req.meta else None
    if ctx is not None and all(
            hasattr(ctx, a) for a in ("cfg", "policy", "shape")):
        from ..launch.analytic import analytic_peak_bytes
        return analytic_peak_bytes(
            ctx.cfg, ctx.shape,
            microbatches=getattr(ctx.policy, "microbatches", 1) or 1,
            with_optimizer=req.opt_init_fn is not None
            or req.update_fn is not None)
    import jax
    import numpy as np
    p_bytes = 0
    n_params = 0
    for leaf in jax.tree_util.tree_leaves(req.params):
        shape = tuple(getattr(leaf, "shape", ()))
        n = int(np.prod(shape)) if shape else 1
        dt = np.dtype(getattr(leaf, "dtype", np.float32))
        p_bytes += n * dt.itemsize
        n_params += n
    in_bytes = _tree_bytes(req.batch)
    grads = p_bytes if req.update_fn is not None else 0
    # two fp32 moments per parameter (Adam-family worst case)
    opt = 2 * 4 * n_params if req.opt_init_fn is not None else 0
    ratio = DEFAULT_TRANSIENT_RATIO
    if log is not None and log.records:
        # observed evidence, widened: the largest transient ratio any
        # exact decision exhibited (margin is applied by the caller)
        ratio = max(log.max_transient_ratio * 2.0, 4.0)
    return int(p_bytes + grads + opt + in_bytes
               + ratio * max(in_bytes, 1))


def backoff_delays(policy: DegradePolicy, seed: str) -> list[float]:
    """Capped exponential backoff schedule with deterministic jitter
    (seeded by the job id, so replays are reproducible)."""
    import random
    rng = random.Random(seed)
    out = []
    for attempt in range(policy.retries):
        base = min(policy.backoff_s * (2 ** attempt), policy.backoff_cap_s)
        out.append(base * (1.0 + policy.jitter * (2 * rng.random() - 1)))
    return out
