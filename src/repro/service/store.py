"""Disk-backed, content-addressed trace store (ISSUE 4 tentpole).

Layered *under* ``core/cache.py``: a :class:`~repro.core.cache.TraceCache`
constructed with ``store=TraceStore(dir)`` looks content-addressed keys
up on disk after a memory miss and writes fresh traces through, so warm
estimates survive process restarts and are shared across workers (the
admission daemon's workers, sweep pool parents, separate gate
processes).

On-disk format: one JSON file per entry, named by the stable sha256 of
the full trace key (function content digest + avals + treedefs + kinds +
scan cap + phase + tag — see ``cache.stable_key_digest``). The payload
is the schema-v3 **columnar** trace format (``ColumnarTrace`` /
``ColumnarBlocks`` ``to_json``, shape tables included), plus the
input/output block summaries, the abstract output pytree and the
memoized coupling verdict. ``closed_jaxpr`` is never persisted — the
coupling verdict is resolved *before* writing (exactly like sweep pool
payloads), so a restored update phase needs no jaxpr.

Invalidation: every file records ``store_version`` and the trace schema
version; a mismatch on load reports a miss. LRU: the store keeps at
most ``max_entries`` files, evicting by mtime (loads touch the file's
mtime, so recently served entries survive).

Crash safety (ISSUE 6): writes go to a **unique** temp file that is
fsynced and atomically renamed over the entry (two concurrent saves of
the same digest can no longer clobber each other's in-flight temp —
last rename wins, both files were complete). Anything unreadable —
truncated JSON, zero-byte files, wrong schema version, foreign payloads
— is moved to ``<dir>/quarantine/`` rather than deleted, so corruption
evidence survives for inspection while the store keeps serving (the
entry just misses and is re-traced). ``__init__`` runs a startup
recovery scan: orphaned ``*.tmp`` files from mid-write crashes and
zero-byte entries are quarantined immediately and reported via
``recovery`` / ``stats()``. An optional :class:`~repro.service.faults.
FaultPlan` (``faults=``) fires at ``store.load`` / ``store.save`` for
chaos testing.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time

import numpy as np

from ..obs import spans as obs_spans
from ..core.cache import BlockInfo, TracedPhase, stable_key_digest
from ..core.events import (BlockKind, ColumnarBlocks, ColumnarTrace, Trace,
                           TRACE_SCHEMA_VERSION)

#: Bump to invalidate every persisted entry (payload layout changes).
STORE_VERSION = 1

_PREFIX = "xm_"


class StoreUnserializable(Exception):
    """Entry contains values the store cannot round-trip losslessly."""


# -- abstract output pytree <-> JSON -----------------------------------------
def _tree_to_json(tree):
    """Serialize an abstract output pytree built from dicts / tuples /
    lists / None with ShapeDtypeStruct-like leaves. Anything else raises
    ``StoreUnserializable`` (the entry is then simply not persisted)."""
    if tree is None:
        return {"t": "none"}
    if isinstance(tree, dict):
        items = []
        for k, v in tree.items():
            if isinstance(k, str):
                kj = ["s", k]
            elif isinstance(k, int):
                kj = ["i", k]
            else:
                raise StoreUnserializable(f"dict key {k!r}")
            items.append([kj, _tree_to_json(v)])
        return {"t": "dict", "items": items}
    if isinstance(tree, tuple):
        return {"t": "tuple", "items": [_tree_to_json(v) for v in tree]}
    if isinstance(tree, list):
        return {"t": "list", "items": [_tree_to_json(v) for v in tree]}
    shape = getattr(tree, "shape", None)
    dtype = getattr(tree, "dtype", None)
    if shape is not None and dtype is not None:
        return {"t": "leaf", "shape": [int(d) for d in shape],
                "dtype": str(dtype)}
    raise StoreUnserializable(f"pytree node {type(tree)!r}")


def _tree_from_json(d):
    import jax
    t = d["t"]
    if t == "none":
        return None
    if t == "dict":
        out = {}
        for (kt, k), vj in d["items"]:
            out[k if kt == "s" else int(k)] = _tree_from_json(vj)
        return out
    if t == "tuple":
        return tuple(_tree_from_json(v) for v in d["items"])
    if t == "list":
        return [_tree_from_json(v) for v in d["items"]]
    return jax.ShapeDtypeStruct(tuple(d["shape"]), np.dtype(d["dtype"]))


def _blocks_to_json(blocks) -> list:
    return [[b.bid, b.size, b.kind.value,
             None if b.shape is None else list(b.shape)] for b in blocks]


def _blocks_from_json(rows) -> tuple:
    return tuple(BlockInfo(int(bid), int(size), BlockKind(kind),
                           None if shape is None else tuple(shape))
                 for bid, size, kind, shape in rows)


def phase_to_json(entry: TracedPhase) -> dict:
    """Payload dict for one ``TracedPhase`` (coupling must already be
    resolved for update phases — the store does that in ``save``)."""
    meta = {k: v for k, v in entry.trace.meta.items() if k != "_columns"}
    try:
        json.dumps(meta)
    except (TypeError, ValueError):
        meta = {}
    return {
        "trace": {
            "columns": entry.trace.columnar().to_json(),
            "num_iterations": entry.trace.num_iterations,
            "meta": meta,
        },
        "lifecycles": ColumnarBlocks.from_lifecycles(
            entry.lifecycles).to_json(),
        "input_blocks": _blocks_to_json(entry.input_blocks),
        "output_blocks": _blocks_to_json(entry.output_blocks),
        "out_shape": _tree_to_json(entry.out_shape),
        "arg_leaf_counts": list(entry.arg_leaf_counts),
        "coupling": entry.coupling,
    }


def phase_from_json(d: dict) -> TracedPhase:
    trace = Trace.from_columnar(
        ColumnarTrace.from_json(d["trace"]["columns"]),
        num_iterations=d["trace"]["num_iterations"],
        meta=d["trace"].get("meta", {}))
    return TracedPhase(
        trace=trace,
        lifecycles=tuple(
            ColumnarBlocks.from_json(d["lifecycles"]).to_lifecycles()),
        input_blocks=_blocks_from_json(d["input_blocks"]),
        output_blocks=_blocks_from_json(d["output_blocks"]),
        out_shape=_tree_from_json(d["out_shape"]),
        closed_jaxpr=None,          # never persisted
        arg_leaf_counts=tuple(d["arg_leaf_counts"]),
        coupling=d.get("coupling"),
    )


class TraceStore:
    """Content-addressed persistent trace store (see module docstring).

    Duck-typed for ``TraceCache(store=...)``: ``load(key)``,
    ``save(key, entry)``, ``stats()``.
    """

    QUARANTINE_DIR = "quarantine"

    def __init__(self, directory: str, max_entries: int = 256,
                 faults=None):
        self.directory = directory
        self.max_entries = max_entries
        self.faults = faults        # optional FaultPlan (chaos testing)
        self._lock = threading.RLock()
        self.loads = 0
        self.saves = 0
        self.load_misses = 0
        self.invalidated = 0
        self.quarantined = 0
        self._qseq = 0
        os.makedirs(directory, exist_ok=True)
        self.recovery = self._recover()

    # -- paths ---------------------------------------------------------------
    def path_for(self, key: tuple) -> str:
        return os.path.join(self.directory,
                            _PREFIX + stable_key_digest(key) + ".json")

    @property
    def quarantine_path(self) -> str:
        return os.path.join(self.directory, self.QUARANTINE_DIR)

    def _entries(self) -> list[str]:
        try:
            names = os.listdir(self.directory)
        except OSError:
            return []
        return [os.path.join(self.directory, n) for n in names
                if n.startswith(_PREFIX) and n.endswith(".json")]

    def __len__(self) -> int:
        return len(self._entries())

    # -- quarantine & recovery ----------------------------------------------
    def _quarantine(self, path: str, reason: str) -> str | None:
        """Move a bad file into the quarantine directory (never delete
        evidence). Returns the destination, or None if the file was
        already gone (e.g. a racing quarantine won)."""
        with self._lock:
            self._qseq += 1
            seq = self._qseq
        dest = os.path.join(
            self.quarantine_path,
            f"{seq:04d}.{os.getpid()}.{reason}.{os.path.basename(path)}")
        try:
            os.makedirs(self.quarantine_path, exist_ok=True)
            os.replace(path, dest)
        except OSError:
            return None
        with self._lock:
            self.quarantined += 1
        obs_spans.event("store.quarantine", reason=reason)
        return dest

    def _recover(self) -> dict:
        """Startup scan: quarantine mid-write leftovers (``*.tmp``) and
        zero-byte entries so a crashed writer cannot poison later loads.
        Deeper corruption (truncated JSON, wrong version) is detected —
        and quarantined — lazily by ``load``; scanning is O(names), not
        O(bytes)."""
        report = {"scanned": 0, "quarantined_tmp": 0,
                  "quarantined_empty": 0}
        try:
            names = os.listdir(self.directory)
        except OSError:
            return report
        for name in names:
            path = os.path.join(self.directory, name)
            if name.endswith(".tmp"):
                report["scanned"] += 1
                if self._quarantine(path, "orphan-tmp"):
                    report["quarantined_tmp"] += 1
                continue
            if name.startswith(_PREFIX) and name.endswith(".json"):
                report["scanned"] += 1
                try:
                    empty = os.path.getsize(path) == 0
                except OSError:
                    continue
                if empty and self._quarantine(path, "zero-byte"):
                    report["quarantined_empty"] += 1
        return report

    # -- load / save ---------------------------------------------------------
    def load(self, key: tuple) -> TracedPhase | None:
        # the file read + JSON parse + columnar decode run WITHOUT the
        # lock (concurrent workers warming from disk must not serialize
        # behind each other); only counters and quarantine moves lock
        path = self.path_for(key)
        if self.faults is not None:
            self.faults.check("store.load", path=path)
        try:
            with obs_spans.span("store.load"), open(path) as f:
                d = json.load(f)
        except OSError:             # absent: a plain miss, no evidence
            with self._lock:
                self.load_misses += 1
            return None
        except ValueError:          # unparseable: quarantine the bytes
            self._quarantine(path, "bad-json")
            with self._lock:
                self.invalidated += 1
                self.load_misses += 1
            return None
        # trace schema v3/v4 entries load compatibly (v3: the space
        # column defaults every event to DEVICE_HBM — code 0; v4: same
        # payload columns as v5, the bump marks the request-driven
        # composition era, not a format change) — all bit-identical.
        # Anything newer or older still quarantines, so a v5 entry read
        # by an older (v4-max) build quarantines symmetrically.
        if (d.get("store_version") != STORE_VERSION
                or d.get("trace_schema")
                not in (3, 4, TRACE_SCHEMA_VERSION)):
            self._quarantine(path, "version")
            with self._lock:
                self.invalidated += 1
                self.load_misses += 1
            return None
        try:
            entry = phase_from_json(d["phase"])
        except Exception:   # noqa: BLE001 — corrupt/foreign payload
            self._quarantine(path, "bad-payload")
            with self._lock:
                self.invalidated += 1
                self.load_misses += 1
            return None
        try:
            os.utime(path)          # LRU touch
        except OSError:
            pass
        with self._lock:
            self.loads += 1
        return entry

    def save(self, key: tuple, entry: TracedPhase) -> None:
        # resolve the coupling verdict NOW, while the jaxpr is still
        # around — a restored update phase has no jaxpr to analyze
        if entry.coupling is None and entry.closed_jaxpr is not None \
                and key[1] == "upd":
            from ..core.estimator import _coupling_from_jaxpr
            entry.coupling = _coupling_from_jaxpr(
                entry.closed_jaxpr.jaxpr, entry.arg_leaf_counts[0],
                entry.arg_leaf_counts[1])
        try:
            payload = phase_to_json(entry)
        except StoreUnserializable:
            return
        d = {
            "store_version": STORE_VERSION,
            "trace_schema": TRACE_SCHEMA_VERSION,
            "saved_at": time.time(),
            "tag": key[1],
            "phase": payload,
        }
        path = self.path_for(key)
        # crash-safe write OUTSIDE the lock: a unique temp name per
        # writer (mkstemp), fsync before the atomic rename, then a
        # directory fsync so the rename itself survives a crash.
        # Concurrent saves of one digest each complete their own temp
        # file; whichever renames last wins — no writer ever touches
        # another writer's temp file.
        tmp = None
        try:
            with obs_spans.span("store.save"):
                fd, tmp = tempfile.mkstemp(
                    dir=self.directory,
                    prefix=_PREFIX + "w", suffix=".tmp")
                with os.fdopen(fd, "w") as f:
                    json.dump(d, f)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, path)
                tmp = None
                self._fsync_dir()
        except OSError:
            if tmp is not None:
                self._remove(tmp)   # our own temp only
            return
        if self.faults is not None:
            # simulated mid-write crash: mangle the *persisted* entry so
            # the damage surfaces at the next load (quarantine path)
            self.faults.check("store.save", path=path)
        with self._lock:
            self.saves += 1
            self._evict_lru()

    def _fsync_dir(self) -> None:
        try:
            dfd = os.open(self.directory, os.O_RDONLY)
        except OSError:
            return
        try:
            os.fsync(dfd)
        except OSError:
            pass
        finally:
            os.close(dfd)

    def _remove(self, path: str) -> None:
        try:
            os.remove(path)
        except OSError:
            pass

    def _evict_lru(self) -> None:
        entries = self._entries()
        if len(entries) <= self.max_entries:
            return
        def mtime(p):
            try:
                return os.path.getmtime(p)
            except OSError:
                return 0.0
        entries.sort(key=mtime)
        for p in entries[:len(entries) - self.max_entries]:
            self._remove(p)

    def clear(self) -> None:
        with self._lock:
            for p in self._entries():
                self._remove(p)

    def stats(self) -> dict:
        return {"dir": self.directory, "entries": len(self),
                "max_entries": self.max_entries, "loads": self.loads,
                "load_misses": self.load_misses, "saves": self.saves,
                "invalidated": self.invalidated,
                "quarantined": self.quarantined,
                "recovery": dict(self.recovery),
                "store_version": STORE_VERSION}
