"""Disk-backed, content-addressed trace store (ISSUE 4 tentpole).

Layered *under* ``core/cache.py``: a :class:`~repro.core.cache.TraceCache`
constructed with ``store=TraceStore(dir)`` looks content-addressed keys
up on disk after a memory miss and writes fresh traces through, so warm
estimates survive process restarts and are shared across workers (the
admission daemon's workers, sweep pool parents, separate gate
processes).

On-disk format: one JSON file per entry, named by the stable sha256 of
the full trace key (function content digest + avals + treedefs + kinds +
scan cap + phase + tag — see ``cache.stable_key_digest``). The payload
is the schema-v3 **columnar** trace format (``ColumnarTrace`` /
``ColumnarBlocks`` ``to_json``, shape tables included), plus the
input/output block summaries, the abstract output pytree and the
memoized coupling verdict. ``closed_jaxpr`` is never persisted — the
coupling verdict is resolved *before* writing (exactly like sweep pool
payloads), so a restored update phase needs no jaxpr.

Invalidation: every file records ``store_version`` and the trace schema
version; a mismatch on load deletes the file and reports a miss. LRU:
the store keeps at most ``max_entries`` files, evicting by mtime (loads
touch the file's mtime, so recently served entries survive).
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time

import numpy as np

from ..core.cache import BlockInfo, TracedPhase, stable_key_digest
from ..core.events import (BlockKind, ColumnarBlocks, ColumnarTrace, Trace,
                           TRACE_SCHEMA_VERSION)

#: Bump to invalidate every persisted entry (payload layout changes).
STORE_VERSION = 1

_PREFIX = "xm_"


class StoreUnserializable(Exception):
    """Entry contains values the store cannot round-trip losslessly."""


# -- abstract output pytree <-> JSON -----------------------------------------
def _tree_to_json(tree):
    """Serialize an abstract output pytree built from dicts / tuples /
    lists / None with ShapeDtypeStruct-like leaves. Anything else raises
    ``StoreUnserializable`` (the entry is then simply not persisted)."""
    if tree is None:
        return {"t": "none"}
    if isinstance(tree, dict):
        items = []
        for k, v in tree.items():
            if isinstance(k, str):
                kj = ["s", k]
            elif isinstance(k, int):
                kj = ["i", k]
            else:
                raise StoreUnserializable(f"dict key {k!r}")
            items.append([kj, _tree_to_json(v)])
        return {"t": "dict", "items": items}
    if isinstance(tree, tuple):
        return {"t": "tuple", "items": [_tree_to_json(v) for v in tree]}
    if isinstance(tree, list):
        return {"t": "list", "items": [_tree_to_json(v) for v in tree]}
    shape = getattr(tree, "shape", None)
    dtype = getattr(tree, "dtype", None)
    if shape is not None and dtype is not None:
        return {"t": "leaf", "shape": [int(d) for d in shape],
                "dtype": str(dtype)}
    raise StoreUnserializable(f"pytree node {type(tree)!r}")


def _tree_from_json(d):
    import jax
    t = d["t"]
    if t == "none":
        return None
    if t == "dict":
        out = {}
        for (kt, k), vj in d["items"]:
            out[k if kt == "s" else int(k)] = _tree_from_json(vj)
        return out
    if t == "tuple":
        return tuple(_tree_from_json(v) for v in d["items"])
    if t == "list":
        return [_tree_from_json(v) for v in d["items"]]
    return jax.ShapeDtypeStruct(tuple(d["shape"]), np.dtype(d["dtype"]))


def _blocks_to_json(blocks) -> list:
    return [[b.bid, b.size, b.kind.value,
             None if b.shape is None else list(b.shape)] for b in blocks]


def _blocks_from_json(rows) -> tuple:
    return tuple(BlockInfo(int(bid), int(size), BlockKind(kind),
                           None if shape is None else tuple(shape))
                 for bid, size, kind, shape in rows)


def phase_to_json(entry: TracedPhase) -> dict:
    """Payload dict for one ``TracedPhase`` (coupling must already be
    resolved for update phases — the store does that in ``save``)."""
    meta = {k: v for k, v in entry.trace.meta.items() if k != "_columns"}
    try:
        json.dumps(meta)
    except (TypeError, ValueError):
        meta = {}
    return {
        "trace": {
            "columns": entry.trace.columnar().to_json(),
            "num_iterations": entry.trace.num_iterations,
            "meta": meta,
        },
        "lifecycles": ColumnarBlocks.from_lifecycles(
            entry.lifecycles).to_json(),
        "input_blocks": _blocks_to_json(entry.input_blocks),
        "output_blocks": _blocks_to_json(entry.output_blocks),
        "out_shape": _tree_to_json(entry.out_shape),
        "arg_leaf_counts": list(entry.arg_leaf_counts),
        "coupling": entry.coupling,
    }


def phase_from_json(d: dict) -> TracedPhase:
    trace = Trace.from_columnar(
        ColumnarTrace.from_json(d["trace"]["columns"]),
        num_iterations=d["trace"]["num_iterations"],
        meta=d["trace"].get("meta", {}))
    return TracedPhase(
        trace=trace,
        lifecycles=tuple(
            ColumnarBlocks.from_json(d["lifecycles"]).to_lifecycles()),
        input_blocks=_blocks_from_json(d["input_blocks"]),
        output_blocks=_blocks_from_json(d["output_blocks"]),
        out_shape=_tree_from_json(d["out_shape"]),
        closed_jaxpr=None,          # never persisted
        arg_leaf_counts=tuple(d["arg_leaf_counts"]),
        coupling=d.get("coupling"),
    )


class TraceStore:
    """Content-addressed persistent trace store (see module docstring).

    Duck-typed for ``TraceCache(store=...)``: ``load(key)``,
    ``save(key, entry)``, ``stats()``.
    """

    def __init__(self, directory: str, max_entries: int = 256):
        self.directory = directory
        self.max_entries = max_entries
        self._lock = threading.RLock()
        self.loads = 0
        self.saves = 0
        self.load_misses = 0
        self.invalidated = 0
        os.makedirs(directory, exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def path_for(self, key: tuple) -> str:
        return os.path.join(self.directory,
                            _PREFIX + stable_key_digest(key) + ".json")

    def _entries(self) -> list[str]:
        try:
            names = os.listdir(self.directory)
        except OSError:
            return []
        return [os.path.join(self.directory, n) for n in names
                if n.startswith(_PREFIX) and n.endswith(".json")]

    def __len__(self) -> int:
        return len(self._entries())

    # -- load / save ---------------------------------------------------------
    def load(self, key: tuple) -> TracedPhase | None:
        # the file read + JSON parse + columnar decode run WITHOUT the
        # lock (concurrent workers warming from disk must not serialize
        # behind each other); only counters and file removal lock
        path = self.path_for(key)
        try:
            with open(path) as f:
                d = json.load(f)
        except (OSError, ValueError):
            with self._lock:
                self.load_misses += 1
            return None
        if (d.get("store_version") != STORE_VERSION
                or d.get("trace_schema") != TRACE_SCHEMA_VERSION):
            with self._lock:
                self._remove(path)
                self.invalidated += 1
                self.load_misses += 1
            return None
        try:
            entry = phase_from_json(d["phase"])
        except Exception:   # noqa: BLE001 — corrupt/foreign payload
            with self._lock:
                self._remove(path)
                self.invalidated += 1
                self.load_misses += 1
            return None
        try:
            os.utime(path)          # LRU touch
        except OSError:
            pass
        with self._lock:
            self.loads += 1
        return entry

    def save(self, key: tuple, entry: TracedPhase) -> None:
        # resolve the coupling verdict NOW, while the jaxpr is still
        # around — a restored update phase has no jaxpr to analyze
        if entry.coupling is None and entry.closed_jaxpr is not None \
                and key[1] == "upd":
            from ..core.estimator import _coupling_from_jaxpr
            entry.coupling = _coupling_from_jaxpr(
                entry.closed_jaxpr.jaxpr, entry.arg_leaf_counts[0],
                entry.arg_leaf_counts[1])
        try:
            payload = phase_to_json(entry)
        except StoreUnserializable:
            return
        d = {
            "store_version": STORE_VERSION,
            "trace_schema": TRACE_SCHEMA_VERSION,
            "saved_at": time.time(),
            "tag": key[1],
            "phase": payload,
        }
        path = self.path_for(key)
        with self._lock:
            tmp = None
            try:
                fd, tmp = tempfile.mkstemp(dir=self.directory,
                                           suffix=".tmp")
                with os.fdopen(fd, "w") as f:
                    json.dump(d, f)
                os.replace(tmp, path)
            except OSError:
                if tmp is not None:
                    self._remove(tmp)   # no orphaned .tmp accumulation
                return
            self.saves += 1
            self._evict_lru()

    def _remove(self, path: str) -> None:
        try:
            os.remove(path)
        except OSError:
            pass

    def _evict_lru(self) -> None:
        entries = self._entries()
        if len(entries) <= self.max_entries:
            return
        def mtime(p):
            try:
                return os.path.getmtime(p)
            except OSError:
                return 0.0
        entries.sort(key=mtime)
        for p in entries[:len(entries) - self.max_entries]:
            self._remove(p)

    def clear(self) -> None:
        with self._lock:
            for p in self._entries():
                self._remove(p)

    def stats(self) -> dict:
        return {"dir": self.directory, "entries": len(self),
                "max_entries": self.max_entries, "loads": self.loads,
                "load_misses": self.load_misses, "saves": self.saves,
                "invalidated": self.invalidated,
                "store_version": STORE_VERSION}
