"""Trace cache — stage-1 memoization for the estimation fast path.

``estimate_training`` costs are dominated by re-tracing: every call runs
``jax.make_jaxpr`` plus eqn-by-eqn interpretation for each phase even
when the job's *structure* is unchanged. Repeated-call workloads
(hillclimb batch-size search, ``calibrate()`` loops, benchmark sweeps,
per-job admission gating in ``launch/train.py``) therefore pay the full
tracing cost over and over.

This module caches the complete per-phase tracing product — the event
stream, the reconstructed lifecycles, the input/output block summaries
and the abstract output pytree — keyed on

    (function identity, input avals + treedefs, arg kinds,
     scan_unroll_cap, phase, call-site tag)

Function identity is held as a *weak* reference: a cache hit requires
the stored function object to still be the one presented (guards
against ``id()`` reuse after garbage collection). Entries are immutable
by contract — consumers copy (``dataclasses.replace``) before rewriting
lifecycles, exactly as the Orchestrator already does.

The default process-global cache (``GLOBAL_TRACE_CACHE``) is shared by
every ``XMemEstimator`` unless an instance-specific cache is supplied,
so independent estimator instances created per admission decision still
share warm traces.
"""
from __future__ import annotations

import dataclasses
import weakref
from collections import OrderedDict
from typing import Any, Sequence

from .events import BlockKind, BlockLifecycle, Trace


@dataclasses.dataclass(frozen=True)
class BlockInfo:
    """Lightweight summary of a tracer input/output block. ``shape``
    feeds the spec-driven sharding engine (None = unknown)."""

    bid: int
    size: int
    kind: BlockKind
    shape: tuple | None = None


@dataclasses.dataclass
class TracedPhase:
    """Everything downstream stages need from one phase trace.

    Treat every field as immutable: entries are shared across estimate
    calls. ``lifecycles`` are copied (``dataclasses.replace``) by the
    composer before any rewrite.
    """

    trace: Trace
    lifecycles: tuple[BlockLifecycle, ...]
    input_blocks: tuple[BlockInfo, ...]
    output_blocks: tuple[BlockInfo, ...]
    out_shape: Any                   # abstract output pytree (eval_shape-like)
    closed_jaxpr: Any                # for taint/coupling analysis
    arg_leaf_counts: tuple[int, ...]
    coupling: dict | None = None     # memoized update-coupling verdict

    @property
    def num_events(self) -> int:
        return len(self.trace.events)


#: numpy dtype __str__ walks the type registry on every call — at ~150
#: leaves per trace key that dominated warm estimates, so the string form
#: is memoized per dtype object (dtypes are interned by numpy/jax).
_DTYPE_STR: dict = {}


def _aval_sig(leaf) -> tuple:
    shape = tuple(getattr(leaf, "shape", ()))
    dtype = getattr(leaf, "dtype", None)
    s = _DTYPE_STR.get(dtype)
    if s is None:
        s = _DTYPE_STR[dtype] = str(dtype)
    return (shape, s)


def trace_key(fn, tag: str, flat_leaves: Sequence, treedefs: tuple,
              kinds: Sequence[BlockKind], scan_unroll_cap: int,
              phase) -> tuple | None:
    """Build a cache key, or None when ``fn`` cannot be weak-referenced
    (no safe identity check is possible then, so caching is skipped)."""
    try:
        weakref.ref(fn)
    except TypeError:
        return None
    return (
        id(fn), tag,
        tuple(_aval_sig(leaf) for leaf in flat_leaves),
        tuple(treedefs),                   # jax treedefs hash/compare fast
        tuple(k.value for k in kinds),
        scan_unroll_cap,
        getattr(phase, "value", phase),
    )


class TraceCache:
    """LRU cache of ``TracedPhase`` entries with hit/miss accounting."""

    def __init__(self, maxsize: int = 64):
        self.maxsize = maxsize
        self._data: "OrderedDict[tuple, tuple[weakref.ref, TracedPhase]]" \
            = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, fn, key: tuple | None) -> TracedPhase | None:
        if key is None:
            self.misses += 1
            return None
        ent = self._data.get(key)
        if ent is not None:
            ref, payload = ent
            if ref() is fn:
                self.hits += 1
                self._data.move_to_end(key)
                return payload
            del self._data[key]   # id() was recycled: stale entry
        self.misses += 1
        return None

    def put(self, fn, key: tuple | None, payload: TracedPhase) -> None:
        if key is None:
            return
        data = self._data

        def _evict(_ref, _key=key):
            # the function died: its entry can never hit again (identity
            # check would fail) — drop the payload promptly instead of
            # letting dead traces linger until LRU pressure. Only drop if
            # the slot still holds THIS ref (a same-keyed newer entry may
            # have replaced it).
            ent = data.get(_key)
            if ent is not None and ent[0] is _ref:
                del data[_key]

        try:
            ref = weakref.ref(fn, _evict)
        except TypeError:
            return
        self._data[key] = (ref, payload)
        self._data.move_to_end(key)
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)

    def clear(self) -> None:
        self._data.clear()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._data)

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._data), "maxsize": self.maxsize}


#: Shared by all estimators by default — admission gates and sweeps that
#: construct a fresh ``XMemEstimator`` per decision still get warm traces.
GLOBAL_TRACE_CACHE = TraceCache()
