"""Trace cache — stage-1 memoization for the estimation fast path.

``estimate_training`` costs are dominated by re-tracing: every call runs
``jax.make_jaxpr`` plus eqn-by-eqn interpretation for each phase even
when the job's *structure* is unchanged. Repeated-call workloads
(hillclimb batch-size search, ``calibrate()`` loops, benchmark sweeps,
per-job admission gating in ``launch/train.py``, the admission service
daemon) therefore pay the full tracing cost over and over.

This module caches the complete per-phase tracing product — the event
stream, the reconstructed lifecycles, the input/output block summaries
and the abstract output pytree — keyed on

    (function identity, input avals + treedefs, arg kinds,
     scan_unroll_cap, phase, call-site tag)

Function identity is **content-addressed** whenever possible: a
structural digest over the function's code object (bytecode, consts,
nested code), defaults, closure cells and the module-level values it
references (``fn_identity`` -> ``("code", sha256-hex)``). Re-created
but structurally identical functions — the admission-gate pattern where
``make_estimator_hooks`` rebuilds closures per decision — therefore hit
the cache, and the same digests key the optional disk store so warm
traces survive process restarts. Functions whose closure/default values
cannot be canonically hashed fall back to the seed identity scheme: a
*weak* ``id(fn)`` reference (a hit then requires the stored function
object to still be the one presented, guarding against ``id()`` reuse).

Entries are immutable by contract — consumers copy
(``dataclasses.replace``) before rewriting lifecycles, exactly as the
Orchestrator already does.

The default process-global cache (``GLOBAL_TRACE_CACHE``) is shared by
every ``XMemEstimator`` unless an instance-specific cache is supplied,
so independent estimator instances created per admission decision still
share warm traces. A :class:`TraceCache` may additionally be layered
over a persistent store (``store=`` — see ``repro.service.store``):
content-keyed entries that miss in memory are looked up on disk, and
fresh traces are written through.
"""
from __future__ import annotations

import dataclasses
import enum as _enum
import functools
import hashlib
import threading
import types
import weakref
from collections import OrderedDict
from typing import Any, Sequence

from ..obs import spans as obs_spans
from .events import BlockKind, BlockLifecycle, Trace


@dataclasses.dataclass(frozen=True)
class BlockInfo:
    """Lightweight summary of a tracer input/output block. ``shape``
    feeds the spec-driven sharding engine (None = unknown)."""

    bid: int
    size: int
    kind: BlockKind
    shape: tuple | None = None


@dataclasses.dataclass
class TracedPhase:
    """Everything downstream stages need from one phase trace.

    Treat every field as immutable: entries are shared across estimate
    calls. ``lifecycles`` are copied (``dataclasses.replace``) by the
    composer before any rewrite.
    """

    trace: Trace
    lifecycles: tuple[BlockLifecycle, ...]
    input_blocks: tuple[BlockInfo, ...]
    output_blocks: tuple[BlockInfo, ...]
    out_shape: Any                   # abstract output pytree (eval_shape-like)
    closed_jaxpr: Any                # for taint/coupling analysis
    arg_leaf_counts: tuple[int, ...]
    coupling: dict | None = None     # memoized update-coupling verdict

    @property
    def num_events(self) -> int:
        return len(self.trace.events)


#: numpy dtype __str__ walks the type registry on every call — at ~150
#: leaves per trace key that dominated warm estimates, so the string form
#: is memoized per dtype object (dtypes are interned by numpy/jax).
_DTYPE_STR: dict = {}


def _aval_sig(leaf) -> tuple:
    shape = tuple(getattr(leaf, "shape", ()))
    dtype = getattr(leaf, "dtype", None)
    s = _DTYPE_STR.get(dtype)
    if s is None:
        s = _DTYPE_STR[dtype] = str(dtype)
    return (shape, s)


# -- content-addressed function identity -------------------------------------
class _Uncanonical(Exception):
    """A value that cannot be hashed structurally (no content key)."""


_CANON_DEPTH_CAP = 12


def _canon_code(code, seen: frozenset, depth: int) -> tuple:
    consts = []
    for c in code.co_consts:
        if isinstance(c, types.CodeType):
            consts.append(_canon_code(c, seen, depth + 1))
        else:
            consts.append(_canon(c, seen, depth + 1))
    return ("code", code.co_name, code.co_code, tuple(consts),
            code.co_names, code.co_freevars)


def _code_names(code) -> set:
    names = set(code.co_names)
    for c in code.co_consts:
        if isinstance(c, types.CodeType):
            names |= _code_names(c)
    return names


def _canon_global(name: str, v, seen: frozenset, depth: int) -> tuple:
    """Lenient canonical form for a module-level value a function reads.

    Globals are usually modules, helper functions or literal constants —
    all canonically hashable. Unhashable values degrade to their type
    name instead of disqualifying the function: the digest then tracks
    code/closure changes but not mutations of that one global (the same
    trade JAX's persistent compilation cache makes)."""
    if isinstance(v, types.ModuleType):
        return (name, "mod", v.__name__)
    try:
        return (name, _canon(v, seen, depth))
    except _Uncanonical:
        return (name, "other", type(v).__qualname__)


def _canon_fn(fn, seen: frozenset, depth: int) -> tuple:
    if id(fn) in seen:          # recursive function: name-level reference
        return ("fnref", getattr(fn, "__qualname__", "?"))
    seen = seen | {id(fn)}
    code = fn.__code__
    cells = []
    for cell in fn.__closure__ or ():
        try:
            cells.append(_canon(cell.cell_contents, seen, depth + 1))
        except ValueError:      # empty cell
            cells.append(("emptycell",))
    gl = fn.__globals__
    globals_sig = tuple(
        _canon_global(n, gl[n], seen, depth + 1)
        for n in sorted(_code_names(code)) if n in gl)
    return ("fn", fn.__module__, fn.__qualname__,
            _canon_code(code, seen, depth + 1),
            _canon(fn.__defaults__ or (), seen, depth + 1),
            _canon(fn.__kwdefaults__ or {}, seen, depth + 1),
            tuple(cells), globals_sig)


def _canon(v, seen: frozenset, depth: int):
    """Canonical (deterministically reprable) structure for ``v``, or
    raise :class:`_Uncanonical`."""
    if depth > _CANON_DEPTH_CAP:
        raise _Uncanonical(f"depth cap at {type(v)!r}")
    if v is None or isinstance(v, (bool, int, float, str, bytes)):
        return ("v", repr(v))
    if isinstance(v, _enum.Enum):
        return ("enum", type(v).__qualname__, repr(v.value))
    if isinstance(v, (tuple, list)):
        return ("seq", type(v).__name__,
                tuple(_canon(x, seen, depth + 1) for x in v))
    if isinstance(v, (dict, types.MappingProxyType)):
        items = sorted(
            ((_canon(k, seen, depth + 1), _canon(x, seen, depth + 1))
             for k, x in v.items()), key=repr)
        return ("dict", tuple(items))
    if isinstance(v, types.FunctionType):
        return _canon_fn(v, seen, depth)
    if isinstance(v, types.MethodType):
        return ("method", _canon_fn(v.__func__, seen, depth),
                _canon(v.__self__, seen, depth + 1))
    if isinstance(v, types.BuiltinFunctionType):
        return ("builtin", getattr(v, "__module__", None) or "",
                v.__qualname__)
    if isinstance(v, functools.partial):
        return ("partial", _canon(v.func, seen, depth + 1),
                _canon(tuple(v.args), seen, depth + 1),
                _canon(dict(v.keywords or {}), seen, depth + 1))
    if isinstance(v, type):
        return ("type", getattr(v, "__module__", ""), v.__qualname__)
    if dataclasses.is_dataclass(v):
        return ("dc", type(v).__qualname__, tuple(
            (f.name, _canon(getattr(v, f.name), seen, depth + 1))
            for f in dataclasses.fields(v)))
    import numpy as np
    if isinstance(v, np.dtype):
        return ("dtype", str(v))
    shape = getattr(v, "shape", None)
    dtype = getattr(v, "dtype", None)
    if shape is not None and dtype is not None:
        try:                     # small concrete arrays: hash the bytes
            arr = np.asarray(v)
            if arr.size <= 256:
                return ("arr", arr.shape, str(arr.dtype), arr.tobytes())
        except Exception:        # noqa: BLE001 — abstract values
            pass
        return ("aval", tuple(int(d) for d in shape), str(dtype))
    raise _Uncanonical(f"unhashable {type(v)!r}")


#: memoized digests for function objects still alive (re-created
#: closures pay the ~10s-of-us canonicalization once per object).
_FN_DIGEST_MEMO: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_NO_DIGEST = object()


def fn_digest(fn) -> str | None:
    """Content digest of a function (sha256 hex), or None when its
    code/closure/defaults cannot be canonically hashed."""
    try:
        memo = _FN_DIGEST_MEMO.get(fn)
    except TypeError:
        memo = None
    if memo is not None:
        return None if memo is _NO_DIGEST else memo
    try:
        canon = _canon(fn, frozenset(), 0)
        digest = hashlib.sha256(repr(canon).encode()).hexdigest()
    except _Uncanonical:
        digest = None
    try:
        _FN_DIGEST_MEMO[fn] = _NO_DIGEST if digest is None else digest
    except TypeError:
        pass
    return digest


def fn_identity(fn) -> tuple | None:
    """Cache identity for ``fn``: ``("code", digest)`` when content-
    addressable, ``("id", id(fn))`` when only weak identity is safe,
    None when ``fn`` cannot be weak-referenced either (caching skipped).
    """
    digest = fn_digest(fn)
    if digest is not None:
        return ("code", digest)
    try:
        weakref.ref(fn)
    except TypeError:
        return None
    return ("id", id(fn))


def trace_key(fn, tag: str, flat_leaves: Sequence, treedefs: tuple,
              kinds: Sequence[BlockKind], scan_unroll_cap: int,
              phase) -> tuple | None:
    """Build a cache key, or None when ``fn`` has no safe identity."""
    ident = fn_identity(fn)
    if ident is None:
        return None
    return (
        ident, tag,
        tuple(_aval_sig(leaf) for leaf in flat_leaves),
        tuple(treedefs),                   # jax treedefs hash/compare fast
        tuple(k.value for k in kinds),
        scan_unroll_cap,
        getattr(phase, "value", phase),
    )


def key_is_content_addressed(key: tuple | None) -> bool:
    return key is not None and key[0][0] == "code"


def stable_key_digest(key: tuple) -> str:
    """Process-independent string digest for a content-addressed key —
    the persistent store's file name. Treedefs (the only non-reprable
    component) serialize via their deterministic ``str`` form."""
    ident, tag, avals, treedefs, kinds, cap, phase = key
    parts = (ident, tag, avals, tuple(str(t) for t in treedefs), kinds,
             cap, phase)
    return hashlib.sha256(repr(parts).encode()).hexdigest()


class TraceCache:
    """LRU cache of ``TracedPhase`` entries with hit/miss accounting.

    Thread-safe (the admission service serves concurrent estimates off
    one shared cache). ``store`` layers a persistent second level under
    the in-memory LRU: content-addressed keys that miss in memory are
    looked up in the store (``store_hits`` counts those), and fresh
    traces are written through, so warm estimates survive process
    restarts and are shared across worker processes.
    """

    def __init__(self, maxsize: int = 64, store=None):
        self.maxsize = maxsize
        self.store = store
        self._data: "OrderedDict[tuple, tuple[weakref.ref | None, TracedPhase]]" \
            = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.store_hits = 0
        # per-thread counters: concurrent service workers attribute
        # hits/misses to THEIR decision, not to whoever ran concurrently
        self._tstats = threading.local()

    def _tlocal(self):
        t = self._tstats
        if not hasattr(t, "hits"):
            t.hits = t.misses = t.store_hits = 0
        return t

    def thread_stats(self) -> dict:
        """Counters accumulated by the calling thread only — the right
        basis for per-request provenance deltas under concurrency."""
        t = self._tlocal()
        return {"hits": t.hits, "misses": t.misses,
                "store_hits": t.store_hits}

    def _count(self, field: str) -> None:
        setattr(self, field, getattr(self, field) + 1)
        t = self._tlocal()
        setattr(t, field, getattr(t, field) + 1)
        # ISSUE 10: annotate the active request trace. Memory hits are
        # deliberately NOT annotated — they are the common case on the
        # warm decide path (three per decision, visible in the cache
        # counters and implied by the replay span's provenance), and
        # skipping them keeps instrumentation inside the <3% overhead
        # gate; misses and store promotions are the events worth a
        # trace line
        if field != "hits":
            obs_spans.event(f"trace_cache.{field}")

    def get(self, fn, key: tuple | None) -> TracedPhase | None:
        if key is None:
            with self._lock:
                self._count("misses")
            return None
        probe_store = False
        with self._lock:
            ent = self._data.get(key)
            if ent is not None:
                ref, payload = ent
                if ref is None or ref() is fn:
                    self._count("hits")
                    self._data.move_to_end(key)
                    return payload
                del self._data[key]   # id() was recycled: stale entry
            probe_store = (self.store is not None
                           and key_is_content_addressed(key))
        if probe_store:
            # disk read + columnar decode happen OUTSIDE the lock so a
            # store miss/hit never stalls other threads' memory hits;
            # a racing duplicate load is benign (idempotent insert)
            payload = self.store.load(key)
            if payload is not None:
                with self._lock:
                    self._count("store_hits")
                    self._insert(key, None, payload)
                return payload
        with self._lock:
            self._count("misses")
        return None

    def _insert(self, key: tuple, ref, payload: TracedPhase) -> None:
        self._data[key] = (ref, payload)
        self._data.move_to_end(key)
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)

    def put(self, fn, key: tuple | None, payload: TracedPhase) -> None:
        if key is None:
            return
        if key_is_content_addressed(key):
            # content keys need no liveness guard: any function with the
            # same digest produces the same trace by construction
            with self._lock:
                self._insert(key, None, payload)
            if self.store is not None:
                self.store.save(key, payload)
            return
        data = self._data

        def _evict(_ref, _key=key):
            # the function died: its entry can never hit again (identity
            # check would fail) — drop the payload promptly instead of
            # letting dead traces linger until LRU pressure. Only drop if
            # the slot still holds THIS ref (a same-keyed newer entry may
            # have replaced it).
            with self._lock:
                ent = data.get(_key)
                if ent is not None and ent[0] is _ref:
                    del data[_key]

        try:
            ref = weakref.ref(fn, _evict)
        except TypeError:
            return
        with self._lock:
            self._insert(key, ref, payload)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.hits = 0
            self.misses = 0
            self.store_hits = 0
            self._tstats = threading.local()

    def __len__(self) -> int:
        return len(self._data)

    def stats(self) -> dict:
        d = {"hits": self.hits, "misses": self.misses,
             "entries": len(self._data), "maxsize": self.maxsize,
             "store_hits": self.store_hits}
        if self.store is not None:
            d["store"] = self.store.stats()
        return d


#: Shared by all estimators by default — admission gates and sweeps that
#: construct a fresh ``XMemEstimator`` per decision still get warm traces.
GLOBAL_TRACE_CACHE = TraceCache()
