"""Evaluation metrics and statistical machinery (paper §4.1.4–4.1.5).

Implements the paper's two-round validation protocol and the three
headline metrics, with the notation of Table 1 / Eq. 1–8:

* round 1: does the estimator's OOM prediction (Eq. 1) match reality on a
  device with full capacity? (Eq. 4)
* round 2: rerun with max runnable memory = the *estimate*; success means
  the estimate was directly usable as a safe OOM threshold (Eq. 5).

"Reality" on this CPU-only box is the oracle peak (XLA's own reservation
for the compiled step — see DESIGN.md §2); round-2 reruns are replays of
the oracle against the reduced capacity.

Also provides one-way ANOVA (F statistic, between/within decomposition)
in plain numpy and the Monte Carlo record aggregation used by RQ1–RQ4.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class RunRecord:
    """One (configuration j, estimator e, device d) evaluation run."""

    config: str
    family: str            # cnn-analogue / transformer / moe / ssm / ...
    estimator: str
    device: str
    capacity: int          # M_d^max
    estimate: int          # \hat{M}^peak_{jde}
    truth: int             # M^peak_{jid} (oracle)
    runtime_s: float = 0.0
    meta: dict = dataclasses.field(default_factory=dict)

    # --- Eq. 1: predicted OOM on full-capacity device ---
    @property
    def oom_pred(self) -> bool:
        return self.estimate > self.capacity

    # --- actual OOM on full-capacity device (round 1) ---
    @property
    def oom_actual(self) -> bool:
        return self.truth > self.capacity

    # --- Eq. 4: round-1 correctness ---
    @property
    def c1(self) -> bool:
        return self.oom_pred == self.oom_actual

    # --- round 2: run again with capacity = estimate (only if c1 and no
    # actual OOM). OOM_{jde2} is true iff the true peak exceeds the
    # estimate-as-capacity. ---
    @property
    def oom_round2(self) -> bool:
        return self.truth > self.estimate

    # --- Eq. 5: overall success ---
    @property
    def c2(self) -> bool:
        if not self.c1:
            return False
        if self.oom_actual:          # correctly predicted an OOM job
            return True
        return not self.oom_round2   # estimate usable as safe threshold

    # --- Eq. 2: relative error (defined only when no real OOM) ---
    @property
    def rel_error(self) -> float | None:
        if self.oom_actual or self.truth == 0:
            return None
        return abs(self.estimate - self.truth) / self.truth

    # --- Eq. 7: memory conserved, with OOM penalty ---
    @property
    def mem_saved(self) -> int:
        if self.c1 and self.oom_actual:
            return self.capacity          # avoided wasting whole device
        if self.c1 and not self.oom_round2:
            return self.capacity - self.estimate
        return -self.capacity             # failure penalty


# ---------------------------------------------------------------------------
def mre(records: Sequence[RunRecord]) -> float | None:
    """Eq. 3 — median relative error over valid runs."""
    errs = [r.rel_error for r in records if r.rel_error is not None]
    return float(np.median(errs)) if errs else None


def pef(records: Sequence[RunRecord]) -> float:
    """Eq. 6 with C_{jde2} — probability of estimation failure."""
    if not records:
        return 0.0
    return 1.0 - sum(r.c2 for r in records) / len(records)


def mcp(records: Sequence[RunRecord]) -> float:
    """Eq. 8 — average memory conserved per run (bytes)."""
    if not records:
        return 0.0
    return float(np.mean([r.mem_saved for r in records]))


def mean_runtime(records: Sequence[RunRecord]) -> float:
    return float(np.mean([r.runtime_s for r in records])) if records else 0.0


def group_by(records: Sequence[RunRecord], key: str) -> dict[str, list[RunRecord]]:
    out: dict[str, list[RunRecord]] = defaultdict(list)
    for r in records:
        out[getattr(r, key, None) or r.meta.get(key, "?")].append(r)
    return dict(out)


def quadrant(records: Sequence[RunRecord], thr: float = 0.20) -> str:
    """Paper Fig. 8 quadrant for one (model, estimator) cell."""
    m, p = mre(records), pef(records)
    if m is None:
        return "n/a"
    lo_m, lo_p = m < thr, p < thr
    return {(True, True): "optimal", (False, True): "overestimation",
            (True, False): "underestimation", (False, False): "worst"}[
        (lo_m, lo_p)]


# ---------------------------------------------------------------------------
def capacity_sweep(min_capacity: int,
                   capacities: Sequence[int]) -> dict[int, bool]:
    """Feasibility verdict per candidate capacity from one
    ``min_feasible_capacity`` value (estimation fast path).

    The PEF/MCP Monte-Carlo protocol probes many device capacities per
    job; replaying ``would_oom`` once per capacity costs O(capacities)
    full allocator replays. A single instrumented replay yields the
    job's minimum feasible capacity, after which every probe is a
    comparison: feasible iff capacity >= min_capacity."""
    return {int(c): int(c) >= min_capacity for c in capacities}


def mem_conserved_at(min_capacity: int, capacity: int,
                     estimate: int) -> int:
    """Eq. 7 analogue computed from a min-capacity verdict: a correctly
    admitted job conserves (capacity - estimate); an infeasible one
    correctly rejected conserves the whole device."""
    if min_capacity > capacity:
        return capacity                 # avoided wasting the device
    return capacity - estimate


# ---------------------------------------------------------------------------
def anova_oneway(groups: Sequence[Sequence[float]]) -> dict:
    """One-way ANOVA: F statistic + df, plain numpy (paper §4.1.4)."""
    groups = [np.asarray(g, dtype=np.float64) for g in groups if len(g)]
    k = len(groups)
    n = sum(len(g) for g in groups)
    if k < 2 or n <= k:
        return {"F": float("nan"), "df_between": 0, "df_within": 0,
                "ss_between": 0.0, "ss_within": 0.0}
    grand = np.concatenate(groups).mean()
    ss_between = sum(len(g) * (g.mean() - grand) ** 2 for g in groups)
    ss_within = sum(((g - g.mean()) ** 2).sum() for g in groups)
    df_b, df_w = k - 1, n - k
    ms_b = ss_between / df_b
    ms_w = ss_within / df_w if df_w else float("nan")
    F = ms_b / ms_w if ms_w else float("inf")
    return {"F": float(F), "df_between": df_b, "df_within": df_w,
            "ss_between": float(ss_between), "ss_within": float(ss_within),
            "eta_sq": float(ss_between / (ss_between + ss_within))
            if (ss_between + ss_within) else 0.0}


def f_critical_approx(df1: int, df2: int, alpha: float = 0.05) -> float:
    """Approximate F critical value (Wilson–Hilferty-based), no scipy."""
    if df1 <= 0 or df2 <= 0:
        return float("nan")
    z = 1.6449 if alpha == 0.05 else 2.3263  # alpha=0.01
    a, b = 2.0 / (9.0 * df1), 2.0 / (9.0 * df2)
    num = (1.0 - b) + z * math.sqrt(b + a - a * b * (z ** 2 / 9.0) ** 0)
    # Paulson approximation:
    h = 2.0 / (1.0 / (2 * df1 - 1) + 1.0 / (2 * df2 - 1))
    lam = (z * z - 3.0) / 6.0
    w = z * math.sqrt(h + lam) / h - (1.0 / (2 * df2 - 1)
                                      - 1.0 / (2 * df1 - 1)) \
        * (lam + 5.0 / 6.0 - 2.0 / (3.0 * h))
    return math.exp(2.0 * w)


# ---------------------------------------------------------------------------
def summarize(records: Sequence[RunRecord]) -> dict:
    """Per-estimator headline table (the paper's abstract-level numbers)."""
    out = {}
    for est, recs in group_by(records, "estimator").items():
        out[est] = {
            "n": len(recs),
            "mre": mre(recs),
            "pef": pef(recs),
            "mcp_gb": mcp(recs) / 1e9,
            "runtime_s": mean_runtime(recs),
        }
    return out


def improvement_vs_best_baseline(records: Sequence[RunRecord],
                                 ours: str = "xmem") -> dict:
    """Headline improvements (paper: 'decreases MRE by 91%, PEF by 75%,
    increases MCP by 368%') computed the same way: ours vs best baseline."""
    s = summarize(records)
    if ours not in s:
        return {}
    base = {k: v for k, v in s.items() if k != ours}
    if not base:
        return {}
    best_mre = min((v["mre"] for v in base.values() if v["mre"] is not None),
                   default=None)
    best_pef = min(v["pef"] for v in base.values())
    best_mcp = max(v["mcp_gb"] for v in base.values())
    o = s[ours]
    return {
        "mre_reduction_pct": (1 - o["mre"] / best_mre) * 100
        if best_mre else None,
        "pef_reduction_pct": (1 - o["pef"] / best_pef) * 100
        if best_pef else None,
        "mcp_increase_pct": (o["mcp_gb"] / best_mcp - 1) * 100
        if best_mcp > 0 else None,
    }
