"""Sweep service — batched estimation over many related jobs.

Sweep callers (hillclimb batch-size search, dry-run capacity gating, the
Monte-Carlo benchmark protocols) historically ran ``estimate_training``
one point at a time, paying a full ``make_jaxpr`` + jaxpr interpretation
for every probe even though the points differ only in one scalar (the
batch size). ``estimate_many`` removes that redundancy in three layers:

1. **Trace-cache dedup** — points sharing avals (and the batch-
   independent optimizer phases of every point) are traced once.
2. **Columnar trace interpolation** — for a 1-D sweep (batch size), the
   forward phase is traced at three probe points (min / median / max).
   If the three columnar traces are structurally identical (same events,
   ids, times, ops, scopes — everything except the size column) and the
   per-event sizes fit an integer affine model ``size = s0 + s1 * b``
   that reproduces the middle probe *exactly*, the remaining points'
   traces are synthesized by array arithmetic: no tracing at all. Every
   synthesized point is additionally cross-checked against its true
   input aval bytes, and any failed check falls back to a real trace —
   the model is an exact-or-bust shortcut, never an approximation.
   Classification, orchestration and replay still run per point (they
   are size-dependent), so results are identical to sequential
   ``estimate_training`` by construction (tests/test_columnar.py).
3. **Parallel replay fan-out** — stages 2-5 of non-probe points are
   pure functions of picklable ``TracedPhase`` payloads, so a
   ``SweepService`` with ``processes > 0`` ships them to a persistent
   process pool (spawned workers never run JAX tracing; reports from
   pooled points carry no usage curve to keep IPC lean).

Use ``SweepService`` when sweeping repeatedly (the pool and trace cache
stay warm across calls); ``estimate_many`` is the one-shot convenience.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ProcessPoolExecutor
from typing import Any, Callable, Sequence

import numpy as np

from .cache import BlockInfo, TracedPhase, trace_key
from .estimator import (EstimateReport, XMemEstimator, _coupling_from_jaxpr,
                        flatten_kinds)
from .events import BlockKind, ColumnarBlocks, Phase, Trace
from .simulator import SimResult


@dataclasses.dataclass
class SweepPoint:
    """One job of a sweep: the ``estimate_training`` argument tuple."""

    fwd_bwd_fn: Callable
    params: Any
    batch: Any
    update_fn: Callable | None = None
    opt_init_fn: Callable | None = None
    shard_factor_fn: Callable | None = None
    collective_specs: Sequence = ()
    capacity: int | None = None
    label: str = ""


@dataclasses.dataclass
class SweepResult:
    reports: list[EstimateReport]       # one per point, input order
    stats: dict                         # traced/interpolated/pooled counts

    def __iter__(self):
        return iter(self.reports)

    def __len__(self):
        return len(self.reports)


# -- mesh-topology sweep -----------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """One (pod, data, model, fsdp) cell of a topology grid."""

    pod: int = 1
    data: int = 1
    model: int = 1
    fsdp: bool = False

    @property
    def n_devices(self) -> int:
        return self.pod * self.data * self.model

    @property
    def axis_sizes(self) -> dict:
        return {"pod": self.pod, "data": self.data, "model": self.model}

    @property
    def label(self) -> str:
        tag = f"{self.pod}x{self.data}x{self.model}"
        return tag + ("+fsdp" if self.fsdp else "")

    def sharding_policy(self):
        from ..distributed.sharding import ShardingPolicy
        fsdp_axes = (("data", "pod") if self.pod > 1 else ("data",))
        return ShardingPolicy(fsdp=self.fsdp, fsdp_axes=fsdp_axes,
                              batch_axes=("pod", "data"))


def topology_grid(n_devices: int, *, pods: Sequence[int] = (1,),
                  fsdp: Sequence[bool] = (False, True)
                  ) -> list[MeshTopology]:
    """All (pod, data, model, fsdp) cells whose device product equals
    ``n_devices`` — the default grid ``estimate_mesh_sweep`` callers
    batch over. fsdp=True cells are skipped when every fsdp axis has
    size 1 (they would duplicate the fsdp=False estimate bit-for-bit
    while claiming ZeRO-3 was modeled)."""
    out = []
    for pod in pods:
        if pod <= 0 or n_devices % pod:
            continue
        per_pod = n_devices // pod
        for model in range(1, per_pod + 1):
            if per_pod % model:
                continue
            data = per_pod // model
            for f in fsdp:
                if f and data * pod == 1:
                    continue
                out.append(MeshTopology(pod=pod, data=data,
                                        model=model, fsdp=f))
    return out


@dataclasses.dataclass
class MeshSweepResult:
    """Per-topology estimates from one cached trace."""

    topologies: list[MeshTopology]
    reports: list[EstimateReport]
    stats: dict

    def __iter__(self):
        return iter(zip(self.topologies, self.reports))

    def __len__(self):
        return len(self.reports)

    def admitted(self, capacity: int) -> list[MeshTopology]:
        """Topologies whose per-device estimate fits ``capacity``."""
        return [t for t, r in zip(self.topologies, self.reports)
                if r.fits(capacity)]

    def best(self, capacity: int
             ) -> tuple[MeshTopology, EstimateReport] | None:
        """Cheapest admitted topology: fewest devices, then lowest
        per-device peak."""
        fits = [(t, r) for t, r in zip(self.topologies, self.reports)
                if r.fits(capacity)]
        if not fits:
            return None
        return min(fits, key=lambda tr: (tr[0].n_devices,
                                         tr[1].peak_bytes))


@dataclasses.dataclass
class ServingSweepResult:
    """Per-knob serving estimates from one cached decode trace."""

    knobs: list            # ServingKnobs grid, aligned with estimates
    estimates: list        # ServingEstimate per knob point
    stats: dict

    def __iter__(self):
        return iter(zip(self.knobs, self.estimates))

    def __len__(self):
        return len(self.estimates)

    def admitted(self, capacity: int) -> list:
        return [k for k, e in zip(self.knobs, self.estimates)
                if e.fits(capacity)]


# -- affine trace model ------------------------------------------------------
def _fit_affine(y_lo, y_hi, b_lo: int, b_hi: int):
    """Integer affine fit through two probes, or None if non-integral."""
    y_lo = np.asarray(y_lo, dtype=np.int64)
    y_hi = np.asarray(y_hi, dtype=np.int64)
    db = b_hi - b_lo
    num = y_hi - y_lo
    if np.any(num % db):
        return None
    slope = num // db
    return y_lo - slope * b_lo, slope


def _eval_affine(model, b: int) -> np.ndarray:
    s0, s1 = model
    return s0 + s1 * b


class _PhaseModel:
    """Exact-or-bust affine model of one phase's trace over a scalar.

    Built from three structurally identical probe traces; synthesizes a
    ``TracedPhase`` for any scalar by rewriting the size columns (and the
    batch-varying out-shape dims). The middle probe must be reproduced
    bit-exactly by the two-point fit or the model rejects itself.
    """

    def __init__(self, probes: list[tuple[int, TracedPhase]]):
        import jax
        (b_lo, p_lo), (b_mid, p_mid), (b_hi, p_hi) = \
            sorted(probes, key=lambda x: x[0])
        self.template = p_lo
        # trusted scalar range: interpolation never extrapolates past the
        # outer probes (structure changes lurk at range boundaries, e.g.
        # dim-1 specialization at batch 1)
        self.b_lo, self.b_hi = b_lo, b_hi
        self.ok = False
        cols = [p.trace.columnar() for p in (p_lo, p_mid, p_hi)]
        if len({len(c) for c in cols}) != 1:
            return
        ref = cols[0]
        for c in cols[1:]:
            if not (np.array_equal(ref.kind, c.kind)
                    and np.array_equal(ref.block_id, c.block_id)
                    and np.array_equal(ref.t, c.t)
                    and np.array_equal(ref.phase, c.phase)
                    and np.array_equal(ref.block_kind, c.block_kind)
                    and np.array_equal(ref.op, c.op)
                    and np.array_equal(ref.scope, c.scope)
                    and np.array_equal(ref.shape, c.shape)
                    and np.array_equal(ref.space, c.space)
                    and ref.op_table == c.op_table
                    and ref.scope_table == c.scope_table):
                return
        lcs = [ColumnarBlocks.from_lifecycles(p.lifecycles)
               for p in (p_lo, p_mid, p_hi)]
        lref = lcs[0]
        for c in lcs[1:]:
            if not (len(lref) == len(c)
                    and np.array_equal(lref.block_id, c.block_id)
                    and np.array_equal(lref.alloc_t, c.alloc_t)
                    and np.array_equal(lref.free_t, c.free_t)
                    and np.array_equal(lref.block_kind, c.block_kind)
                    and np.array_equal(lref.shape, c.shape)
                    and np.array_equal(lref.space, c.space)
                    and np.array_equal(lref.shard_factor, c.shard_factor)):
                return

        def fit3(lo, mid, hi):
            m = _fit_affine(lo, hi, b_lo, b_hi)
            if m is None or not np.array_equal(
                    _eval_affine(m, b_mid), np.asarray(mid, np.int64)):
                return None
            return m

        def fit_shape_table(tables):
            """Affine model per shape-table entry (None entries must be
            None in every probe; dims fit like sizes)."""
            lo, mid, hi = tables
            if not (len(lo) == len(mid) == len(hi)):
                return None
            models: list = []
            for a, bb, c in zip(lo, mid, hi):
                if a is None or bb is None or c is None:
                    if not (a is None and bb is None and c is None):
                        return None
                    models.append(None)
                    continue
                if not (len(a) == len(bb) == len(c)):
                    return None
                m = fit3(a, bb, c)
                if m is None:
                    return None
                models.append(m)
            return models

        def fit_block_shapes(block_lists):
            """Affine per-block shape model over input/output BlockInfos."""
            lo, mid, hi = block_lists
            return fit_shape_table((tuple(b.shape for b in lo),
                                    tuple(b.shape for b in mid),
                                    tuple(b.shape for b in hi)))

        self.ev_sizes = fit3(cols[0].size, cols[1].size, cols[2].size)
        self.lc_sizes = fit3(lcs[0].size, lcs[1].size, lcs[2].size)
        self.in_sizes = fit3(*[[b.size for b in p.input_blocks]
                               for p in (p_lo, p_mid, p_hi)])
        self.out_sizes = fit3(*[[b.size for b in p.output_blocks]
                                for p in (p_lo, p_mid, p_hi)])
        self.ev_shapes = fit_shape_table([c.shape_table for c in cols])
        self.lc_shapes = fit_shape_table([c.shape_table for c in lcs])
        self.in_shapes = fit_block_shapes([p.input_blocks
                                           for p in (p_lo, p_mid, p_hi)])
        self.out_shapes = fit_block_shapes([p.output_blocks
                                            for p in (p_lo, p_mid, p_hi)])
        if None in (self.ev_sizes, self.lc_sizes, self.in_sizes,
                    self.out_sizes, self.ev_shapes, self.lc_shapes,
                    self.in_shapes, self.out_shapes):
            return
        if len({(b.bid, b.kind) for b in p_lo.input_blocks}
               ^ {(b.bid, b.kind) for b in p_hi.input_blocks}):
            return
        # out_shape: identical pytrees, per-leaf dims affine in b
        if len({jax.tree_util.tree_structure(p.out_shape)
                for p in (p_lo, p_mid, p_hi)}) != 1:
            return
        shapes = [[(tuple(l.shape), l.dtype)
                   for l in jax.tree_util.tree_leaves(p.out_shape)]
                  for p in (p_lo, p_mid, p_hi)]
        if len({len(s) for s in shapes}) != 1:
            return
        dims = []
        for i in range(len(shapes[0])):
            if len({len(s[i][0]) for s in shapes}) != 1 \
                    or len({s[i][1] for s in shapes}) != 1:
                return
            m = fit3(shapes[0][i][0], shapes[1][i][0], shapes[2][i][0])
            if m is None:
                return
            dims.append(m)
        self.out_dims = dims
        # constant out_shape -> the optimizer phases (keyed on the grads
        # avals) are provably shared across all points, so whole point
        # chunks can ship to pool workers with one upd/init payload
        self.out_constant = all(not s1.any() for _s0, s1 in dims)
        self.lc_template = lref
        self.ok = True

    def stripped(self) -> "_PhaseModel":
        """Picklable, lean copy for pool payloads: drops the template
        jaxpr and its object lifecycles (``synthesize`` rebuilds
        lifecycles from the columnar template, never from these)."""
        clone = _PhaseModel.__new__(_PhaseModel)
        clone.__dict__.update(self.__dict__)
        clone.template = dataclasses.replace(self.template,
                                             closed_jaxpr=None,
                                             lifecycles=())
        return clone

    def synthesize(self, b: int, expected_input_sizes: list[int]
                   ) -> TracedPhase | None:
        """Build the point's TracedPhase, or None when any exactness
        check fails (scalar outside the probed range, negative sizes,
        input-aval mismatch). The input sizes a real trace would record
        are fully determined by the point's avals, so the caller passes
        that ground truth in."""
        import jax
        if not (self.b_lo <= b <= self.b_hi):
            return None
        tp = self.template
        in_sizes = _eval_affine(self.in_sizes, b)
        if in_sizes.tolist() != expected_input_sizes:
            return None
        ev_sizes = _eval_affine(self.ev_sizes, b)
        lc_sizes = _eval_affine(self.lc_sizes, b)
        out_sizes = _eval_affine(self.out_sizes, b)
        if (ev_sizes < 0).any() or (lc_sizes < 0).any() \
                or (out_sizes < 0).any():
            return None

        def eval_shapes(models):
            out = []
            for m in models:
                if m is None:
                    out.append(None)
                    continue
                shape = tuple(int(d) for d in _eval_affine(m, b))
                if any(d < 0 for d in shape):
                    return None
                out.append(shape)
            return out

        ev_table = eval_shapes(self.ev_shapes)
        lc_table = eval_shapes(self.lc_shapes)
        in_shapes = eval_shapes(self.in_shapes)
        out_shapes = eval_shapes(self.out_shapes)
        if None in (ev_table, lc_table, in_shapes, out_shapes):
            return None
        new_leaves = []
        for leaf, dim_model in zip(
                jax.tree_util.tree_leaves(tp.out_shape), self.out_dims):
            shape = tuple(int(d) for d in _eval_affine(dim_model, b))
            if any(d < 0 for d in shape):
                return None
            new_leaves.append(jax.ShapeDtypeStruct(shape, leaf.dtype))
        out_shape = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(tp.out_shape), new_leaves)
        trace = Trace.from_columnar(
            dataclasses.replace(tp.trace.columnar().with_sizes(ev_sizes),
                                shape_table=ev_table),
            num_iterations=tp.trace.num_iterations,
            meta={k: v for k, v in tp.trace.meta.items()
                  if k != "_columns"})
        lifecycles = tuple(dataclasses.replace(
            self.lc_template.with_sizes(lc_sizes),
            shape_table=lc_table).to_lifecycles())
        return TracedPhase(
            trace=trace,
            lifecycles=lifecycles,
            input_blocks=tuple(
                BlockInfo(bi.bid, int(s), bi.kind, shp)
                for bi, s, shp in zip(tp.input_blocks, in_sizes,
                                      in_shapes)),
            output_blocks=tuple(
                BlockInfo(bi.bid, int(s), bi.kind, shp)
                for bi, s, shp in zip(tp.output_blocks, out_sizes,
                                      out_shapes)),
            out_shape=out_shape,
            closed_jaxpr=None,          # never shipped / re-analyzed
            arg_leaf_counts=tp.arg_leaf_counts,
        )


def _trace_sig(entry: TracedPhase) -> tuple:
    """Structural fingerprint of a phase trace — everything except the
    size columns and the shape *table* (whose dims vary with the sweep
    scalar; the interned shape index pattern must still match). Two
    traces with equal signatures differ only in sizes/shape dims, the
    precondition for the affine model."""
    c = entry.trace.columnar()
    return (len(c), c.kind.tobytes(), c.block_id.tobytes(), c.t.tobytes(),
            c.op.tobytes(), c.scope.tobytes(), c.phase.tobytes(),
            c.block_kind.tobytes(), c.shape.tobytes(), c.space.tobytes(),
            tuple(c.op_table), tuple(c.scope_table))


# -- scalar detection --------------------------------------------------------
def _leaf_sig(tree):
    import jax
    leaves = jax.tree_util.tree_leaves(tree)
    return (jax.tree_util.tree_structure(tree),
            tuple((tuple(getattr(l, "shape", ())),
                   str(getattr(l, "dtype", None))) for l in leaves))


def _aval_nbytes(leaf) -> int:
    """Byte size a real trace records for an input leaf — delegates to
    the tracer's own sizing so the interpolation cross-check can never
    drift from what tracing would have produced."""
    from .tracer import aval_bytes
    return aval_bytes(leaf)


def _sweep_scalars(points: list[SweepPoint]) -> list[int] | None:
    """Scalar parameter per point for a 1-D batch sweep, or None when
    the points do not form one (different treedefs / dtypes / ranks)."""
    sigs = [_leaf_sig(p.batch) for p in points]
    if len({s[0] for s in sigs}) != 1:
        return None
    ref = sigs[0][1]
    for _, leafsig in sigs:
        if len(leafsig) != len(ref):
            return None
        for (shape, dt), (rshape, rdt) in zip(leafsig, ref):
            if dt != rdt or len(shape) != len(rshape):
                return None
    varying = set()
    for _, leafsig in sigs:
        for li, (shape, _) in enumerate(leafsig):
            for di, d in enumerate(shape):
                if d != ref[li][0][di]:
                    varying.add((li, di))
    if not varying:
        return [0] * len(points)      # identical points: cache handles it
    li, di = sorted(varying)[0]
    return [int(s[1][li][0][di]) for s in sigs]


# -- process-pool worker -----------------------------------------------------
def _report_to_dict(rep: EstimateReport) -> dict:
    return {
        "peak_bytes": rep.peak_bytes,
        "peak_tensor_bytes": rep.peak_tensor_bytes,
        "persistent_bytes": rep.persistent_bytes,
        "oom": rep.oom,
        "breakdown": rep.breakdown,
        "num_events": rep.num_events,
        "sim_peak_reserved": rep.sim.peak_reserved,
        "sim_peak_allocated": rep.sim.peak_allocated,
        "sim_oom_at": rep.sim.oom_at,
        "sim_stats": rep.sim.stats,
        "sim_unbounded": getattr(rep, "sim_unbounded", False),
    }


def _pool_worker_chunk(payload: dict) -> list[dict | None]:
    """Stages 2-5 for a chunk of sweep points in a worker process: the
    point traces are synthesized in-worker from the shipped model (array
    arithmetic), then composed + orchestrated + replayed. No JAX tracing
    happens here; the shared upd/init payload is shipped once per chunk.
    A None result marks a point whose exactness check failed — the
    parent falls back to a real trace for it."""
    est = XMemEstimator(trace_cache=None, **payload["estimator"])
    model: _PhaseModel = payload["model"]
    upd, init = payload["upd"], payload["init"]
    out = []
    for pt in payload["points"]:
        fwd = model.synthesize(pt["b"], pt["expected_input_sizes"])
        if fwd is None:
            out.append(None)
            continue
        rep = est.estimate_from_phases(fwd, upd, init,
                                       capacity=pt["capacity"])
        out.append(_report_to_dict(rep))
    return out


def _pool_worker_jobs(payload: dict) -> list[dict]:
    """Full estimates (stage 1 included) for picklable jobs in a worker
    process — used for probe points (traced concurrently with the
    parent's own probe) and for whole non-interpolable sweeps."""
    est = XMemEstimator(**payload["estimator"])
    out = []
    for job in payload["jobs"]:
        rep = est.estimate_training(
            job["fwd_bwd_fn"], job["params"], job["batch"],
            update_fn=job["update_fn"], opt_init_fn=job["opt_init_fn"],
            capacity=job["capacity"])
        d = _report_to_dict(rep)
        if payload["want_phases"]:
            fwd, upd, init = est.trace_phases(
                job["fwd_bwd_fn"], job["params"], job["batch"],
                job["update_fn"], job["opt_init_fn"])
            if (upd is not None and upd.coupling is None
                    and upd.closed_jaxpr is not None):
                upd.coupling = _coupling_from_jaxpr(
                    upd.closed_jaxpr.jaxpr, upd.arg_leaf_counts[0],
                    upd.arg_leaf_counts[1])
            d["phases"] = tuple(
                SweepService._strip_for_pool(e)
                for e in (fwd, upd, init))
        out.append(d)
    return out


def _pool_warm(_i: int) -> bool:
    return True


class _ColumnarLifecycles(Sequence):
    """Tuple-compatible lifecycles view backed by ``ColumnarBlocks`` —
    crosses process boundaries as arrays, materializes on first use."""

    def __init__(self, columns: ColumnarBlocks):
        self.columns = columns
        self._mat = None

    def _m(self):
        if self._mat is None:
            self._mat = self.columns.to_lifecycles()
        return self._mat

    def __len__(self):
        return len(self.columns)

    def __getitem__(self, i):
        return self._m()[i]

    def __iter__(self):
        return iter(self._m())

    def __reduce__(self):
        return (_ColumnarLifecycles, (self.columns,))


class SweepService:
    """Reusable sweep runner: shared trace cache, interpolation models
    and (optionally) a persistent process pool for replay fan-out."""

    def __init__(self, estimator: XMemEstimator | None = None,
                 processes: int = 0):
        self.estimator = estimator or XMemEstimator()
        if self.estimator.trace_cache is None:
            raise ValueError(
                "SweepService needs a fast-path estimator (fastpath=True): "
                "the sweep dedups work through its trace cache")
        self.processes = max(int(processes), 0)
        self._pool: ProcessPoolExecutor | None = None

    # -- pool lifecycle ------------------------------------------------------
    def _get_pool(self) -> ProcessPoolExecutor | None:
        if self.processes <= 0:
            return None
        if self._pool is None:
            import multiprocessing as mp
            # spawn: workers must not inherit JAX/XLA runtime threads
            self._pool = ProcessPoolExecutor(
                max_workers=self.processes,
                mp_context=mp.get_context("spawn"))
        return self._pool

    def warm_up(self) -> None:
        """Spin up pool workers (spawn + imports) ahead of timed work."""
        pool = self._get_pool()
        if pool is not None:
            list(pool.map(_pool_warm, range(self.processes)))

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown()
            self._pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- internals -----------------------------------------------------------
    def _fwd_key(self, p: SweepPoint):
        import jax
        est = self.estimator
        flat, kinds, _ = flatten_kinds(
            [(p.params, BlockKind.PARAM, "params"),
             (p.batch, BlockKind.INPUT, "batch")])
        treedefs = (jax.tree_util.tree_structure(p.params),
                    jax.tree_util.tree_structure(p.batch))
        return trace_key(p.fwd_bwd_fn, "fwd", flat, treedefs, kinds,
                         est.scan_unroll_cap, Phase.FORWARD_BACKWARD), flat

    def _estimate_full(self, p: SweepPoint) -> EstimateReport:
        return self.estimator.estimate_training(
            p.fwd_bwd_fn, p.params, p.batch, update_fn=p.update_fn,
            opt_init_fn=p.opt_init_fn, shard_factor_fn=p.shard_factor_fn,
            collective_specs=p.collective_specs, capacity=p.capacity)

    def _estimator_config(self) -> dict:
        est = self.estimator
        return dict(allocator_policy=est.allocator_policy,
                    orchestrator_policy=est.orchestrator.policy,
                    iterations=est.iterations,
                    scan_unroll_cap=est.scan_unroll_cap,
                    capacity=est.capacity,
                    engine=est.engine)

    @staticmethod
    def _strip_for_pool(entry: TracedPhase | None) -> TracedPhase | None:
        """Make a phase payload picklable and lean: drop the jaxpr (the
        coupling verdict must already be memoized on the entry) and ship
        lifecycles as columns — object pickling of hundreds of
        dataclasses is the slow part of the payload."""
        if entry is None:
            return None
        return dataclasses.replace(
            entry, closed_jaxpr=None,
            lifecycles=_ColumnarLifecycles(
                ColumnarBlocks.from_lifecycles(entry.lifecycles)))

    def _resolve_coupling(self, upd: TracedPhase | None) -> None:
        if (upd is not None and upd.coupling is None
                and upd.closed_jaxpr is not None):
            upd.coupling = _coupling_from_jaxpr(
                upd.closed_jaxpr.jaxpr, upd.arg_leaf_counts[0],
                upd.arg_leaf_counts[1])

    def _report_from_pool(self, d: dict) -> EstimateReport:
        sim = SimResult(
            peak_reserved=d["sim_peak_reserved"],
            peak_allocated=d["sim_peak_allocated"],
            oom=d["oom"], oom_at=d["sim_oom_at"],
            curve=[],                  # dropped for IPC leanness
            stats=d["sim_stats"], segments=[])
        rep = EstimateReport(
            peak_bytes=d["peak_bytes"],
            peak_tensor_bytes=d["peak_tensor_bytes"],
            persistent_bytes=d["persistent_bytes"],
            oom=d["oom"], sim=sim, breakdown=d["breakdown"],
            wall_time_s=0.0, num_events=d["num_events"])
        rep.sim_unbounded = d["sim_unbounded"]
        return rep

    @staticmethod
    def _picklable_jobs(gpoints: list[SweepPoint]) -> bool:
        """Can these jobs' functions/avals cross a process boundary?
        (Module-level step fns can; closures typically cannot.)"""
        import pickle
        try:
            p = gpoints[0]
            pickle.dumps((p.fwd_bwd_fn, p.update_fn, p.opt_init_fn,
                          p.params, p.batch))
            return True
        except Exception:   # noqa: BLE001 — any pickling failure
            return False

    def _job_payload(self, p: SweepPoint) -> dict:
        return {"fwd_bwd_fn": p.fwd_bwd_fn, "params": p.params,
                "batch": p.batch, "update_fn": p.update_fn,
                "opt_init_fn": p.opt_init_fn, "capacity": p.capacity}

    def _run_group(self, points, idxs, scalars, reports, stats) -> None:
        """Estimate one interpolation group (same fns / params)."""
        est = self.estimator
        pool = self._get_pool()
        gpoints = [points[i] for i in idxs]
        distinct = sorted(set(scalars)) if scalars is not None else []
        plain = all(p.shard_factor_fn is None and not p.collective_specs
                    for p in gpoints)
        picklable = (pool is not None and plain
                     and self._picklable_jobs(gpoints))

        if scalars is None or len(distinct) < 4:
            # no 1-D structure worth modeling: full estimates, fanned out
            # over the pool when the jobs can travel
            if picklable and len(idxs) > 1:
                self._pool_full_jobs(points, idxs, reports, stats)
            else:
                for i in idxs:
                    reports[i] = self._estimate_full(points[i])
                    stats["traced"] += 1
            return

        # --- probes: min / median / max scalars, traced for real -------
        probe_vals = [distinct[0], distinct[len(distinct) // 2],
                      distinct[-1]]
        probe_idx = {}
        for i, b in zip(idxs, scalars):
            if b in probe_vals and b not in probe_idx:
                probe_idx[b] = i
        probe_entries: list[tuple[int, TracedPhase]] = []
        upd_entry = init_entry = None

        def note_probe(b, fwd, upd, init):
            nonlocal upd_entry, init_entry
            if fwd is not None:
                probe_entries.append((b, fwd))
                upd_entry, init_entry = upd, init

        if picklable and len(probe_vals) > 1:
            # parent traces the min probe while workers trace the rest
            futures = [
                (b, probe_idx[b], pool.submit(_pool_worker_jobs, {
                    "estimator": self._estimator_config(),
                    "jobs": [self._job_payload(points[probe_idx[b]])],
                    "want_phases": True}))
                for b in probe_vals[1:]]
            b0 = probe_vals[0]
            reports[probe_idx[b0]] = self._estimate_full(
                points[probe_idx[b0]])
            stats["traced"] += 1
            key, _ = self._fwd_key(points[probe_idx[b0]])
            entry = est.trace_cache.get(points[probe_idx[b0]].fwd_bwd_fn,
                                        key)
            note_probe(b0, entry, *est.trace_phases(
                points[probe_idx[b0]].fwd_bwd_fn,
                points[probe_idx[b0]].params, points[probe_idx[b0]].batch,
                points[probe_idx[b0]].update_fn,
                points[probe_idx[b0]].opt_init_fn, fwd=entry)[1:])
            for b, i, fut in futures:
                d = fut.result()[0]
                reports[i] = self._report_from_pool(d)
                stats["traced"] += 1
                fwd, upd, init = d.pop("phases")
                note_probe(b, fwd, upd, init)
                # seed the parent cache so duplicate scalars /
                # fallbacks do not re-trace
                key, _ = self._fwd_key(points[i])
                if fwd is not None and key is not None:
                    est.trace_cache.put(points[i].fwd_bwd_fn, key, fwd)
        else:
            for b in probe_vals:
                i = probe_idx[b]
                reports[i] = self._estimate_full(points[i])
                stats["traced"] += 1
                key, _ = self._fwd_key(points[i])
                entry = est.trace_cache.get(points[i].fwd_bwd_fn, key)
                note_probe(b, entry, *est.trace_phases(
                    points[i].fwd_bwd_fn, points[i].params,
                    points[i].batch, points[i].update_fn,
                    points[i].opt_init_fn, fwd=entry)[1:])

        # build the model from a structurally consistent probe trio; if
        # one probe diverged structurally (e.g. batch-1 specialization),
        # trace one repair probe between the two consistent ones and
        # trust only that narrowed range
        model = None
        if len(probe_entries) == 3:
            sigs = [(b, e, _trace_sig(e)) for b, e in probe_entries]
            groups: dict = {}
            for b, e, s in sigs:
                groups.setdefault(s, []).append((b, e))
            consistent = max(groups.values(), key=len)
            if len(consistent) == 2:
                bl = min(b for b, _ in consistent)
                bh = max(b for b, _ in consistent)
                scalar_index = {}
                for i, b in zip(idxs, scalars):
                    scalar_index.setdefault(b, i)
                spare = [b for b in distinct
                         if bl < b < bh and b not in probe_idx]
                if spare:
                    bm = spare[len(spare) // 2]
                    i = scalar_index[bm]
                    reports[i] = self._estimate_full(points[i])
                    stats["traced"] += 1
                    probe_idx[bm] = i
                    key, _ = self._fwd_key(points[i])
                    e = est.trace_cache.get(points[i].fwd_bwd_fn, key)
                    if e is not None and _trace_sig(e) == \
                            _trace_sig(consistent[0][1]):
                        consistent.append((bm, e))
            if len(consistent) >= 3:
                model = _PhaseModel(sorted(consistent)[:3])
                if not model.ok:
                    model = None
        self._resolve_coupling(upd_entry)

        # --- remaining points ------------------------------------------
        rest = [(i, b) for i, b in zip(idxs, scalars) if i not in reports]
        chunk_points: list[tuple[int, dict]] = []
        full_left: list[int] = []
        for i, b in rest:
            p = points[i]
            if b in probe_idx:          # duplicate scalar: cache-hot
                reports[i] = self._estimate_full(p)
                stats["traced"] += 1
                continue
            if model is not None and not (model.b_lo <= b <= model.b_hi):
                full_left.append(i)     # outside the trusted probe range
                continue
            _key, flat = self._fwd_key(p)
            expected = [_aval_nbytes(leaf) for leaf in flat]
            if (picklable and model is not None and model.out_constant
                    and plain):
                chunk_points.append((i, {
                    "b": b, "expected_input_sizes": expected,
                    "capacity": p.capacity}))
                continue
            fwd = (model.synthesize(b, expected)
                   if model is not None else None)
            if fwd is None:
                full_left.append(i)
                continue
            stats["interpolated"] += 1
            fwd, upd, init = est.trace_phases(
                p.fwd_bwd_fn, p.params, p.batch, p.update_fn,
                p.opt_init_fn, fwd=fwd)
            self._resolve_coupling(upd)
            reports[i] = est.estimate_from_phases(
                fwd, upd, init, shard_factor_fn=p.shard_factor_fn,
                collective_specs=p.collective_specs, capacity=p.capacity)

        if chunk_points:
            # round-robin chunks: one payload per worker carries the
            # model and the shared optimizer phases exactly once; the
            # parent keeps one share and works it while the pool drains
            shared = {
                "estimator": self._estimator_config(),
                "model": model.stripped(),
                "upd": self._strip_for_pool(upd_entry),
                "init": self._strip_for_pool(init_entry),
            }
            n_chunks = max(min(self.processes + 1, len(chunk_points)), 1)
            chunks = [chunk_points[k::n_chunks] for k in range(n_chunks)]
            own, worker_chunks = chunks[-1], chunks[:-1]
            futures = []
            for chunk in worker_chunks:
                payload = dict(shared)
                payload["points"] = [meta for _i, meta in chunk]
                futures.append((chunk, pool.submit(_pool_worker_chunk,
                                                   payload)))
            for i, meta in own:
                fwd = model.synthesize(meta["b"],
                                       meta["expected_input_sizes"])
                if fwd is None:
                    full_left.append(i)
                    continue
                reports[i] = est.estimate_from_phases(
                    fwd, upd_entry, init_entry, capacity=meta["capacity"])
                stats["interpolated"] += 1
            for chunk, fut in futures:
                for (i, _meta), d in zip(chunk, fut.result()):
                    if d is None:   # in-worker exactness check failed
                        full_left.append(i)
                    else:
                        reports[i] = self._report_from_pool(d)
                        stats["pooled"] += 1
                        stats["interpolated"] += 1

        if full_left:
            stats["fallback"] += len(full_left)
            if picklable and len(full_left) > 1:
                self._pool_full_jobs(points, full_left, reports, stats)
            else:
                for i in full_left:
                    reports[i] = self._estimate_full(points[i])
                    stats["traced"] += 1

    def _pool_full_jobs(self, points, idxs, reports, stats) -> None:
        """Fan whole estimates out over the pool (picklable jobs only)."""
        pool = self._get_pool()
        n_chunks = max(min(self.processes, len(idxs)), 1)
        chunks = [idxs[k::n_chunks] for k in range(n_chunks)]
        futures = []
        for chunk in chunks:
            payload = {"estimator": self._estimator_config(),
                       "jobs": [self._job_payload(points[i])
                                for i in chunk],
                       "want_phases": False}
            futures.append((chunk, pool.submit(_pool_worker_jobs,
                                               payload)))
        for chunk, fut in futures:
            for i, d in zip(chunk, fut.result()):
                reports[i] = self._report_from_pool(d)
                stats["traced"] += 1
                stats["pooled"] += 1

    # -- public API ----------------------------------------------------------
    def estimate_mesh_sweep(self, fwd_bwd_fn, params, batch,
                            topologies: Sequence[MeshTopology], *,
                            update_fn=None, opt_init_fn=None, cfg=None,
                            shard_factors: str = "spec",
                            collectives: bool = True,
                            capacity: int | None = None) -> MeshSweepResult:
        """Per-device estimates for a grid of mesh topologies from ONE
        cached trace (ROADMAP: multi-device topologies as first-class
        estimation targets).

        Stage 1 (jaxpr tracing) is topology-independent: the phases are
        traced once (or served from the trace cache) and stages 2-5 —
        compose, spec-driven shard factors, per-axis collective
        injection, vectorized replay — re-run per topology. With
        ``shard_factors="spec"`` each topology's factors come from the
        PartitionSpecs the sharding engine would place at that mesh,
        divisibility fallbacks included; ``collectives=True`` injects
        the per-axis staging buffers (``mesh_collective_specs``).
        """
        from ..distributed.sharding import (mesh_collective_specs,
                                            shard_factor_fn)
        t0 = time.perf_counter()
        est = self.estimator
        cache = est.trace_cache
        h0, m0 = cache.hits, cache.misses
        fwd, upd, init = est.trace_phases(fwd_bwd_fn, params, batch,
                                          update_fn, opt_init_fn)
        self._resolve_coupling(upd)
        t_trace = time.perf_counter() - t0
        opt_state = init.out_shape if init is not None else None
        reports = []
        for topo in topologies:
            mesh = topo.axis_sizes
            pol = topo.sharding_policy()
            factor = shard_factor_fn(cfg, mesh, pol, mode=shard_factors,
                                     params=params, opt_state=opt_state,
                                     batch=batch)
            specs = (mesh_collective_specs(mesh, pol)
                     if collectives else ())
            reports.append(est.estimate_from_phases(
                fwd, upd, init, shard_factor_fn=factor,
                collective_specs=specs, capacity=capacity))
        stats = {
            "topologies": len(reports),
            "trace_s": t_trace,
            "trace_cache": {"hits": cache.hits - h0,
                            "misses": cache.misses - m0},
            "wall_s": time.perf_counter() - t0,
            "shard_factors": shard_factors,
        }
        return MeshSweepResult(list(topologies), reports, stats)

    def estimate_serving_sweep(self, decode_fn, params, cache, batch, *,
                               stream, knob_grid: Sequence,
                               kv_bytes_per_token: int,
                               resident_bytes_per_request: int = 0,
                               capacity: int | None = None
                               ) -> ServingSweepResult:
        """Serving estimates for a grid of :class:`ServingKnobs` from at
        most ONE fresh decode trace (the serving analogue of
        :meth:`estimate_mesh_sweep`).

        Tracing is knob-independent — page size, concurrency, and KV
        dtype only change the CPU-side request-stream lowering and the
        allocator replay, so the whole grid shares one cached trace.
        The fresh-trace count is reported in ``stats["trace_cache"]``
        and bench-asserted (``SERVING_TRACE_BUDGET``)."""
        t0 = time.perf_counter()
        est = self.estimator
        tcache = est.trace_cache
        h0, m0 = tcache.hits, tcache.misses
        estimates = [
            est.estimate_request_stream(
                decode_fn, params, cache, batch, stream=stream,
                knobs=k, kv_bytes_per_token=kv_bytes_per_token,
                resident_bytes_per_request=resident_bytes_per_request,
                capacity=capacity)
            for k in knob_grid]
        stats = {
            "knobs": len(estimates),
            "trace_cache": {"hits": tcache.hits - h0,
                            "misses": tcache.misses - m0},
            "wall_s": time.perf_counter() - t0,
        }
        return ServingSweepResult(list(knob_grid), estimates, stats)

    def estimate_many(self, points: Sequence[SweepPoint],
                      interpolate: bool = True) -> SweepResult:
        t0 = time.perf_counter()
        points = list(points)
        reports: dict[int, EstimateReport] = {}
        stats = {"points": len(points), "traced": 0, "interpolated": 0,
                 "fallback": 0, "pooled": 0,
                 "pool_workers": self.processes}

        # group points that can share an interpolation model: same fns,
        # same params signature
        groups: dict[tuple, list[int]] = {}
        for i, p in enumerate(points):
            key = (id(p.fwd_bwd_fn), id(p.update_fn), id(p.opt_init_fn),
                   _leaf_sig(p.params))
            groups.setdefault(key, []).append(i)

        for idxs in groups.values():
            gpoints = [points[i] for i in idxs]
            scalars = _sweep_scalars(gpoints) if interpolate else None
            self._run_group(points, idxs, scalars, reports, stats)

        stats["wall_s"] = time.perf_counter() - t0
        stats["cache"] = self.estimator.trace_cache.stats()
        return SweepResult([reports[i] for i in range(len(points))], stats)


def estimate_many(points: Sequence[SweepPoint],
                  estimator: XMemEstimator | None = None,
                  processes: int = 0,
                  interpolate: bool = True) -> SweepResult:
    """One-shot sweep: see :class:`SweepService`. Creating a service is
    preferable when sweeping repeatedly (warm pool + cache)."""
    svc = SweepService(estimator, processes=processes)
    try:
        return svc.estimate_many(points, interpolate=interpolate)
    finally:
        svc.close()
