"""Memory Simulator — final stage of the xMem pipeline (paper §3.4).

Replays the orchestrated block lifecycles chronologically through the
two-level allocator simulation and reports:

* estimated peak memory (reserved *segments* — the quantity a scheduler
  must budget, paper §2.2.2),
* peak allocated (tensor) bytes — the naive lower bound,
* the full usage curve over time (paper's optional output, used for the
  Fig.-6-style fidelity benchmark),
* OOM verdict for a given capacity — OOM fires only when both simulated
  levels fail after cache reclaim, mirroring the real chain.

Fast-path extensions (ISSUE 1):

* ``replay`` accepts a ``PeriodicBlocks`` composition and replays the
  repeated middle iterations with **steady-state detection**: once the
  allocator's state fingerprint at two consecutive iteration boundaries
  matches (the paper's §3.1 observation that allocator state stabilizes
  within 2-3 iterations), the remaining identical iterations are skipped
  — their trajectories are provably exact repeats — and replay resumes
  at the final iteration. Replay cost becomes independent of N.
* ``min_feasible_capacity`` computes the smallest device capacity at
  which the job replays without OOM from **one instrumented replay**
  (max over time of in-use segment demand), verifying minimality with
  two bounded replays and falling back to page-granular bisection only
  when the allocator's reclaim behavior genuinely shifts the answer —
  O(1) replays in the common case versus O(capacities) for a sweep of
  ``would_oom`` calls.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

from .allocator import (AllocatorPolicy, CachingAllocatorSim, CUDA_CACHING,
                        DeviceAllocatorSim, SimOOMError, round_up)
from .events import (BlockLifecycle, PeriodicBlocks, lifecycles_to_events,
                     shift_cycle_bid, split_cycle_bid)

_UNBOUNDED = 1 << 62


@dataclasses.dataclass
class SimResult:
    peak_reserved: int            # the estimate a scheduler budgets
    peak_allocated: int           # sum-of-live-tensors peak (naive bound)
    oom: bool
    oom_at: int | None            # event index of OOM, if any
    curve: list[tuple[int, int, int]]   # (t, allocated, reserved)
    stats: dict
    segments: list[dict]          # final segment map (fidelity plots)

    @property
    def fragmentation_overhead(self) -> float:
        if not self.peak_allocated:
            return 0.0
        return self.peak_reserved / self.peak_allocated - 1.0


def _event_tuples(blocks: Sequence[BlockLifecycle], seq0: int
                  ) -> list[tuple[int, int, int, int, int, int]]:
    """(t, order, seq, kind, block_id, size) tuples, sorted the same way
    ``lifecycles_to_events`` sorts: frees before allocs at equal t, ties
    broken by block position (``seq``) — the order the allocator sees."""
    evs = []
    for i, b in enumerate(blocks):
        s = b.sharded_size
        evs.append((b.alloc_t, 1, seq0 + i, 1, b.block_id, s))
        if b.free_t is not None:
            evs.append((b.free_t, 0, seq0 + i, 0, b.block_id, s))
    evs.sort()
    return evs


class MemorySimulator:
    def __init__(self, policy: AllocatorPolicy = CUDA_CACHING,
                 capacity: int = _UNBOUNDED):
        self.policy = policy
        self.capacity = capacity
        self.last_capacity_replays = 0    # replays used by the last sweep

    def replay(self, blocks, steady_state: bool = True) -> SimResult:
        """Replay a flat lifecycle list or a ``PeriodicBlocks`` program."""
        if isinstance(blocks, PeriodicBlocks):
            return self._replay_periodic(blocks, steady_state)
        events = lifecycles_to_events(blocks)
        device = DeviceAllocatorSim(self.capacity, self.policy.device_page)
        sim = CachingAllocatorSim(self.policy, device)
        handles: dict[int, int] = {}
        oom, oom_at = False, None
        for i, e in enumerate(events):
            try:
                if e.kind == "alloc":
                    if e.size <= 0:
                        continue
                    handles[e.block_id] = sim.malloc(e.size, t=e.t)
                else:
                    h = handles.pop(e.block_id, None)
                    if h is not None:
                        sim.free(h, t=e.t)
            except SimOOMError:
                oom, oom_at = True, i
                break
        return self._result(sim, oom, oom_at)

    @staticmethod
    def _result(sim: CachingAllocatorSim, oom: bool, oom_at,
                extra_stats: dict | None = None) -> SimResult:
        stats = sim.stats()
        if extra_stats:
            stats.update(extra_stats)
        return SimResult(
            peak_reserved=sim.peak_reserved,
            peak_allocated=sim.peak_allocated,
            oom=oom,
            oom_at=oom_at,
            curve=sim.timeline,
            stats=stats,
            segments=sim.segments_snapshot(),
        )

    def _replay_event_tuples(self, evs, nc: int) -> SimResult:
        """Linear replay of pre-merged (t, order, seq, kind, bid, size)
        tuples — the small-N fast path (no heap, no boundary tracking)."""
        device = DeviceAllocatorSim(self.capacity, self.policy.device_page)
        sim = CachingAllocatorSim(self.policy, device)
        handles: dict[int, int] = {}
        oom, oom_at = False, None
        n_done = 0
        try:
            for t, _o, _s, kind, bid, size in evs:
                if kind == 1:
                    if size > 0:
                        handles[bid] = sim.malloc(size, t=t)
                else:
                    h = handles.pop(bid, None)
                    if h is not None:
                        sim.free(h, t=t)
                n_done += 1
        except SimOOMError:
            oom, oom_at = True, n_done
        return self._result(sim, oom, oom_at, extra_stats={
            "steady_state": {"cycles_total": nc, "cycles_skipped": 0,
                             "detected_at": None, "period": None},
            "events_replayed": n_done,
        })

    # -- periodic replay with steady-state extrapolation ---------------------
    def _replay_periodic(self, pb: PeriodicBlocks,
                         steady_state: bool = True) -> SimResult:
        P, nc = pb.period, pb.n_cycles
        base = _event_tuples(pb.cycle, seq0=len(pb.prefix))
        cycle_start = pb.meta.get("cycle_start")
        # Steady-state bookkeeping is only sound when each cycle instance's
        # events stay within two periods of its window start (alloc in its
        # own window, frees at most one full window ahead — at_next_iter
        # gradients and next-iteration output release land exactly on the
        # +2P boundary). Compositions violating that replay fully.
        span_ok = (nc > 0 and cycle_start is not None and P > 0
                   and (not base or base[-1][0] <= cycle_start + 2 * P))
        if nc > 1 and not span_ok:
            return self.replay(pb.materialize(), steady_state=False)

        prefix_ev = _event_tuples(pb.prefix, seq0=0)
        suffix_ev = _event_tuples(
            pb.suffix, seq0=len(pb.prefix) + nc * len(pb.cycle))
        if nc < 3 or not steady_state:
            # too few cycles for a skip to ever pay off (detection needs
            # two boundary fingerprints plus at least one window to
            # jump): replay the fully merged stream without the heap
            evs = list(prefix_ev)
            C = len(pb.cycle)
            for k in range(nc):
                dt, ds = k * P, k * C
                evs.extend((t + dt, o, s + ds, kind,
                            shift_cycle_bid(bid, k), size)
                           for t, o, s, kind, bid, size in base)
            evs.extend(suffix_ev)
            evs.sort()
            return self._replay_event_tuples(evs, nc)
        device = DeviceAllocatorSim(self.capacity, self.policy.device_page)
        sim = CachingAllocatorSim(self.policy, device)
        handles: dict[int, int] = {}
        oom, oom_at = False, None
        n_done = 0

        # heap entries: (t, order, seq, src, idx, inst) where src is one of
        # "p"(refix), "c"(ycle instance), "s"(uffix)
        heap: list = []

        def push(src: str, idx: int, inst: int = 0) -> None:
            if src == "p":
                if idx >= len(prefix_ev):
                    return
                t, order, seq, *_ = prefix_ev[idx]
            elif src == "s":
                if idx >= len(suffix_ev):
                    return
                t, order, seq, *_ = suffix_ev[idx]
            else:
                if idx >= len(base):
                    return
                t, order, seq, *_ = base[idx]
                t += inst * P
                seq += inst * len(pb.cycle)
            heapq.heappush(heap, (t, order, seq, src, idx, inst))

        def payload(src: str, idx: int, inst: int) -> tuple[int, int, int, int]:
            if src == "p":
                t, _, _, kind, bid, size = prefix_ev[idx]
            elif src == "s":
                t, _, _, kind, bid, size = suffix_ev[idx]
            else:
                t, _, _, kind, bid, size = base[idx]
                t += inst * P
                bid = shift_cycle_bid(bid, inst)
            return t, kind, bid, size

        push("p", 0)
        push("s", 0)
        if nc > 0:
            push("c", 0, 0)
        activated = 1 if nc > 0 else 0   # cycle instances with events pushed
        prefix_left = len(prefix_ev)     # prefix events not yet processed

        def handle_pattern(boundary: int) -> int:
            """Live-handle structure relative to the boundary index —
            must repeat (with the instance index rebased) for the future
            event stream to act on an isomorphic state."""
            pat = []
            for bid in handles:
                inst, raw = split_cycle_bid(bid)
                if inst >= 0:
                    pat.append((1, boundary - inst, raw))
                else:
                    pat.append((0, 0, bid))
            pat.sort()
            return hash(tuple(pat))

        jb = 1                              # next boundary index to observe
        next_boundary = (cycle_start + P) if span_ok else None
        fp_hist: list = []                  # fingerprints at B_1..B_{jb-1}
        max_period = 4                      # e.g. at_next_iter grads double-
        detected_at = None                  # buffer -> state period 2
        skipped_cycles = 0
        ss_period = None

        def first_base_at(t_cut: int) -> int:
            i = 0
            while i < len(base) and base[i][0] < t_cut:
                i += 1
            return i

        while heap:
            t_min = heap[0][0]
            # boundary bookkeeping: fingerprint when replay first reaches
            # each cycle-window start B_j = cycle_start + j*P
            skip_done = False
            while (next_boundary is not None and t_min >= next_boundary
                   and jb <= nc):
                fp = (sim.state_fingerprint(), handle_pattern(jb))
                p_found = None
                for p in range(1, min(max_period, len(fp_hist)) + 1):
                    if fp_hist[-p] == fp:
                        p_found = p
                        break
                m = ((nc - jb) // p_found) * p_found if p_found else 0
                if steady_state and m > 0 and prefix_left == 0:
                    # the state cycles with period p: windows jb..jb+m-1
                    # are exact repeats — jump m windows ahead with the
                    # live cycle handles rebased by m instances, then
                    # replay the < p remaining windows + tail + suffix.
                    jp = jb + m
                    remapped: dict[int, int] = {}
                    for bid, h in handles.items():
                        inst, raw = split_cycle_bid(bid)
                        if inst >= 0:
                            bid = shift_cycle_bid(raw, inst + m)
                        remapped[bid] = h
                    handles = remapped
                    heap = []
                    # instances jp-2 / jp-1 contribute their events from
                    # B_jp onward (span <= 2 periods, checked above)
                    for back in (2, 1):
                        inst = jp - back
                        if 0 <= inst < nc:
                            push("c",
                                 first_base_at(cycle_start + back * P), inst)
                    if jp < nc:
                        push("c", 0, jp)
                        activated = jp + 1
                    else:
                        activated = nc
                    push("s", 0)
                    detected_at = jb
                    skipped_cycles = m
                    ss_period = p_found
                    next_boundary = None
                    skip_done = True
                    break
                fp_hist.append(fp)
                jb += 1
                next_boundary = (cycle_start + jb * P) if jb <= nc else None
            if skip_done:
                continue                  # stream rebuilt; re-enter loop
            _, _, _, src, idx, inst = heapq.heappop(heap)
            if src == "p":
                prefix_left -= 1
                push("p", idx + 1)
            elif src == "s":
                push("s", idx + 1)
            else:
                push("c", idx + 1, inst)
                if idx == 0 and inst + 1 < nc and activated == inst + 1:
                    push("c", 0, inst + 1)    # activate the next instance
                    activated += 1
            t, kind, bid, size = payload(src, idx, inst)
            try:
                if kind == 1:
                    if size > 0:
                        handles[bid] = sim.malloc(size, t=t)
                else:
                    h = handles.pop(bid, None)
                    if h is not None:
                        sim.free(h, t=t)
            except SimOOMError:
                oom, oom_at = True, n_done
                break
            n_done += 1
        return self._result(sim, oom, oom_at, extra_stats={
            "steady_state": {
                "cycles_total": nc,
                "cycles_skipped": skipped_cycles,
                "detected_at": detected_at,
                "period": ss_period,
            },
            "events_replayed": n_done,
        })

    # -- capacity probing ------------------------------------------------------
    def would_oom(self, blocks, capacity: int) -> bool:
        """Two-level OOM verdict at a specific capacity (PEF round 2)."""
        return MemorySimulator(self.policy, capacity).replay(blocks).oom

    def min_feasible_capacity(self, blocks,
                              probe: SimResult | None = None) -> int:
        """Smallest capacity at which ``blocks`` replays without OOM.

        One instrumented unbounded replay yields the max in-use segment
        demand (the candidate) plus a proven bracket: ``peak_allocated``
        rounded up is a hard lower bound, and an unbounded run's
        ``peak_reserved`` is always feasible (the trajectory is identical
        at that capacity). Two verification replays confirm the candidate
        in the common case; otherwise a page-granular bisection inside
        the bracket resolves reclaim-induced divergence.
        """
        page = max(self.policy.device_page, 1)
        # a usable probe must be a COMPLETE unbounded replay: an OOM'd or
        # capacity-constrained run has truncated peaks/demand (and its
        # reclaim behavior invalidates the feasible-by-identity bracket)
        if (probe is None or probe.oom
                or "max_inuse_demand" not in probe.stats):
            probe = MemorySimulator(self.policy, _UNBOUNDED).replay(blocks)
            self.last_capacity_replays = 1
        else:
            self.last_capacity_replays = 0
        if probe.peak_reserved <= 0:
            return 0
        lo = round_up(max(probe.peak_allocated, 1), page)
        hi = round_up(probe.peak_reserved, page)      # feasible by identity
        cand = min(max(round_up(
            probe.stats.get("max_inuse_demand", hi), page), lo), hi)

        def feasible(c: int) -> bool:
            self.last_capacity_replays += 1
            return not self.would_oom(blocks, c)

        lo_k, hi_k = lo // page, hi // page
        if feasible(cand):
            if cand <= lo or not feasible(cand - page):
                return cand                            # O(1) replays
            hi_k = cand // page - 1
        else:
            lo_k = cand // page + 1
        while lo_k < hi_k:
            mid = (lo_k + hi_k) // 2
            if feasible(mid * page):
                hi_k = mid
            else:
                lo_k = mid + 1
        return hi_k * page
