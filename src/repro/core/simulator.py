"""Memory Simulator — final stage of the xMem pipeline (paper §3.4).

Replays the orchestrated block lifecycles chronologically through the
two-level allocator simulation and reports:

* estimated peak memory (reserved *segments* — the quantity a scheduler
  must budget, paper §2.2.2),
* peak allocated (tensor) bytes — the naive lower bound,
* the full usage curve over time (paper's optional output, used for the
  Fig.-6-style fidelity benchmark),
* OOM verdict for a given capacity — OOM fires only when both simulated
  levels fail after cache reclaim, mirroring the real chain.

Fast-path extensions (ISSUE 1):

* ``replay`` accepts a ``PeriodicBlocks`` composition and replays the
  repeated middle iterations with **steady-state detection**: once the
  allocator's state fingerprint at two consecutive iteration boundaries
  matches (the paper's §3.1 observation that allocator state stabilizes
  within 2-3 iterations), the remaining identical iterations are skipped
  — their trajectories are provably exact repeats — and replay resumes
  at the final iteration. Replay cost becomes independent of N.
* ``min_feasible_capacity`` computes the smallest device capacity at
  which the job replays without OOM from **one instrumented replay**
  (max over time of in-use segment demand), verifying minimality with
  two bounded replays and falling back to page-granular bisection only
  when the allocator's reclaim behavior genuinely shifts the answer —
  O(1) replays in the common case versus O(capacities) for a sweep of
  ``would_oom`` calls.
"""
from __future__ import annotations

import dataclasses
import heapq
from operator import attrgetter
from typing import Sequence

import numpy as np

from ..obs import spans as obs_spans
from .allocator import (AllocatorPolicy, CachingAllocatorSim, CUDA_CACHING,
                        DeviceAllocatorSim, SimOOMError, default_space_specs,
                        round_size_array, round_up, round_up_array)
from .events import (CYCLE_ID_STRIDE, BlockLifecycle, ComposedBlocks,
                     MemorySpace, PeriodicBlocks, lifecycles_to_events,
                     sharded_sizes_array, shift_cycle_bid, split_cycle_bid)

_UNBOUNDED = 1 << 62

#: Above this many expanded event rows the columnar engine hands back to
#: the object engine, whose steady-state replay is O(cycle) in N while
#: tiled expansion is O(N * cycle).
_MAX_COLUMNAR_EVENTS = 4_000_000


# -- columnar programs (vectorized replay engine) ----------------------------
@dataclasses.dataclass
class ColumnarProgram:
    """A replay-ready, time-sorted columnar event stream.

    Rows are sorted exactly the way the object engine orders its merged
    stream — primary ``t``, frees (kind 0) before allocs (kind 1) at
    equal ``t``, ties broken by block position — so event indices (and
    therefore ``oom_at``) coincide between engines. ``size`` is the
    sharded request size; ``exec_mask`` marks events that actually drive
    the allocator (positive-size allocs, and frees whose alloc both
    executes and precedes them), mirroring the object engine's skip
    rules. A program is immutable and capacity-independent: one build
    serves every probe of a capacity sweep and every point of a batch
    sweep that shares the structure.
    """

    t: np.ndarray          # int64 logical clock
    kind: np.ndarray       # int8: 1 = alloc, 0 = free
    bid: np.ndarray        # int64 block id
    size: np.ndarray       # int64 sharded request bytes
    exec_mask: np.ndarray  # bool: event reaches the allocator
    _n_blocks: int = 0
    _traj: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return int(self.t.shape[0])

    @property
    def unique_bids(self) -> bool:
        flag = self._traj.get("unique_bids")
        if flag is None:
            allocs = self.bid[self.kind == 1]
            flag = int(np.unique(allocs).shape[0]) == self._n_blocks
            self._traj["unique_bids"] = flag
        return flag

    def arena_trajectory(self, policy: AllocatorPolicy):
        """Capacity-independent arena state curves (live bytes, page
        demand), computed once and cached — every capacity probe of a
        sweep reuses them, so probing K capacities costs one pass plus
        K comparisons."""
        key = (policy.min_block, policy.device_page)
        traj = self._traj.get(key)
        if traj is None:
            exec_mask = self.exec_mask
            exec_alloc = exec_mask & (self.kind == 1)
            rounded = round_size_array(self.size, policy)
            delta = np.where(exec_mask,
                             np.where(self.kind == 1, rounded, -rounded), 0)
            live = np.cumsum(delta)
            want = np.where(exec_alloc,
                            round_up_array(live, policy.device_page), 0)
            traj = self._traj[key] = (exec_alloc, live, want)
        return traj


_BLOCK_COLS = attrgetter("block_id", "size", "alloc_t", "free_t",
                         "shard_factor")


def _block_arrays(blocks: Sequence[BlockLifecycle]):
    n = len(blocks)
    if not n:
        z = np.empty(0, np.int64)
        return z, z, z, z
    bid, size, at, ft, shard = zip(*map(_BLOCK_COLS, blocks))
    bid = np.array(bid, np.int64)
    size = np.array(size, np.int64)
    at = np.array(at, np.int64)
    ft = np.fromiter((-1 if v is None else v for v in ft), np.int64, n)
    shard = np.array(shard, np.float64)
    if np.any(shard != 1.0):
        size = sharded_sizes_array(size, shard)
    return bid, size, at, ft


def _program_from_block_arrays(bid, size, at, ft) -> ColumnarProgram:
    """Expand per-lifecycle columns (free_t == -1 means persistent) into
    the sorted event stream. Row ``i < n_blocks`` is block i's alloc;
    the tail rows are the frees, paired by construction."""
    n_b = int(bid.shape[0])
    idx_f = np.nonzero(ft >= 0)[0]
    n_f = int(idx_f.shape[0])
    n_ev = n_b + n_f

    def expand(col, fill=None):
        out = np.empty(n_ev, col.dtype)
        out[:n_b] = col
        out[n_b:] = col[idx_f] if fill is None else fill
        return out

    ev_t = expand(at, fill=ft[idx_f])
    ev_bid = expand(bid)
    ev_size = expand(size)
    ev_kind = np.zeros(n_ev, np.int8)
    ev_kind[:n_b] = 1
    ev_seq = np.empty(n_ev, np.int64)
    ev_seq[:n_b] = np.arange(n_b)
    ev_seq[n_b:] = idx_f
    order = np.lexsort((ev_seq, ev_kind, ev_t))
    pos = np.empty(n_ev, np.int64)
    pos[order] = np.arange(n_ev)
    alloc_ok = size > 0
    ev_exec = np.empty(n_ev, bool)
    ev_exec[:n_b] = alloc_ok
    ev_exec[n_b:] = alloc_ok[idx_f] & (pos[:n_b][idx_f] < pos[n_b:])
    return ColumnarProgram(ev_t[order], ev_kind[order], ev_bid[order],
                           ev_size[order], ev_exec[order], n_b)


def program_from_lifecycles(blocks: Sequence[BlockLifecycle]
                            ) -> ColumnarProgram:
    return _program_from_block_arrays(*_block_arrays(blocks))


def program_from_periodic(pb: PeriodicBlocks) -> ColumnarProgram:
    """Expand a periodic composition with array arithmetic: the middle
    iterations are offset-shifted tiles of the cycle template (times
    shifted by k*period, ids by the cycle-instance stride) — no
    per-event Python objects are ever built."""
    parts = [_block_arrays(pb.prefix)]
    nc, P = pb.n_cycles, pb.period
    if nc > 0 and len(pb.cycle):
        c_bid, c_size, c_at, c_ft = _block_arrays(pb.cycle)
        C = c_bid.shape[0]
        inst = np.arange(nc, dtype=np.int64)
        dt = (inst * P)[:, None]
        shift = ((inst + 1) * CYCLE_ID_STRIDE)[:, None]
        ft_tiled = np.where(c_ft[None, :] < 0, np.int64(-1),
                            c_ft[None, :] + dt)
        parts.append(((c_bid[None, :] + shift).ravel(),
                      np.broadcast_to(c_size, (nc, C)).ravel(),
                      (c_at[None, :] + dt).ravel(),
                      ft_tiled.ravel()))
    parts.append(_block_arrays(pb.suffix))
    bid, size, at, ft = (np.concatenate(cols) for cols in zip(*parts))
    return _program_from_block_arrays(bid, size, at, ft)


@dataclasses.dataclass
class SimResult:
    peak_reserved: int            # the estimate a scheduler budgets
    peak_allocated: int           # sum-of-live-tensors peak (naive bound)
    oom: bool
    oom_at: int | None            # event index of OOM, if any
    curve: list[tuple[int, int, int]]   # (t, allocated, reserved)
    stats: dict
    segments: list[dict]          # final segment map (fidelity plots)

    @property
    def fragmentation_overhead(self) -> float:
        if not self.peak_allocated:
            return 0.0
        return self.peak_reserved / self.peak_allocated - 1.0


def split_blocks_by_space(blocks):
    """Partition a flat lifecycle list or ``PeriodicBlocks`` composition
    into per-space sub-compositions (same structure, same times — each
    space's allocator sees only its own demand). Returns a dict keyed by
    :class:`MemorySpace`; inputs that never left the device return a
    single-entry dict holding the *original* object, so the all-device
    replay path is byte-for-byte the one-space case."""
    if isinstance(blocks, PeriodicBlocks):
        spaces = {b.space for part in (blocks.prefix, blocks.cycle,
                                       blocks.suffix) for b in part}
        if spaces <= {MemorySpace.DEVICE_HBM}:
            return {MemorySpace.DEVICE_HBM: blocks}
        out = {}
        for s in spaces:
            out[s] = PeriodicBlocks(
                [b for b in blocks.prefix if b.space is s],
                [b for b in blocks.cycle if b.space is s],
                blocks.n_cycles, blocks.period,
                [b for b in blocks.suffix if b.space is s],
                dict(blocks.meta))
        return out
    if isinstance(blocks, ComposedBlocks):
        # non-periodic composition (e.g. RequestBlocks): all-device
        # inputs keep the ORIGINAL object (single-space replay path is
        # byte-for-byte the composed replay); mixed-space inputs fall
        # through to the flat partition over the materialized stream
        spaces = {b.space for b in blocks.iter_groups()}
        if spaces <= {MemorySpace.DEVICE_HBM}:
            return {MemorySpace.DEVICE_HBM: blocks}
        blocks = blocks.materialize()
    spaces = {b.space for b in blocks}
    if spaces <= {MemorySpace.DEVICE_HBM}:
        return {MemorySpace.DEVICE_HBM: blocks}
    out = {s: [] for s in spaces}
    for b in blocks:
        out[b.space].append(b)
    return out


def _event_tuples(blocks: Sequence[BlockLifecycle], seq0: int
                  ) -> list[tuple[int, int, int, int, int, int]]:
    """(t, order, seq, kind, block_id, size) tuples, sorted the same way
    ``lifecycles_to_events`` sorts: frees before allocs at equal t, ties
    broken by block position (``seq``) — the order the allocator sees."""
    evs = []
    for i, b in enumerate(blocks):
        s = b.sharded_size
        evs.append((b.alloc_t, 1, seq0 + i, 1, b.block_id, s))
        if b.free_t is not None:
            evs.append((b.free_t, 0, seq0 + i, 0, b.block_id, s))
    evs.sort()
    return evs


class MemorySimulator:
    """Two-level allocator replay with two interchangeable engines.

    ``engine="object"`` (default) is the reference implementation: the
    per-event Python interpreter, including steady-state extrapolation
    for periodic compositions. ``engine="columnar"`` replays a
    :class:`ColumnarProgram` — exact vectorized prefix-sum liveness for
    the arena policy, a batched stepper (numpy rounding + tight loop
    over primitive columns) for the BFC policies — and falls back to the
    object engine whenever a program cannot represent the input (block-id
    collisions, or expansions past ``_MAX_COLUMNAR_EVENTS`` where
    steady-state skipping wins). Both engines produce identical
    ``SimResult`` peaks and OOM points (tests/test_columnar.py).
    """

    def __init__(self, policy: AllocatorPolicy = CUDA_CACHING,
                 capacity: int = _UNBOUNDED, engine: str = "object"):
        if engine not in ("object", "columnar"):
            raise ValueError(f"unknown replay engine {engine!r}")
        self.policy = policy
        self.capacity = capacity
        self.engine = engine
        self.last_capacity_replays = 0    # replays used by the last sweep

    # -- columnar dispatch ----------------------------------------------------
    def as_program(self, blocks) -> ColumnarProgram | None:
        """Build (or pass through) a columnar program, or None when the
        input needs the object engine. A *prebuilt* program that this
        policy cannot replay (arena + colliding block ids) raises — it
        carries no lifecycles to fall back to."""
        if isinstance(blocks, ColumnarProgram):
            if self.policy.arena and not blocks.unique_bids:
                raise ValueError(
                    "ColumnarProgram has colliding block ids: the arena "
                    "engine needs unique lifecycle ids — replay the "
                    "original lifecycles instead (the object engine "
                    "resolves collisions through its handle table)")
            return blocks
        if isinstance(blocks, PeriodicBlocks):
            rows = 2 * (len(blocks.prefix) + len(blocks.suffix)
                        + blocks.n_cycles * len(blocks.cycle))
            if rows > _MAX_COLUMNAR_EVENTS:
                return None
            prog = program_from_periodic(blocks)
        else:
            if isinstance(blocks, ComposedBlocks):
                blocks = blocks.materialize()
            if 2 * len(blocks) > _MAX_COLUMNAR_EVENTS:
                return None
            prog = program_from_lifecycles(blocks)
        if self.policy.arena and not prog.unique_bids:
            # the vectorized pairing assumes one lifecycle per id; the
            # object engine's handle table resolves collisions instead
            return None
        return prog

    def replay_program(self, prog: ColumnarProgram) -> SimResult:
        if self.policy.arena:
            return self._replay_arena_program(prog)
        return self._replay_bfc_program(prog)

    def replay(self, blocks, steady_state: bool = True) -> SimResult:
        """Replay a flat lifecycle list, a ``PeriodicBlocks`` composition
        or a prebuilt ``ColumnarProgram``."""
        # ISSUE 10: replay span — one ContextVar.get when observability
        # is off; the replay itself is untouched either way
        with obs_spans.span("simulator.replay", engine=self.engine):
            if self.engine == "columnar" \
                    or isinstance(blocks, ColumnarProgram):
                prog = self.as_program(blocks)
                if prog is not None:
                    return self.replay_program(prog)
            if isinstance(blocks, PeriodicBlocks):
                return self._replay_periodic(blocks, steady_state)
            if isinstance(blocks, ComposedBlocks):
                blocks = blocks.materialize()
            events = lifecycles_to_events(blocks)
            device = DeviceAllocatorSim(self.capacity,
                                        self.policy.device_page)
            sim = CachingAllocatorSim(self.policy, device)
            handles: dict[int, int] = {}
            oom, oom_at = False, None
            for i, e in enumerate(events):
                try:
                    if e.kind == "alloc":
                        if e.size <= 0:
                            continue
                        handles[e.block_id] = sim.malloc(e.size, t=e.t)
                    else:
                        h = handles.pop(e.block_id, None)
                        if h is not None:
                            sim.free(h, t=e.t)
                except SimOOMError:
                    oom, oom_at = True, i
                    break
            return self._result(sim, oom, oom_at)

    @staticmethod
    def _result(sim: CachingAllocatorSim, oom: bool, oom_at,
                extra_stats: dict | None = None) -> SimResult:
        stats = sim.stats()
        if extra_stats:
            stats.update(extra_stats)
        return SimResult(
            peak_reserved=sim.peak_reserved,
            peak_allocated=sim.peak_allocated,
            oom=oom,
            oom_at=oom_at,
            curve=sim.timeline,
            stats=stats,
            segments=sim.segments_snapshot(),
        )

    def _replay_event_tuples(self, evs, nc: int) -> SimResult:
        """Linear replay of pre-merged (t, order, seq, kind, bid, size)
        tuples — the small-N fast path (no heap, no boundary tracking)."""
        device = DeviceAllocatorSim(self.capacity, self.policy.device_page)
        sim = CachingAllocatorSim(self.policy, device)
        handles: dict[int, int] = {}
        oom, oom_at = False, None
        n_done = 0
        try:
            for t, _o, _s, kind, bid, size in evs:
                if kind == 1:
                    if size > 0:
                        handles[bid] = sim.malloc(size, t=t)
                else:
                    h = handles.pop(bid, None)
                    if h is not None:
                        sim.free(h, t=t)
                n_done += 1
        except SimOOMError:
            oom, oom_at = True, n_done
        return self._result(sim, oom, oom_at, extra_stats={
            "steady_state": {"cycles_total": nc, "cycles_skipped": 0,
                             "detected_at": None, "period": None},
            "events_replayed": n_done,
        })

    # -- periodic replay with steady-state extrapolation ---------------------
    def _replay_periodic(self, pb: PeriodicBlocks,
                         steady_state: bool = True) -> SimResult:
        P, nc = pb.period, pb.n_cycles
        base = _event_tuples(pb.cycle, seq0=len(pb.prefix))
        cycle_start = pb.meta.get("cycle_start")
        # Steady-state bookkeeping is only sound when each cycle instance's
        # events stay within two periods of its window start (alloc in its
        # own window, frees at most one full window ahead — at_next_iter
        # gradients and next-iteration output release land exactly on the
        # +2P boundary). Compositions violating that replay fully.
        span_ok = (nc > 0 and cycle_start is not None and P > 0
                   and (not base or base[-1][0] <= cycle_start + 2 * P))
        if nc > 1 and not span_ok:
            return self.replay(pb.materialize(), steady_state=False)

        prefix_ev = _event_tuples(pb.prefix, seq0=0)
        suffix_ev = _event_tuples(
            pb.suffix, seq0=len(pb.prefix) + nc * len(pb.cycle))
        if nc < 3 or not steady_state:
            # too few cycles for a skip to ever pay off (detection needs
            # two boundary fingerprints plus at least one window to
            # jump): replay the fully merged stream without the heap
            evs = list(prefix_ev)
            C = len(pb.cycle)
            for k in range(nc):
                dt, ds = k * P, k * C
                evs.extend((t + dt, o, s + ds, kind,
                            shift_cycle_bid(bid, k), size)
                           for t, o, s, kind, bid, size in base)
            evs.extend(suffix_ev)
            evs.sort()
            return self._replay_event_tuples(evs, nc)
        device = DeviceAllocatorSim(self.capacity, self.policy.device_page)
        sim = CachingAllocatorSim(self.policy, device)
        handles: dict[int, int] = {}
        oom, oom_at = False, None
        n_done = 0

        # heap entries: (t, order, seq, src, idx, inst) where src is one of
        # "p"(refix), "c"(ycle instance), "s"(uffix)
        heap: list = []

        def push(src: str, idx: int, inst: int = 0) -> None:
            if src == "p":
                if idx >= len(prefix_ev):
                    return
                t, order, seq, *_ = prefix_ev[idx]
            elif src == "s":
                if idx >= len(suffix_ev):
                    return
                t, order, seq, *_ = suffix_ev[idx]
            else:
                if idx >= len(base):
                    return
                t, order, seq, *_ = base[idx]
                t += inst * P
                seq += inst * len(pb.cycle)
            heapq.heappush(heap, (t, order, seq, src, idx, inst))

        def payload(src: str, idx: int, inst: int) -> tuple[int, int, int, int]:
            if src == "p":
                t, _, _, kind, bid, size = prefix_ev[idx]
            elif src == "s":
                t, _, _, kind, bid, size = suffix_ev[idx]
            else:
                t, _, _, kind, bid, size = base[idx]
                t += inst * P
                bid = shift_cycle_bid(bid, inst)
            return t, kind, bid, size

        push("p", 0)
        push("s", 0)
        if nc > 0:
            push("c", 0, 0)
        activated = 1 if nc > 0 else 0   # cycle instances with events pushed
        prefix_left = len(prefix_ev)     # prefix events not yet processed

        def handle_pattern(boundary: int) -> int:
            """Live-handle structure relative to the boundary index —
            must repeat (with the instance index rebased) for the future
            event stream to act on an isomorphic state."""
            pat = []
            for bid in handles:
                inst, raw = split_cycle_bid(bid)
                if inst >= 0:
                    pat.append((1, boundary - inst, raw))
                else:
                    pat.append((0, 0, bid))
            pat.sort()
            return hash(tuple(pat))

        jb = 1                              # next boundary index to observe
        next_boundary = (cycle_start + P) if span_ok else None
        fp_hist: list = []                  # fingerprints at B_1..B_{jb-1}
        max_period = 4                      # e.g. at_next_iter grads double-
        detected_at = None                  # buffer -> state period 2
        skipped_cycles = 0
        ss_period = None

        def first_base_at(t_cut: int) -> int:
            i = 0
            while i < len(base) and base[i][0] < t_cut:
                i += 1
            return i

        while heap:
            t_min = heap[0][0]
            # boundary bookkeeping: fingerprint when replay first reaches
            # each cycle-window start B_j = cycle_start + j*P
            skip_done = False
            while (next_boundary is not None and t_min >= next_boundary
                   and jb <= nc):
                fp = (sim.state_fingerprint(), handle_pattern(jb))
                p_found = None
                for p in range(1, min(max_period, len(fp_hist)) + 1):
                    if fp_hist[-p] == fp:
                        p_found = p
                        break
                m = ((nc - jb) // p_found) * p_found if p_found else 0
                if steady_state and m > 0 and prefix_left == 0:
                    # the state cycles with period p: windows jb..jb+m-1
                    # are exact repeats — jump m windows ahead with the
                    # live cycle handles rebased by m instances, then
                    # replay the < p remaining windows + tail + suffix.
                    jp = jb + m
                    remapped: dict[int, int] = {}
                    for bid, h in handles.items():
                        inst, raw = split_cycle_bid(bid)
                        if inst >= 0:
                            bid = shift_cycle_bid(raw, inst + m)
                        remapped[bid] = h
                    handles = remapped
                    heap = []
                    # instances jp-2 / jp-1 contribute their events from
                    # B_jp onward (span <= 2 periods, checked above)
                    for back in (2, 1):
                        inst = jp - back
                        if 0 <= inst < nc:
                            push("c",
                                 first_base_at(cycle_start + back * P), inst)
                    if jp < nc:
                        push("c", 0, jp)
                        activated = jp + 1
                    else:
                        activated = nc
                    push("s", 0)
                    detected_at = jb
                    skipped_cycles = m
                    ss_period = p_found
                    next_boundary = None
                    skip_done = True
                    break
                fp_hist.append(fp)
                jb += 1
                next_boundary = (cycle_start + jb * P) if jb <= nc else None
            if skip_done:
                continue                  # stream rebuilt; re-enter loop
            _, _, _, src, idx, inst = heapq.heappop(heap)
            if src == "p":
                prefix_left -= 1
                push("p", idx + 1)
            elif src == "s":
                push("s", idx + 1)
            else:
                push("c", idx + 1, inst)
                if idx == 0 and inst + 1 < nc and activated == inst + 1:
                    push("c", 0, inst + 1)    # activate the next instance
                    activated += 1
            t, kind, bid, size = payload(src, idx, inst)
            try:
                if kind == 1:
                    if size > 0:
                        handles[bid] = sim.malloc(size, t=t)
                else:
                    h = handles.pop(bid, None)
                    if h is not None:
                        sim.free(h, t=t)
            except SimOOMError:
                oom, oom_at = True, n_done
                break
            n_done += 1
        return self._result(sim, oom, oom_at, extra_stats={
            "steady_state": {
                "cycles_total": nc,
                "cycles_skipped": skipped_cycles,
                "detected_at": detected_at,
                "period": ss_period,
            },
            "events_replayed": n_done,
        })

    # -- columnar engines ------------------------------------------------------
    def _replay_arena_program(self, prog: ColumnarProgram) -> SimResult:
        """Exact vectorized arena replay: request rounding, live-byte
        prefix sum, page-rounded demand curve and first-over-capacity OOM
        detection are all single array expressions. O(n log n) in the
        event count (the sort lives in program construction)."""
        n = len(prog)
        # arena demand: reserved ratchets to round_up(live, page) at each
        # executing alloc; OOM iff that want exceeds capacity (§3.4(v)
        # collapses to one comparison — reclaim cannot help a compacting
        # arena whose live bytes alone overflow). The curves are
        # capacity-independent, so they are cached on the program and
        # every capacity probe pays only the comparisons below.
        exec_alloc, live, want = prog.arena_trajectory(self.policy)
        over = want > self.capacity
        oom = bool(over.any())
        oom_at = int(np.argmax(over)) if oom else None
        j = oom_at if oom else n
        live_j, want_j = live[:j], want[:j]
        alloc_j = exec_alloc[:j]
        peak_alloc = int(live_j[alloc_j].max()) if alloc_j.any() else 0
        res_run = np.maximum.accumulate(want_j)
        reserved = int(res_run[-1]) if j else 0
        demand_hi = j + 1 if oom else n   # failing want still recorded
        max_inuse = int(want[:demand_hi].max()) if demand_hi else 0
        executed = prog.exec_mask[:j]
        curve = list(zip(prog.t[:j][executed].tolist(),
                         live_j[executed].tolist(),
                         res_run[executed].tolist()))
        allocated = int(live_j[-1]) if j else 0
        stats = {
            "allocated": allocated,
            "reserved": reserved,
            "peak_allocated": peak_alloc,
            "peak_reserved": reserved,
            "device_peak_reserved": reserved,
            "n_splits": 0, "n_merges": 0, "n_cache_hits": 0,
            "n_segments": 0,
            "max_inuse_demand": max_inuse,
            "engine": "columnar",
            "events_replayed": j,
        }
        return SimResult(peak_reserved=reserved, peak_allocated=peak_alloc,
                         oom=oom, oom_at=oom_at, curve=curve, stats=stats,
                         segments=[])

    def _replay_bfc_program(self, prog: ColumnarProgram) -> SimResult:
        """Batched BFC stepper: request rounding is done for the whole
        column with numpy and events stream through a tight loop over
        primitive values; the Python free-list/segment logic is entered
        only where BFC state actually decides (best-fit, split, coalesce,
        reclaim)."""
        device = DeviceAllocatorSim(self.capacity, self.policy.device_page)
        sim = CachingAllocatorSim(self.policy, device)
        rounded = round_size_array(prog.size, self.policy)
        handles: dict[int, int] = {}
        malloc = sim.malloc_rounded
        free = sim.free
        pop = handles.pop
        oom, oom_at = False, None
        n_done = 0
        try:
            for kind, bid, rsize, size, t in zip(
                    prog.kind.tolist(), prog.bid.tolist(), rounded.tolist(),
                    prog.size.tolist(), prog.t.tolist()):
                if kind:
                    if size > 0:
                        handles[bid] = malloc(rsize, t)
                else:
                    h = pop(bid, None)
                    if h is not None:
                        free(h, t)
                n_done += 1
        except SimOOMError:
            oom, oom_at = True, n_done
        return self._result(sim, oom, oom_at, extra_stats={
            "engine": "columnar", "events_replayed": n_done})

    # -- multi-space replay ----------------------------------------------------
    def replay_spaces(self, blocks, space_specs: dict | None = None,
                      steady_state: bool = True) -> SimResult:
        """Replay a (possibly multi-space) composition and report
        per-space peaks.

        Each space's demand replays independently through that space's
        own allocator policy (device HBM pages vs pinned-arena vs
        malloc-like pageable — per ``space_specs``, defaulting to
        :func:`default_space_specs` with this simulator's device policy
        and capacity). The primary :class:`SimResult` is the *device*
        replay — the quantity schedulers budget — and
        ``stats["space_peaks"]`` maps space name to peak reserved bytes;
        ``stats["host_spaces"]`` carries each host space's peaks and OOM
        verdict (against its capacity, unbounded by default), and
        ``stats["any_space_oom"]`` is the job-level verdict.

        All-device inputs take exactly the single-space :meth:`replay`
        path on the original object — bit-identical to the pre-v4
        engine by construction.
        """
        groups = split_blocks_by_space(blocks) \
            if not isinstance(blocks, ColumnarProgram) \
            else {MemorySpace.DEVICE_HBM: blocks}
        host_spaces = [s for s in groups if s is not MemorySpace.DEVICE_HBM]
        if not host_spaces:
            res = self.replay(blocks, steady_state)
            res.stats["space_peaks"] = {
                MemorySpace.DEVICE_HBM.value: res.peak_reserved}
            return res
        specs = space_specs if space_specs is not None else \
            default_space_specs(
                self.policy,
                None if self.capacity >= _UNBOUNDED else self.capacity)
        dev = groups.get(MemorySpace.DEVICE_HBM)
        if dev is None:
            dev = []
        res = self.replay(dev, steady_state)
        peaks = {MemorySpace.DEVICE_HBM.value: res.peak_reserved}
        host_stats: dict[str, dict] = {}
        any_oom = res.oom
        for s in host_spaces:
            spec = specs.get(s)
            policy = spec.policy if spec is not None else self.policy
            cap = (spec.capacity if spec is not None
                   and spec.capacity is not None else _UNBOUNDED)
            sub = MemorySimulator(policy, cap, self.engine).replay(
                groups[s], steady_state)
            peaks[s.value] = sub.peak_reserved
            host_stats[s.value] = {
                "peak_reserved": sub.peak_reserved,
                "peak_allocated": sub.peak_allocated,
                "oom": sub.oom,
                "policy": policy.name,
            }
            any_oom = any_oom or sub.oom
        res.stats["space_peaks"] = peaks
        res.stats["host_spaces"] = host_stats
        res.stats["any_space_oom"] = any_oom
        return res

    # -- capacity probing ------------------------------------------------------
    def would_oom(self, blocks, capacity: int) -> bool:
        """Two-level OOM verdict at a specific capacity (PEF round 2)."""
        return MemorySimulator(self.policy, capacity,
                               self.engine).replay(blocks).oom

    def min_feasible_capacity(self, blocks,
                              probe: SimResult | None = None) -> int:
        """Smallest capacity at which ``blocks`` replays without OOM.

        One instrumented unbounded replay yields the max in-use segment
        demand (the candidate) plus a proven bracket: ``peak_allocated``
        rounded up is a hard lower bound, and an unbounded run's
        ``peak_reserved`` is always feasible (the trajectory is identical
        at that capacity).

        For the arena policy the candidate is returned outright — an
        arena trajectory is capacity-independent up to its OOM point, so
        feasibility at c is exactly ``max demand <= c`` and the
        instrumented maximum IS the answer (a true multi-capacity replay:
        every candidate capacity is decided by the one demand curve).
        For the BFC policies reclaim can genuinely shift the answer, so
        two verification replays confirm the candidate and a
        page-granular bisection resolves divergence; with the columnar
        engine all of those probes share one prebuilt program (the sort
        and rounding are paid once, not per probe).
        """
        page = max(self.policy.device_page, 1)
        prog = (self.as_program(blocks) if self.engine == "columnar"
                else None)

        def replay_at(cap: int) -> SimResult:
            sim = MemorySimulator(self.policy, cap, self.engine)
            return (sim.replay_program(prog) if prog is not None
                    else sim.replay(blocks))

        # a usable probe must be a COMPLETE unbounded replay: an OOM'd or
        # capacity-constrained run has truncated peaks/demand (and its
        # reclaim behavior invalidates the feasible-by-identity bracket)
        if (probe is None or probe.oom
                or "max_inuse_demand" not in probe.stats):
            probe = replay_at(_UNBOUNDED)
            self.last_capacity_replays = 1
        else:
            self.last_capacity_replays = 0
        if probe.peak_reserved <= 0:
            return 0
        lo = round_up(max(probe.peak_allocated, 1), page)
        cand = max(round_up(
            probe.stats.get("max_inuse_demand", probe.peak_reserved),
            page), lo)
        if self.policy.arena:
            return cand                     # exact, zero extra replays

        def feasible(c: int) -> bool:
            self.last_capacity_replays += 1
            return not replay_at(c).oom

        # upper bracket: an unbounded run's peak_reserved is usually
        # feasible by trajectory identity, but growth-doubling policies
        # can need MORE than the unbounded reservation once capacity
        # pressure reorders reclaims and doubling grants — so the
        # bracket is verified and grown geometrically until it holds
        hi = max(round_up(probe.peak_reserved, page), cand)
        while not feasible(hi):
            hi = round_up(hi * 2, page)
        cand = min(cand, hi)

        lo_k, hi_k = lo // page, hi // page
        if cand == hi or feasible(cand):
            if cand <= lo or not feasible(cand - page):
                return cand                            # O(1) replays
            hi_k = cand // page - 1
        else:
            lo_k = cand // page + 1
        while lo_k < hi_k:
            mid = (lo_k + hi_k) // 2
            if feasible(mid * page):
                hi_k = mid
            else:
                lo_k = mid + 1
        return hi_k * page
