"""Memory Simulator — final stage of the xMem pipeline (paper §3.4).

Replays the orchestrated block lifecycles chronologically through the
two-level allocator simulation and reports:

* estimated peak memory (reserved *segments* — the quantity a scheduler
  must budget, paper §2.2.2),
* peak allocated (tensor) bytes — the naive lower bound,
* the full usage curve over time (paper's optional output, used for the
  Fig.-6-style fidelity benchmark),
* OOM verdict for a given capacity — OOM fires only when both simulated
  levels fail after cache reclaim, mirroring the real chain.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from .allocator import (AllocatorPolicy, CachingAllocatorSim, CUDA_CACHING,
                        DeviceAllocatorSim, SimOOMError)
from .events import BlockLifecycle, lifecycles_to_events


@dataclasses.dataclass
class SimResult:
    peak_reserved: int            # the estimate a scheduler budgets
    peak_allocated: int           # sum-of-live-tensors peak (naive bound)
    oom: bool
    oom_at: int | None            # event index of OOM, if any
    curve: list[tuple[int, int, int]]   # (t, allocated, reserved)
    stats: dict
    segments: list[dict]          # final segment map (fidelity plots)

    @property
    def fragmentation_overhead(self) -> float:
        if not self.peak_allocated:
            return 0.0
        return self.peak_reserved / self.peak_allocated - 1.0


class MemorySimulator:
    def __init__(self, policy: AllocatorPolicy = CUDA_CACHING,
                 capacity: int = 1 << 62):
        self.policy = policy
        self.capacity = capacity

    def replay(self, blocks: Sequence[BlockLifecycle]) -> SimResult:
        events = lifecycles_to_events(blocks)
        device = DeviceAllocatorSim(self.capacity, self.policy.device_page)
        sim = CachingAllocatorSim(self.policy, device)
        handles: dict[int, int] = {}
        oom, oom_at = False, None
        for i, e in enumerate(events):
            try:
                if e.kind == "alloc":
                    if e.size <= 0:
                        continue
                    handles[e.block_id] = sim.malloc(e.size, t=e.t)
                else:
                    h = handles.pop(e.block_id, None)
                    if h is not None:
                        sim.free(h, t=e.t)
            except SimOOMError:
                oom, oom_at = True, i
                break
        return SimResult(
            peak_reserved=sim.peak_reserved,
            peak_allocated=sim.peak_allocated,
            oom=oom,
            oom_at=oom_at,
            curve=sim.timeline,
            stats=sim.stats(),
            segments=sim.segments_snapshot(),
        )

    def would_oom(self, blocks: Sequence[BlockLifecycle],
                  capacity: int) -> bool:
        """Two-level OOM verdict at a specific capacity (PEF round 2)."""
        return MemorySimulator(self.policy, capacity).replay(blocks).oom
