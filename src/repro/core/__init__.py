"""repro.core — the paper's contribution: xMem, a CPU-only a-priori
peak-memory estimator for DL training jobs, adapted to JAX/XLA/TPU.

Pipeline: tracer (CPU jaxpr interpretation) -> analyzer (lifecycles +
attribution) -> orchestrator (device-semantics lifecycle rewriting) ->
simulator (two-level BFC allocator replay) -> peak estimate + OOM verdict.
"""
from .allocator import (AllocatorPolicy, CachingAllocatorSim, CUDA_CACHING,
                        DeviceAllocatorSim, POLICIES, SimOOMError, TPU_ARENA,
                        XLA_BFC)
from .analyzer import (attribute_by_time_window, classify_blocks,
                       layer_report, reconstruct_from_address_events,
                       reconstruct_lifecycles)
from .cache import GLOBAL_TRACE_CACHE, TraceCache, TracedPhase, trace_key
from .estimator import (EstimateReport, XMemEstimator, flatten_kinds,
                        update_grad_coupling)
from .events import (TRACE_SCHEMA_VERSION, BlockKind, BlockLifecycle,
                     ColumnarBlocks, ColumnarTrace, LazyEvents, MemoryEvent,
                     PeriodicBlocks, Phase, Trace, TraceSchemaError,
                     lifecycles_to_events, liveness_curve, peak_live_bytes,
                     periodic_breakdown_peaks, periodic_peak_live,
                     periodic_phase_peaks, reduced_for_breakdown)
from .orchestrator import (CollectiveSpec, FUSIBLE_OPS, MemoryOrchestrator,
                           OrchestratorPolicy)
from .simulator import (ColumnarProgram, MemorySimulator, SimResult,
                        program_from_lifecycles, program_from_periodic)
from .sweep import SweepPoint, SweepService, estimate_many
from .tracer import (JaxprMemoryTracer, aval_bytes, trace_fn,
                     trace_fn_with_shape)

__all__ = [
    "AllocatorPolicy", "CachingAllocatorSim", "CUDA_CACHING",
    "DeviceAllocatorSim", "POLICIES", "SimOOMError", "TPU_ARENA", "XLA_BFC",
    "attribute_by_time_window", "classify_blocks", "layer_report",
    "reconstruct_from_address_events", "reconstruct_lifecycles",
    "GLOBAL_TRACE_CACHE", "TraceCache", "TracedPhase", "trace_key",
    "EstimateReport", "XMemEstimator", "flatten_kinds",
    "update_grad_coupling", "BlockKind", "BlockLifecycle", "MemoryEvent",
    "PeriodicBlocks", "Phase", "Trace", "lifecycles_to_events",
    "liveness_curve", "peak_live_bytes", "periodic_breakdown_peaks",
    "periodic_peak_live", "periodic_phase_peaks", "reduced_for_breakdown",
    "CollectiveSpec", "FUSIBLE_OPS",
    "MemoryOrchestrator", "OrchestratorPolicy", "MemorySimulator",
    "SimResult", "JaxprMemoryTracer", "aval_bytes", "trace_fn",
    "trace_fn_with_shape",
    "TRACE_SCHEMA_VERSION", "TraceSchemaError", "ColumnarBlocks",
    "ColumnarTrace", "ColumnarProgram", "LazyEvents",
    "program_from_lifecycles", "program_from_periodic",
    "SweepPoint", "SweepService", "estimate_many",
]
