"""Memory Orchestrator — stage 3 of the xMem pipeline (paper §3.3).

Rewrites CPU-derived block lifecycles so they reflect the lifecycles the
blocks will have on the *target device*. The paper's five policies map to
JAX as follows (DESIGN.md §2):

1. Model parameters  -> persistent across the analyzed iterations.
2. Batch data        -> lives exactly one iteration.
3. Activations       -> keep tracer-derived lifetimes (the CPU-derived
                        interleaving approximates the device's).
4. Gradients         -> freed per ``grad_release``: ``at_update`` frees
                        them when the optimizer consumes them (the JAX
                        donation idiom; paper POS0) vs ``at_next_iter``
                        which keeps them alive until the next backward
                        pass rewrites them (grad-accumulation buffers /
                        ``zero_grad`` at iteration start; paper POS1 —
                        Fig. 1's memory-doubling case).
5. Optimizer state   -> persistent from iteration 1 onward (why the
                        paper — and we — analyze >= 2 iterations).

XLA-specific passes the original (eager PyTorch) pipeline does not need:

6. donation          -> outputs aliased onto donated inputs (new params /
                        opt state reuse the old buffers; no double count).
7. fusion folding    -> short-lived outputs of fusible elementwise ops
                        never materialize in HBM (XLA fuses them); they
                        are dropped below a size threshold.
8. collective inject -> distributed estimation (paper §6.2/6.4's
                        "inject simulated allreduce buffers"): adds
                        COLLECTIVE blocks for gradient reduction buckets
                        and TP gather temporaries.
9. sharding          -> per-device sizes via shard factors from the
                        sharding engine.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from .events import BlockKind, BlockLifecycle, MemorySpace, Phase

# Elementwise/layout primitives XLA reliably fuses into consumers —
# their outputs typically never hit HBM as standalone buffers.
FUSIBLE_OPS = frozenset({
    "add", "sub", "mul", "div", "neg", "exp", "log", "tanh", "logistic",
    "max", "min", "pow", "integer_pow", "sqrt", "rsqrt", "abs", "sign",
    "convert_element_type", "select_n", "broadcast_in_dim", "reshape",
    "transpose", "squeeze", "expand_dims", "stop_gradient", "and", "or",
    "not", "xor", "eq", "ne", "ge", "gt", "le", "lt", "clamp", "erf",
    "floor", "ceil", "round", "is_finite", "copy", "real", "imag",
    "slice", "rev", "iota", "cos", "sin", "cumsum", "cumlogsumexp",
})


@dataclasses.dataclass(frozen=True)
class OffloadPlan:
    """A-priori host-offload schedule (TENSILE direction, ISSUE 8).

    TENSILE schedules tensor swapping reactively at runtime; because the
    event engine replays on CPU, the same swaps are decided here a
    priori, as a lifecycle rewrite: offloaded blocks change their
    ``space`` to a host space and paired transfer blocks (op
    ``offload_out`` / ``fetch_in``) model the device-side staging the
    copies need. The replay engine then reports per-space peaks and the
    roofline cost model charges the transfer bytes over PCIe.

    * ``optimizer_state`` — park persistent OPT_STATE on the host; a
      device staging copy exists only during each optimizer phase
      (fetched in before the update, written back after).
    * ``activations`` — fraction (by bytes, largest-first) of eligible
      saved activations offloaded between production (forward) and
      consumption (backward). Rematerialization interacts naturally:
      stronger remat policies save fewer/smaller activations, so the
      eligible set shrinks and with it the transfer cost.
    """

    optimizer_state: bool = False
    activations: float = 0.0          # 0..1, fraction of eligible bytes
    space: MemorySpace = MemorySpace.HOST_PINNED
    min_block_bytes: int = 1 << 16    # never offload tiny blocks
    stage_ticks: int = 1              # device residency of a staging copy

    @property
    def enabled(self) -> bool:
        return self.optimizer_state or self.activations > 0.0


@dataclasses.dataclass
class OrchestratorPolicy:
    """Knobs for lifecycle rewriting."""

    # "at_update"    - grads live until the optimizer phase consumes them
    #                  (PyTorch-eager semantics; paper POS0-ish)
    # "at_next_iter" - grads survive into the next iteration (accumulation
    #                  buffers / zero_grad at iteration start; paper POS1)
    # "eager_fused"  - XLA fuses per-leaf updates into the backward pass,
    #                  so each grad dies ~immediately after production.
    #                  Selected automatically when the update is per-leaf
    #                  (no cross-gradient coupling such as global-norm
    #                  clipping) — see estimator.update_grad_coupling.
    # "auto"         - estimator picks eager_fused vs at_update by taint
    #                  analysis of the update jaxpr.
    grad_release: str = "auto"
    eager_fuse_window: int = 6           # events a fused grad survives
    donate_params: bool = True
    donate_opt_state: bool = True
    # Step outputs (loss/metrics and, when donation is off, the freshly
    # written params/opt state) are overwritten by the next iteration's
    # outputs in a real loop — the old buffers die once replaced. Without
    # this pass every iteration leaks its outputs into "persistent",
    # which (a) inflates multi-iteration estimates (grossly so for
    # non-donated updates: + params x N) and (b) makes allocator state
    # drift forever, defeating steady-state replay. The final iteration's
    # outputs stay live (they are the job's results).
    release_outputs_next_iter: bool = True
    fusion_folding: bool = True
    fusion_max_lifetime: int = 8          # events a fusible temp may span
    fusion_min_bytes: int = 0             # fold regardless of size by default
    keep_unattributed: bool = True
    # Mixed-precision optimizers upcast each gradient to f32; observed
    # XLA schedules materialize these working copies together across
    # leaves during the update phase. Modeled as synthetic blocks of
    # grad_size * upcast_factor spanning the optimizer phase (ablation
    # benchmark quantifies the contribution).
    optimizer_upcast_coexist: bool = True
    upcast_factor: float = 2.0            # bf16 grads -> f32 copies
    # Backend scheduling-slack calibration (paper's Fig-6 loop made
    # explicit): one constant multiplying *transient* block sizes,
    # fitted once per target backend/runtime from a small calibration
    # set (XMemEstimator.calibrate). 1.0 = uncalibrated. Unlike
    # data-driven estimators this is model-independent — it captures
    # the runtime's buffering behavior, not the workload.
    transient_scale: float = 1.0
    # Host-offload schedule (None = everything stays in device HBM).
    # Applied as a separate pass *after* run/run_unfused so the fused
    # pipeline stays output-identical to its oracle.
    offload: OffloadPlan | None = None


@dataclasses.dataclass
class CollectiveSpec:
    """One injected communication buffer (distributed estimation).

    ``size`` is a fixed per-device byte count when ``source`` is empty.
    With ``source`` set, the buffer is sized at injection time from the
    composition's *actual sharded tensors* (per mesh axis, not a fixed
    factor): the largest per-device block of the named kind in the
    iteration, times ``scale`` (e.g. the axis size for an all-gather
    that materializes the unsharded tensor). ``axis``/``collective`` are
    attribution metadata (which mesh axis and primitive the buffer
    models)."""

    name: str
    size: int              # bytes per device (fixed-size specs)
    phase: Phase
    at: str = "phase_start"  # or "phase_end"
    persistent: bool = False
    axis: str = ""           # mesh axis the collective runs over
    collective: str = ""     # all_reduce | all_gather | reduce_scatter
    source: str = ""         # "" fixed | "grads" | "params" | "activations"
    scale: float = 1.0       # multiplier on the derived per-device size


class MemoryOrchestrator:
    def __init__(self, policy: OrchestratorPolicy | None = None):
        self.policy = policy or OrchestratorPolicy()

    # -- individual passes ---------------------------------------------------
    def mark_persistent(self, blocks: list[BlockLifecycle],
                        kinds=(BlockKind.PARAM, BlockKind.OPT_STATE)
                        ) -> list[BlockLifecycle]:
        return [dataclasses.replace(b, free_t=None)
                if b.block_kind in kinds else b for b in blocks]

    def batch_per_iteration(self, blocks: list[BlockLifecycle],
                            iteration_ends: dict[int, int]
                            ) -> list[BlockLifecycle]:
        """INPUT blocks die at their iteration's boundary marker."""
        out = []
        for b in blocks:
            if b.block_kind is BlockKind.INPUT:
                end = iteration_ends.get(b.iteration)
                if end is not None:
                    b = dataclasses.replace(b, free_t=end)
            out.append(b)
        return out

    def release_gradients(self, blocks: list[BlockLifecycle],
                          update_start: dict[int, int],
                          next_bwd_start: dict[int, int]
                          ) -> list[BlockLifecycle]:
        """Apply grad_release (the paper's zero_grad-placement semantics)."""
        out = []
        for b in blocks:
            # Only *persistent* GRAD blocks are true gradient outputs whose
            # release the framework controls; GRAD-classified backward
            # intermediates keep their tracer-derived lifetimes.
            if b.block_kind is BlockKind.GRAD and b.free_t is None:
                mode = self.policy.grad_release
                if mode in ("auto",):  # estimator resolves auto; fall back
                    mode = "at_update"
                if mode == "eager_fused":
                    us = update_start.get(b.iteration)
                    if b.op == "scan_ys":
                        # stacked-layer grads are backward-scan output
                        # buffers accumulated across the whole loop —
                        # they cannot die before the update consumes them
                        t = us
                    else:
                        t = b.alloc_t + self.policy.eager_fuse_window
                        if us is not None:
                            t = min(t, us)
                elif mode == "at_update":
                    t = update_start.get(b.iteration)
                else:  # at_next_iter: grads survive into the next iteration
                    t = next_bwd_start.get(b.iteration + 1)
                b = dataclasses.replace(b, free_t=t)  # None -> persistent
            out.append(b)
        return out

    def apply_donation(self, blocks: list[BlockLifecycle]
                       ) -> list[BlockLifecycle]:
        """Drop OUTPUT blocks that alias donated persistent inputs.

        With ``donate_argnums`` the updated params/opt-state are written
        into the old buffers; a simulator that allocates both
        double-counts — the classic over-estimation DNNMem-style static
        analysis exhibits (evaluated in benchmarks/ablation).
        """
        if not (self.policy.donate_params or self.policy.donate_opt_state):
            return blocks
        _PARAM, _OPT, _OUT = (BlockKind.PARAM, BlockKind.OPT_STATE,
                              BlockKind.OUTPUT)
        persistent_sizes: dict[int, int] = {}
        for b in blocks:
            bk = b.block_kind
            if (bk is _PARAM or bk is _OPT) and b.free_t is None:
                persistent_sizes[b.size] = persistent_sizes.get(b.size, 0) + 1
        # every iteration's update writes into the same donated buffers, so
        # the aliasing budget applies per iteration, not once for the trace
        budgets: dict[int, dict[int, int]] = {}
        out = []
        append = out.append
        for b in blocks:
            if b.block_kind is _OUT:
                budget = budgets.get(b.iteration)
                if budget is None:
                    budget = budgets[b.iteration] = dict(persistent_sizes)
                if budget.get(b.size, 0) > 0:
                    budget[b.size] -= 1
                    continue  # aliased: no new allocation
            append(b)
        return out

    def release_step_outputs(self, blocks: list[BlockLifecycle],
                             iteration_ends: dict[int, int]
                             ) -> list[BlockLifecycle]:
        """Free iteration i's surviving OUTPUT blocks at iteration i+1's
        end (when the next step's outputs have replaced them). Outputs of
        the final iteration — no successor in ``iteration_ends`` — stay
        persistent."""
        out = []
        for b in blocks:
            if b.block_kind is BlockKind.OUTPUT and b.free_t is None:
                end = iteration_ends.get(b.iteration + 1)
                if end is not None:
                    b = dataclasses.replace(b, free_t=end)
            out.append(b)
        return out

    def fold_fused(self, blocks: list[BlockLifecycle]) -> list[BlockLifecycle]:
        """Drop blocks XLA fusion would never materialize."""
        if not self.policy.fusion_folding:
            return blocks
        p = self.policy
        out = []
        for b in blocks:
            if (b.op in FUSIBLE_OPS
                    and b.free_t is not None
                    and (b.free_t - b.alloc_t) <= p.fusion_max_lifetime
                    and b.size >= p.fusion_min_bytes
                    and b.block_kind in (BlockKind.ACTIVATION, BlockKind.TEMP)):
                continue
            out.append(b)
        return out

    def inject_optimizer_upcasts(self, blocks: list[BlockLifecycle],
                                 update_start: dict[int, int],
                                 iteration_ends: dict[int, int]
                                 ) -> list[BlockLifecycle]:
        """Synthetic f32 working copies of gradients during the update."""
        if not self.policy.optimizer_upcast_coexist:
            return blocks
        out = list(blocks)
        bid = -100_000
        for b in blocks:
            if b.block_kind is not BlockKind.GRAD:
                continue
            us = update_start.get(b.iteration)
            end = iteration_ends.get(b.iteration)
            if us is None or end is None or us >= end:
                continue
            # only true gradient outputs (freed at/after update start)
            if b.free_t is not None and b.free_t < us:
                continue
            out.append(BlockLifecycle(
                bid, int(b.size * self.policy.upcast_factor), us, end,
                b.iteration, Phase.OPTIMIZER, "grad_upcast", b.scope,
                BlockKind.TEMP, b.shard_factor, b.shape))
            bid -= 1
        return out

    def inject_collectives(self, blocks: list[BlockLifecycle],
                           specs: Sequence[CollectiveSpec],
                           phase_bounds: dict[tuple[int, str], tuple[int, int]],
                           num_iterations: int,
                           shard_factor_fn: Callable | None = None
                           ) -> list[BlockLifecycle]:
        """Add COLLECTIVE buffers at phase starts/ends per iteration.

        Dynamic specs (``source`` set) are sized from the composition's
        actual blocks at their *per-device* size — the sharding pass runs
        after injection, so the factor function is applied here to the
        candidate source blocks (collective buffers themselves stay
        factor-1: they are already per-device quantities)."""
        if not specs:
            return blocks
        dynamic = [s for s in specs if s.source]
        src_max: dict[tuple[int, str], int] = {}
        if dynamic:
            wanted = {s.source for s in dynamic}

            def per_device(b: BlockLifecycle) -> int:
                if shard_factor_fn is not None:
                    f = max(shard_factor_fn(b), 1.0)
                    if f != 1.0:
                        return max(int(b.size / f), 1) if b.size else 0
                return b.sharded_size

            for b in blocks:
                k = b.block_kind
                if k is BlockKind.GRAD:
                    source = "grads"
                elif k is BlockKind.PARAM:
                    source = "params"
                elif k is BlockKind.ACTIVATION:
                    source = "activations"
                else:
                    continue
                if source not in wanted:
                    continue
                # persistent params count for every iteration
                its = (range(num_iterations) if k is BlockKind.PARAM
                       and b.free_t is None else (b.iteration,))
                s = per_device(b)
                for it in its:
                    key = (it, source)
                    if s > src_max.get(key, 0):
                        src_max[key] = s
        out = list(blocks)
        bid = -1  # negative ids: synthetic blocks
        for it in range(num_iterations):
            for s in specs:
                key = (it, s.phase.value)
                if key not in phase_bounds:
                    continue
                size = s.size
                if s.source:
                    size = int(src_max.get((it, s.source), 0) * s.scale)
                    if size <= 0:
                        continue
                start, end = phase_bounds[key]
                if s.at == "phase_start":
                    t0, t1 = start, end
                else:
                    # end-of-phase staging (gradient all-reduce /
                    # reduce-scatter): allocated one tick before the
                    # boundary so it coexists with tensors freed exactly
                    # at phase end (frees sort before allocs at equal t)
                    t0, t1 = max(start, end - 1), end
                out.append(BlockLifecycle(
                    bid, size, t0, None if s.persistent else t1,
                    it, s.phase, "collective", s.name, BlockKind.COLLECTIVE))
                bid -= 1
        return out

    def apply_transient_scale(self, blocks: list[BlockLifecycle]
                              ) -> list[BlockLifecycle]:
        """Scale transient (non-persistent, non-input) blocks by the
        backend calibration constant."""
        s = self.policy.transient_scale
        if s == 1.0:
            return blocks
        out = []
        for b in blocks:
            if b.free_t is not None and b.block_kind in (
                    BlockKind.ACTIVATION, BlockKind.TEMP, BlockKind.GRAD):
                b = dataclasses.replace(b, size=int(b.size * s))
            out.append(b)
        return out

    def apply_sharding(self, blocks: list[BlockLifecycle],
                       factor_fn: Callable[[BlockLifecycle], float]
                       ) -> list[BlockLifecycle]:
        return [dataclasses.replace(b, shard_factor=max(factor_fn(b), 1.0))
                for b in blocks]

    def apply_offload(self, blocks: list[BlockLifecycle],
                      update_start: dict[int, int] | None = None,
                      iteration_ends: dict[int, int] | None = None,
                      ) -> tuple[list[BlockLifecycle], dict | None]:
        """Rewrite lifecycles per the policy's :class:`OffloadPlan`.

        Runs *after* ``run``/``run_unfused`` (so the fused pipeline stays
        identical to its oracle) and before replay. Two rewrites:

        * optimizer-state: persistent OPT_STATE blocks move to the host
          space; each optimizer phase gets a device ``fetch_in`` staging
          copy spanning ``[update_start, iteration_end]`` (the state is
          fetched before the update and written back after — 2x bytes
          over the interconnect per iteration).
        * activations: eligible saved activations (device-resident,
          freed, >= ``min_block_bytes``, lifetime long enough to round-
          trip) are picked largest-first per iteration until the
          ``activations`` byte fraction is covered. The original block's
          device residency shrinks to a copy-out window at its head; a
          host block (op ``offload_out``) holds the bulk residency, and
          a device ``fetch_in`` staging block covers the copy-back
          window before the backward pass consumes it.

        Synthetic blocks get ids descending from -200000 (below the
        upcast namespace). Returns ``(blocks, stats)``; stats is None
        when no offload is configured. Transfer accounting uses
        per-device (sharded) sizes — those are the bytes that cross
        PCIe on each device.
        """
        plan = self.policy.offload
        if plan is None or not plan.enabled:
            return blocks, None
        update_start = update_start or {}
        iteration_ends = iteration_ends or {}
        _DEV = MemorySpace.DEVICE_HBM
        out: list[BlockLifecycle] = []
        extra: list[BlockLifecycle] = []
        bid = -200_000
        transfers: dict[int, int] = {}  # per-iteration transfer bytes
        opt_blocks = opt_bytes = 0
        act_blocks = act_bytes = 0
        min_life = 2 * plan.stage_ticks + 1

        # per-iteration activation selection: largest-first until the
        # requested byte fraction of the eligible set is covered
        selected: set[int] = set()
        if plan.activations > 0.0:
            eligible: dict[int, list[BlockLifecycle]] = {}
            for b in blocks:
                if (b.block_kind is BlockKind.ACTIVATION
                        and b.space is _DEV
                        and b.free_t is not None
                        and b.size >= plan.min_block_bytes
                        and (b.free_t - b.alloc_t) > min_life):
                    eligible.setdefault(b.iteration, []).append(b)
            for it, cands in eligible.items():
                total = sum(c.size for c in cands)
                target = plan.activations * total
                taken = 0
                cands.sort(key=lambda c: (-c.size, c.alloc_t, c.block_id))
                for c in cands:
                    if taken >= target:
                        break
                    selected.add(id(c))
                    taken += c.size

        for b in blocks:
            if (plan.optimizer_state
                    and b.block_kind is BlockKind.OPT_STATE
                    and b.space is _DEV
                    and b.free_t is None
                    and b.size >= plan.min_block_bytes):
                out.append(dataclasses.replace(b, space=plan.space))
                opt_blocks += 1
                opt_bytes += b.sharded_size
                for it, us in update_start.items():
                    end = iteration_ends.get(it)
                    if us is None or end is None or us >= end:
                        continue
                    extra.append(BlockLifecycle(
                        bid, b.size, us, end, it, Phase.OPTIMIZER,
                        "fetch_in", b.scope, BlockKind.OPT_STATE,
                        b.shard_factor, b.shape))
                    bid -= 1
                    transfers[it] = (transfers.get(it, 0)
                                     + 2 * b.sharded_size)
                continue
            if id(b) in selected:
                head_end = b.alloc_t + plan.stage_ticks
                tail_start = max(b.free_t - plan.stage_ticks, head_end)
                out.append(dataclasses.replace(b, free_t=head_end))
                extra.append(BlockLifecycle(
                    bid, b.size, b.alloc_t, b.free_t, b.iteration,
                    b.phase, "offload_out", b.scope, b.block_kind,
                    b.shard_factor, b.shape, plan.space))
                bid -= 1
                extra.append(BlockLifecycle(
                    bid, b.size, tail_start, b.free_t, b.iteration,
                    b.phase, "fetch_in", b.scope, b.block_kind,
                    b.shard_factor, b.shape))
                bid -= 1
                act_blocks += 1
                act_bytes += b.sharded_size
                transfers[b.iteration] = (
                    transfers.get(b.iteration, 0) + 2 * b.sharded_size)
                continue
            out.append(b)
        out.extend(extra)
        # steady-state transfer bytes: the cycle iteration (1) when the
        # composition has one, else the heaviest observed iteration
        steady = transfers.get(1)
        if steady is None:
            steady = max(transfers.values(), default=0)
        stats = {
            "opt_state_blocks": opt_blocks,
            "opt_state_bytes": opt_bytes,
            "activation_blocks": act_blocks,
            "activation_bytes": act_bytes,
            "transfer_bytes_per_iter": steady,
            "space": plan.space.value,
        }
        return out, stats

    # -- composite ------------------------------------------------------------
    def run_unfused(self, blocks: list[BlockLifecycle], *,
                    iteration_ends: dict[int, int] | None = None,
                    update_start: dict[int, int] | None = None,
                    next_bwd_start: dict[int, int] | None = None,
                    collective_specs: Sequence[CollectiveSpec] = (),
                    phase_bounds: dict | None = None,
                    num_iterations: int = 1,
                    shard_factor_fn=None) -> list[BlockLifecycle]:
        """The pass pipeline as individual passes — the readable form
        ``run`` is a fusion of (and the oracle it is tested against)."""
        # fold first: fused temps are never touched by the lifecycle
        # passes below (they act on PARAM/OPT/GRAD/INPUT/OUTPUT or on
        # persistent blocks, which fusible short-lived temps are not), so
        # dropping them up front shrinks every subsequent pass
        blocks = self.fold_fused(blocks)
        blocks = self.mark_persistent(blocks)
        if iteration_ends:
            blocks = self.batch_per_iteration(blocks, iteration_ends)
        if update_start is not None:
            blocks = self.release_gradients(blocks, update_start,
                                            next_bwd_start or {})
            if iteration_ends:
                blocks = self.inject_optimizer_upcasts(
                    blocks, update_start, iteration_ends)
        blocks = self.apply_donation(blocks)
        if self.policy.release_outputs_next_iter and iteration_ends:
            blocks = self.release_step_outputs(blocks, iteration_ends)
        blocks = self.apply_transient_scale(blocks)
        if collective_specs and phase_bounds:
            blocks = self.inject_collectives(blocks, collective_specs,
                                             phase_bounds, num_iterations,
                                             shard_factor_fn)
        if shard_factor_fn is not None:
            blocks = self.apply_sharding(blocks, shard_factor_fn)
        return blocks

    def run(self, blocks: list[BlockLifecycle], *,
            iteration_ends: dict[int, int] | None = None,
            update_start: dict[int, int] | None = None,
            next_bwd_start: dict[int, int] | None = None,
            collective_specs: Sequence[CollectiveSpec] = (),
            phase_bounds: dict | None = None,
            num_iterations: int = 1,
            shard_factor_fn: Callable[[BlockLifecycle], float] | None = None,
            ) -> list[BlockLifecycle]:
        """Fused pass pipeline — output-identical to ``run_unfused``
        (asserted by tests/test_columnar.py) but two list traversals
        instead of eight. This is the estimator's per-point hot loop, so
        the per-block passes (fold, persistence, batch, grad release,
        upcast injection) run in one pass that also collects the donation
        budget, and the list-order-dependent tail (donation, output
        release, transient scale) runs in a second."""
        p = self.policy
        iteration_ends = iteration_ends or {}
        update_start_d = update_start if update_start is not None else None
        next_bwd = next_bwd_start or {}
        do_batch = bool(iteration_ends)
        do_upcast = (update_start is not None and bool(iteration_ends)
                     and p.optimizer_upcast_coexist)
        grad_mode = p.grad_release
        if grad_mode in ("auto",):
            grad_mode = "at_update"
        _PARAM, _OPT, _GRAD = (BlockKind.PARAM, BlockKind.OPT_STATE,
                               BlockKind.GRAD)
        _IN, _OUT, _ACT, _TMP = (BlockKind.INPUT, BlockKind.OUTPUT,
                                 BlockKind.ACTIVATION, BlockKind.TEMP)
        fold = p.fusion_folding
        fuse_life, fuse_min = p.fusion_max_lifetime, p.fusion_min_bytes
        out: list[BlockLifecycle] = []
        append = out.append
        upcast_blocks: list[BlockLifecycle] = []
        persistent_sizes: dict[int, int] = {}
        for b in blocks:
            kind = b.block_kind
            free_t = b.free_t
            # fold_fused
            if (fold and free_t is not None and b.op in FUSIBLE_OPS
                    and (free_t - b.alloc_t) <= fuse_life
                    and b.size >= fuse_min and (kind is _ACT or kind is _TMP)):
                continue
            # mark_persistent
            if kind is _PARAM or kind is _OPT:
                if free_t is not None:
                    b = dataclasses.replace(b, free_t=None)
                persistent_sizes[b.size] = \
                    persistent_sizes.get(b.size, 0) + 1
                append(b)
                continue
            # batch_per_iteration
            if do_batch and kind is _IN:
                end = iteration_ends.get(b.iteration)
                if end is not None:
                    b = dataclasses.replace(b, free_t=end)
                append(b)
                continue
            # release_gradients (+ upcast injection bookkeeping)
            if kind is _GRAD and update_start_d is not None:
                if free_t is None:
                    if grad_mode == "eager_fused":
                        us = update_start_d.get(b.iteration)
                        if b.op == "scan_ys":
                            t = us
                        else:
                            t = b.alloc_t + p.eager_fuse_window
                            if us is not None:
                                t = min(t, us)
                    elif grad_mode == "at_update":
                        t = update_start_d.get(b.iteration)
                    else:  # at_next_iter
                        t = next_bwd.get(b.iteration + 1)
                    b = dataclasses.replace(b, free_t=t)
                    free_t = t
                if do_upcast:
                    us = update_start_d.get(b.iteration)
                    end = iteration_ends.get(b.iteration)
                    if (us is not None and end is not None and us < end
                            and (free_t is None or free_t >= us)):
                        upcast_blocks.append((b, us, end))
                append(b)
                continue
            append(b)
        # inject_optimizer_upcasts appends synthetic blocks at the tail,
        # in GRAD block order, ids descending from -100000
        bid = -100_000
        for b, us, end in upcast_blocks:
            append(BlockLifecycle(
                bid, int(b.size * p.upcast_factor), us, end,
                b.iteration, Phase.OPTIMIZER, "grad_upcast", b.scope,
                BlockKind.TEMP, b.shard_factor, b.shape))
            bid -= 1
        # second traversal: donation, output release, transient scale
        do_donate = p.donate_params or p.donate_opt_state
        do_release_out = p.release_outputs_next_iter and bool(iteration_ends)
        scale = p.transient_scale
        budgets: dict[int, dict[int, int]] = {}
        blocks2: list[BlockLifecycle] = []
        append2 = blocks2.append
        for b in out:
            if b.block_kind is _OUT:
                if do_donate:
                    budget = budgets.get(b.iteration)
                    if budget is None:
                        budget = budgets[b.iteration] = \
                            dict(persistent_sizes)
                    if budget.get(b.size, 0) > 0:
                        budget[b.size] -= 1
                        continue          # aliased: no new allocation
                if do_release_out and b.free_t is None:
                    end = iteration_ends.get(b.iteration + 1)
                    if end is not None:
                        b = dataclasses.replace(b, free_t=end)
            if (scale != 1.0 and b.free_t is not None
                    and b.block_kind in (_ACT, _TMP, _GRAD)):
                b = dataclasses.replace(b, size=int(b.size * scale))
            append2(b)
        blocks = blocks2
        if collective_specs and phase_bounds:
            blocks = self.inject_collectives(blocks, collective_specs,
                                             phase_bounds, num_iterations,
                                             shard_factor_fn)
        if shard_factor_fn is not None:
            blocks = self.apply_sharding(blocks, shard_factor_fn)
        return blocks
