"""Memory Orchestrator — stage 3 of the xMem pipeline (paper §3.3).

Rewrites CPU-derived block lifecycles so they reflect the lifecycles the
blocks will have on the *target device*. The paper's five policies map to
JAX as follows (DESIGN.md §2):

1. Model parameters  -> persistent across the analyzed iterations.
2. Batch data        -> lives exactly one iteration.
3. Activations       -> keep tracer-derived lifetimes (the CPU-derived
                        interleaving approximates the device's).
4. Gradients         -> freed per ``grad_release``: ``at_update`` frees
                        them when the optimizer consumes them (the JAX
                        donation idiom; paper POS0) vs ``at_next_iter``
                        which keeps them alive until the next backward
                        pass rewrites them (grad-accumulation buffers /
                        ``zero_grad`` at iteration start; paper POS1 —
                        Fig. 1's memory-doubling case).
5. Optimizer state   -> persistent from iteration 1 onward (why the
                        paper — and we — analyze >= 2 iterations).

XLA-specific passes the original (eager PyTorch) pipeline does not need:

6. donation          -> outputs aliased onto donated inputs (new params /
                        opt state reuse the old buffers; no double count).
7. fusion folding    -> short-lived outputs of fusible elementwise ops
                        never materialize in HBM (XLA fuses them); they
                        are dropped below a size threshold.
8. collective inject -> distributed estimation (paper §6.2/6.4's
                        "inject simulated allreduce buffers"): adds
                        COLLECTIVE blocks for gradient reduction buckets
                        and TP gather temporaries.
9. sharding          -> per-device sizes via shard factors from the
                        sharding engine.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from .events import BlockKind, BlockLifecycle, MemorySpace, Phase

# Elementwise/layout primitives XLA reliably fuses into consumers —
# their outputs typically never hit HBM as standalone buffers.
FUSIBLE_OPS = frozenset({
    "add", "sub", "mul", "div", "neg", "exp", "log", "tanh", "logistic",
    "max", "min", "pow", "integer_pow", "sqrt", "rsqrt", "abs", "sign",
    "convert_element_type", "select_n", "broadcast_in_dim", "reshape",
    "transpose", "squeeze", "expand_dims", "stop_gradient", "and", "or",
    "not", "xor", "eq", "ne", "ge", "gt", "le", "lt", "clamp", "erf",
    "floor", "ceil", "round", "is_finite", "copy", "real", "imag",
    "slice", "rev", "iota", "cos", "sin", "cumsum", "cumlogsumexp",
})


@dataclasses.dataclass(frozen=True)
class OffloadPlan:
    """A-priori host-offload schedule (TENSILE direction, ISSUE 8).

    TENSILE schedules tensor swapping reactively at runtime; because the
    event engine replays on CPU, the same swaps are decided here a
    priori, as a lifecycle rewrite: offloaded blocks change their
    ``space`` to a host space and paired transfer blocks (op
    ``offload_out`` / ``fetch_in``) model the device-side staging the
    copies need. The replay engine then reports per-space peaks and the
    roofline cost model charges the transfer bytes over PCIe.

    * ``optimizer_state`` — park persistent OPT_STATE on the host; a
      device staging copy exists only during each optimizer phase
      (fetched in before the update, written back after).
    * ``activations`` — fraction (by bytes, largest-first) of eligible
      saved activations offloaded between production (forward) and
      consumption (backward). Rematerialization interacts naturally:
      stronger remat policies save fewer/smaller activations, so the
      eligible set shrinks and with it the transfer cost.
    """

    optimizer_state: bool = False
    activations: float = 0.0          # 0..1, fraction of eligible bytes
    space: MemorySpace = MemorySpace.HOST_PINNED
    min_block_bytes: int = 1 << 16    # never offload tiny blocks
    stage_ticks: int = 1              # device residency of a staging copy

    @property
    def enabled(self) -> bool:
        return self.optimizer_state or self.activations > 0.0


@dataclasses.dataclass
class OrchestratorPolicy:
    """Knobs for lifecycle rewriting."""

    # "at_update"    - grads live until the optimizer phase consumes them
    #                  (PyTorch-eager semantics; paper POS0-ish)
    # "at_next_iter" - grads survive into the next iteration (accumulation
    #                  buffers / zero_grad at iteration start; paper POS1)
    # "eager_fused"  - XLA fuses per-leaf updates into the backward pass,
    #                  so each grad dies ~immediately after production.
    #                  Selected automatically when the update is per-leaf
    #                  (no cross-gradient coupling such as global-norm
    #                  clipping) — see estimator.update_grad_coupling.
    # "auto"         - estimator picks eager_fused vs at_update by taint
    #                  analysis of the update jaxpr.
    grad_release: str = "auto"
    eager_fuse_window: int = 6           # events a fused grad survives
    donate_params: bool = True
    donate_opt_state: bool = True
    # Step outputs (loss/metrics and, when donation is off, the freshly
    # written params/opt state) are overwritten by the next iteration's
    # outputs in a real loop — the old buffers die once replaced. Without
    # this pass every iteration leaks its outputs into "persistent",
    # which (a) inflates multi-iteration estimates (grossly so for
    # non-donated updates: + params x N) and (b) makes allocator state
    # drift forever, defeating steady-state replay. The final iteration's
    # outputs stay live (they are the job's results).
    release_outputs_next_iter: bool = True
    fusion_folding: bool = True
    fusion_max_lifetime: int = 8          # events a fusible temp may span
    fusion_min_bytes: int = 0             # fold regardless of size by default
    keep_unattributed: bool = True
    # Mixed-precision optimizers upcast each gradient to f32; observed
    # XLA schedules materialize these working copies together across
    # leaves during the update phase. Modeled as synthetic blocks of
    # grad_size * upcast_factor spanning the optimizer phase (ablation
    # benchmark quantifies the contribution).
    optimizer_upcast_coexist: bool = True
    upcast_factor: float = 2.0            # bf16 grads -> f32 copies
    # Backend scheduling-slack calibration (paper's Fig-6 loop made
    # explicit): one constant multiplying *transient* block sizes,
    # fitted once per target backend/runtime from a small calibration
    # set (XMemEstimator.calibrate). 1.0 = uncalibrated. Unlike
    # data-driven estimators this is model-independent — it captures
    # the runtime's buffering behavior, not the workload.
    transient_scale: float = 1.0
    # Host-offload schedule (None = everything stays in device HBM).
    # Applied as a separate pass *after* run/run_unfused so the fused
    # pipeline stays output-identical to its oracle.
    offload: OffloadPlan | None = None


@dataclasses.dataclass
class CollectiveSpec:
    """One injected communication buffer (distributed estimation).

    ``size`` is a fixed per-device byte count when ``source`` is empty.
    With ``source`` set, the buffer is sized at injection time from the
    composition's *actual sharded tensors* (per mesh axis, not a fixed
    factor): the largest per-device block of the named kind in the
    iteration, times ``scale`` (e.g. the axis size for an all-gather
    that materializes the unsharded tensor). ``axis``/``collective`` are
    attribution metadata (which mesh axis and primitive the buffer
    models)."""

    name: str
    size: int              # bytes per device (fixed-size specs)
    phase: Phase
    at: str = "phase_start"  # or "phase_end"
    persistent: bool = False
    axis: str = ""           # mesh axis the collective runs over
    collective: str = ""     # all_reduce | all_gather | reduce_scatter
    source: str = ""         # "" fixed | "grads" | "params" | "activations"
    scale: float = 1.0       # multiplier on the derived per-device size


class MemoryOrchestrator:
    def __init__(self, policy: OrchestratorPolicy | None = None):
        self.policy = policy or OrchestratorPolicy()

    # -- individual passes ---------------------------------------------------
    def mark_persistent(self, blocks: list[BlockLifecycle],
                        kinds=(BlockKind.PARAM, BlockKind.OPT_STATE)
                        ) -> list[BlockLifecycle]:
        return [dataclasses.replace(b, free_t=None)
                if b.block_kind in kinds else b for b in blocks]

    def batch_per_iteration(self, blocks: list[BlockLifecycle],
                            iteration_ends: dict[int, int]
                            ) -> list[BlockLifecycle]:
        """INPUT blocks die at their iteration's boundary marker."""
        out = []
        for b in blocks:
            if b.block_kind is BlockKind.INPUT:
                end = iteration_ends.get(b.iteration)
                if end is not None:
                    b = dataclasses.replace(b, free_t=end)
            out.append(b)
        return out

    def release_gradients(self, blocks: list[BlockLifecycle],
                          update_start: dict[int, int],
                          next_bwd_start: dict[int, int]
                          ) -> list[BlockLifecycle]:
        """Apply grad_release (the paper's zero_grad-placement semantics)."""
        out = []
        for b in blocks:
            # Only *persistent* GRAD blocks are true gradient outputs whose
            # release the framework controls; GRAD-classified backward
            # intermediates keep their tracer-derived lifetimes.
            if b.block_kind is BlockKind.GRAD and b.free_t is None:
                mode = self.policy.grad_release
                if mode in ("auto",):  # estimator resolves auto; fall back
                    mode = "at_update"
                if mode == "eager_fused":
                    us = update_start.get(b.iteration)
                    if b.op == "scan_ys":
                        # stacked-layer grads are backward-scan output
                        # buffers accumulated across the whole loop —
                        # they cannot die before the update consumes them
                        t = us
                    else:
                        t = b.alloc_t + self.policy.eager_fuse_window
                        if us is not None:
                            t = min(t, us)
                elif mode == "at_update":
                    t = update_start.get(b.iteration)
                else:  # at_next_iter: grads survive into the next iteration
                    t = next_bwd_start.get(b.iteration + 1)
                b = dataclasses.replace(b, free_t=t)  # None -> persistent
            out.append(b)
        return out

    def apply_donation(self, blocks: list[BlockLifecycle]
                       ) -> list[BlockLifecycle]:
        """Drop OUTPUT blocks that alias donated persistent inputs.

        With ``donate_argnums`` the updated params/opt-state are written
        into the old buffers; a simulator that allocates both
        double-counts — the classic over-estimation DNNMem-style static
        analysis exhibits (evaluated in benchmarks/ablation).
        """
        if not (self.policy.donate_params or self.policy.donate_opt_state):
            return blocks
        _PARAM, _OPT, _OUT = (BlockKind.PARAM, BlockKind.OPT_STATE,
                              BlockKind.OUTPUT)
        persistent_sizes: dict[int, int] = {}
        for b in blocks:
            bk = b.block_kind
            if (bk is _PARAM or bk is _OPT) and b.free_t is None:
                persistent_sizes[b.size] = persistent_sizes.get(b.size, 0) + 1
        # every iteration's update writes into the same donated buffers, so
        # the aliasing budget applies per iteration, not once for the trace
        budgets: dict[int, dict[int, int]] = {}
        out = []
        append = out.append
        for b in blocks:
            if b.block_kind is _OUT:
                budget = budgets.get(b.iteration)
                if budget is None:
                    budget = budgets[b.iteration] = dict(persistent_sizes)
                if budget.get(b.size, 0) > 0:
                    budget[b.size] -= 1
                    continue  # aliased: no new allocation
            append(b)
        return out

    def release_step_outputs(self, blocks: list[BlockLifecycle],
                             iteration_ends: dict[int, int]
                             ) -> list[BlockLifecycle]:
        """Free iteration i's surviving OUTPUT blocks at iteration i+1's
        end (when the next step's outputs have replaced them). Outputs of
        the final iteration — no successor in ``iteration_ends`` — stay
        persistent."""
        out = []
        for b in blocks:
            if b.block_kind is BlockKind.OUTPUT and b.free_t is None:
                end = iteration_ends.get(b.iteration + 1)
                if end is not None:
                    b = dataclasses.replace(b, free_t=end)
            out.append(b)
        return out

    def fold_fused(self, blocks: list[BlockLifecycle]) -> list[BlockLifecycle]:
        """Drop blocks XLA fusion would never materialize."""
        if not self.policy.fusion_folding:
            return blocks
        p = self.policy
        out = []
        for b in blocks:
            if (b.op in FUSIBLE_OPS
                    and b.free_t is not None
                    and (b.free_t - b.alloc_t) <= p.fusion_max_lifetime
                    and b.size >= p.fusion_min_bytes
                    and b.block_kind in (BlockKind.ACTIVATION, BlockKind.TEMP)):
                continue
            out.append(b)
        return out

    def inject_optimizer_upcasts(self, blocks: list[BlockLifecycle],
                                 update_start: dict[int, int],
                                 iteration_ends: dict[int, int]
                                 ) -> list[BlockLifecycle]:
        """Synthetic f32 working copies of gradients during the update."""
        if not self.policy.optimizer_upcast_coexist:
            return blocks
        out = list(blocks)
        bid = -100_000
        for b in blocks:
            if b.block_kind is not BlockKind.GRAD:
                continue
            us = update_start.get(b.iteration)
            end = iteration_ends.get(b.iteration)
            if us is None or end is None or us >= end:
                continue
            # only true gradient outputs (freed at/after update start)
            if b.free_t is not None and b.free_t < us:
                continue
            out.append(BlockLifecycle(
                bid, int(b.size * self.policy.upcast_factor), us, end,
                b.iteration, Phase.OPTIMIZER, "grad_upcast", b.scope,
                BlockKind.TEMP, b.shard_factor, b.shape))
            bid -= 1
        return out

    def inject_collectives(self, blocks: list[BlockLifecycle],
                           specs: Sequence[CollectiveSpec],
                           phase_bounds: dict[tuple[int, str], tuple[int, int]],
                           num_iterations: int,
                           shard_factor_fn: Callable | None = None
                           ) -> list[BlockLifecycle]:
        """Add COLLECTIVE buffers at phase starts/ends per iteration.

        Dynamic specs (``source`` set) are sized from the composition's
        actual blocks at their *per-device* size — the sharding pass runs
        after injection, so the factor function is applied here to the
        candidate source blocks (collective buffers themselves stay
        factor-1: they are already per-device quantities)."""
        if not specs:
            return blocks
        dynamic = [s for s in specs if s.source]
        src_max: dict[tuple[int, str], int] = {}
        if dynamic:
            wanted = {s.source for s in dynamic}

            def per_device(b: BlockLifecycle) -> int:
                if shard_factor_fn is not None:
                    f = max(shard_factor_fn(b), 1.0)
                    if f != 1.0:
                        return max(int(b.size / f), 1) if b.size else 0
                return b.sharded_size

            for b in blocks:
                k = b.block_kind
                if k is BlockKind.GRAD:
                    source = "grads"
                elif k is BlockKind.PARAM:
                    source = "params"
                elif k is BlockKind.ACTIVATION:
                    source = "activations"
                else:
                    continue
                if source not in wanted:
                    continue
                # persistent params count for every iteration
                its = (range(num_iterations) if k is BlockKind.PARAM
                       and b.free_t is None else (b.iteration,))
                s = per_device(b)
                for it in its:
                    key = (it, source)
                    if s > src_max.get(key, 0):
                        src_max[key] = s
        out = list(blocks)
        bid = -1  # negative ids: synthetic blocks
        for it in range(num_iterations):
            for s in specs:
                key = (it, s.phase.value)
                if key not in phase_bounds:
                    continue
                size = s.size
                if s.source:
                    size = int(src_max.get((it, s.source), 0) * s.scale)
                    if size <= 0:
                        continue
                start, end = phase_bounds[key]
                if s.at == "phase_start":
                    t0, t1 = start, end
                else:
                    # end-of-phase staging (gradient all-reduce /
                    # reduce-scatter): allocated one tick before the
                    # boundary so it coexists with tensors freed exactly
                    # at phase end (frees sort before allocs at equal t)
                    t0, t1 = max(start, end - 1), end
                out.append(BlockLifecycle(
                    bid, size, t0, None if s.persistent else t1,
                    it, s.phase, "collective", s.name, BlockKind.COLLECTIVE))
                bid -= 1
        return out

    def apply_transient_scale(self, blocks: list[BlockLifecycle]
                              ) -> list[BlockLifecycle]:
        """Scale transient (non-persistent, non-input) blocks by the
        backend calibration constant."""
        s = self.policy.transient_scale
        if s == 1.0:
            return blocks
        out = []
        for b in blocks:
            if b.free_t is not None and b.block_kind in (
                    BlockKind.ACTIVATION, BlockKind.TEMP, BlockKind.GRAD):
                b = dataclasses.replace(b, size=int(b.size * s))
            out.append(b)
        return out

    def apply_sharding(self, blocks: list[BlockLifecycle],
                       factor_fn: Callable[[BlockLifecycle], float]
                       ) -> list[BlockLifecycle]:
        return [dataclasses.replace(b, shard_factor=max(factor_fn(b), 1.0))
                for b in blocks]

    def apply_offload(self, blocks: list[BlockLifecycle],
                      update_start: dict[int, int] | None = None,
                      iteration_ends: dict[int, int] | None = None,
                      ) -> tuple[list[BlockLifecycle], dict | None]:
        """Rewrite lifecycles per the policy's :class:`OffloadPlan`.

        Runs *after* ``run``/``run_unfused`` (so the fused pipeline stays
        identical to its oracle) and before replay. Two rewrites:

        * optimizer-state: persistent OPT_STATE blocks move to the host
          space; each optimizer phase gets a device ``fetch_in`` staging
          copy spanning ``[update_start, iteration_end]`` (the state is
          fetched before the update and written back after — 2x bytes
          over the interconnect per iteration).
        * activations: eligible saved activations (device-resident,
          freed, >= ``min_block_bytes``, lifetime long enough to round-
          trip) are picked largest-first per iteration until the
          ``activations`` byte fraction is covered. The original block's
          device residency shrinks to a copy-out window at its head; a
          host block (op ``offload_out``) holds the bulk residency, and
          a device ``fetch_in`` staging block covers the copy-back
          window before the backward pass consumes it.

        Synthetic blocks get ids descending from -200000 (below the
        upcast namespace). Returns ``(blocks, stats)``; stats is None
        when no offload is configured. Transfer accounting uses
        per-device (sharded) sizes — those are the bytes that cross
        PCIe on each device.
        """
        plan = self.policy.offload
        if plan is None or not plan.enabled:
            return blocks, None
        update_start = update_start or {}
        iteration_ends = iteration_ends or {}
        _DEV = MemorySpace.DEVICE_HBM
        out: list[BlockLifecycle] = []
        extra: list[BlockLifecycle] = []
        bid = -200_000
        transfers: dict[int, int] = {}  # per-iteration transfer bytes
        opt_blocks = opt_bytes = 0
        act_blocks = act_bytes = 0
        min_life = 2 * plan.stage_ticks + 1

        # per-iteration activation selection: largest-first until the
        # requested byte fraction of the eligible set is covered
        selected: set[int] = set()
        if plan.activations > 0.0:
            eligible: dict[int, list[BlockLifecycle]] = {}
            for b in blocks:
                if (b.block_kind is BlockKind.ACTIVATION
                        and b.space is _DEV
                        and b.free_t is not None
                        and b.size >= plan.min_block_bytes
                        and (b.free_t - b.alloc_t) > min_life):
                    eligible.setdefault(b.iteration, []).append(b)
            for it, cands in eligible.items():
                total = sum(c.size for c in cands)
                target = plan.activations * total
                taken = 0
                cands.sort(key=lambda c: (-c.size, c.alloc_t, c.block_id))
                for c in cands:
                    if taken >= target:
                        break
                    selected.add(id(c))
                    taken += c.size

        for b in blocks:
            if (plan.optimizer_state
                    and b.block_kind is BlockKind.OPT_STATE
                    and b.space is _DEV
                    and b.free_t is None
                    and b.size >= plan.min_block_bytes):
                out.append(dataclasses.replace(b, space=plan.space))
                opt_blocks += 1
                opt_bytes += b.sharded_size
                for it, us in update_start.items():
                    end = iteration_ends.get(it)
                    if us is None or end is None or us >= end:
                        continue
                    extra.append(BlockLifecycle(
                        bid, b.size, us, end, it, Phase.OPTIMIZER,
                        "fetch_in", b.scope, BlockKind.OPT_STATE,
                        b.shard_factor, b.shape))
                    bid -= 1
                    transfers[it] = (transfers.get(it, 0)
                                     + 2 * b.sharded_size)
                continue
            if id(b) in selected:
                head_end = b.alloc_t + plan.stage_ticks
                tail_start = max(b.free_t - plan.stage_ticks, head_end)
                out.append(dataclasses.replace(b, free_t=head_end))
                extra.append(BlockLifecycle(
                    bid, b.size, b.alloc_t, b.free_t, b.iteration,
                    b.phase, "offload_out", b.scope, b.block_kind,
                    b.shard_factor, b.shape, plan.space))
                bid -= 1
                extra.append(BlockLifecycle(
                    bid, b.size, tail_start, b.free_t, b.iteration,
                    b.phase, "fetch_in", b.scope, b.block_kind,
                    b.shard_factor, b.shape))
                bid -= 1
                act_blocks += 1
                act_bytes += b.sharded_size
                transfers[b.iteration] = (
                    transfers.get(b.iteration, 0) + 2 * b.sharded_size)
                continue
            out.append(b)
        out.extend(extra)
        # steady-state transfer bytes: the cycle iteration (1) when the
        # composition has one, else the heaviest observed iteration
        steady = transfers.get(1)
        if steady is None:
            steady = max(transfers.values(), default=0)
        stats = {
            "opt_state_blocks": opt_blocks,
            "opt_state_bytes": opt_bytes,
            "activation_blocks": act_blocks,
            "activation_bytes": act_bytes,
            "transfer_bytes_per_iter": steady,
            "space": plan.space.value,
        }
        return out, stats

    # -- composite ------------------------------------------------------------
    def run_unfused(self, blocks: list[BlockLifecycle], *,
                    iteration_ends: dict[int, int] | None = None,
                    update_start: dict[int, int] | None = None,
                    next_bwd_start: dict[int, int] | None = None,
                    collective_specs: Sequence[CollectiveSpec] = (),
                    phase_bounds: dict | None = None,
                    num_iterations: int = 1,
                    shard_factor_fn=None) -> list[BlockLifecycle]:
        """The pass pipeline as individual passes — the readable form
        ``run`` is a fusion of (and the oracle it is tested against)."""
        # fold first: fused temps are never touched by the lifecycle
        # passes below (they act on PARAM/OPT/GRAD/INPUT/OUTPUT or on
        # persistent blocks, which fusible short-lived temps are not), so
        # dropping them up front shrinks every subsequent pass
        blocks = self.fold_fused(blocks)
        blocks = self.mark_persistent(blocks)
        if iteration_ends:
            blocks = self.batch_per_iteration(blocks, iteration_ends)
        if update_start is not None:
            blocks = self.release_gradients(blocks, update_start,
                                            next_bwd_start or {})
            if iteration_ends:
                blocks = self.inject_optimizer_upcasts(
                    blocks, update_start, iteration_ends)
        blocks = self.apply_donation(blocks)
        if self.policy.release_outputs_next_iter and iteration_ends:
            blocks = self.release_step_outputs(blocks, iteration_ends)
        blocks = self.apply_transient_scale(blocks)
        if collective_specs and phase_bounds:
            blocks = self.inject_collectives(blocks, collective_specs,
                                             phase_bounds, num_iterations,
                                             shard_factor_fn)
        if shard_factor_fn is not None:
            blocks = self.apply_sharding(blocks, shard_factor_fn)
        return blocks

    def run(self, blocks: list[BlockLifecycle], *,
            iteration_ends: dict[int, int] | None = None,
            update_start: dict[int, int] | None = None,
            next_bwd_start: dict[int, int] | None = None,
            collective_specs: Sequence[CollectiveSpec] = (),
            phase_bounds: dict | None = None,
            num_iterations: int = 1,
            shard_factor_fn: Callable[[BlockLifecycle], float] | None = None,
            ) -> list[BlockLifecycle]:
        """Fused pass pipeline — output-identical to ``run_unfused``
        (asserted by tests/test_columnar.py) but two list traversals
        instead of eight. This is the estimator's per-point hot loop, so
        the per-block passes (fold, persistence, batch, grad release,
        upcast injection) run in one pass that also collects the donation
        budget, and the list-order-dependent tail (donation, output
        release, transient scale) runs in a second."""
        p = self.policy
        iteration_ends = iteration_ends or {}
        update_start_d = update_start if update_start is not None else None
        next_bwd = next_bwd_start or {}
        do_batch = bool(iteration_ends)
        do_upcast = (update_start is not None and bool(iteration_ends)
                     and p.optimizer_upcast_coexist)
        grad_mode = p.grad_release
        if grad_mode in ("auto",):
            grad_mode = "at_update"
        _PARAM, _OPT, _GRAD = (BlockKind.PARAM, BlockKind.OPT_STATE,
                               BlockKind.GRAD)
        _IN, _OUT, _ACT, _TMP = (BlockKind.INPUT, BlockKind.OUTPUT,
                                 BlockKind.ACTIVATION, BlockKind.TEMP)
        fold = p.fusion_folding
        fuse_life, fuse_min = p.fusion_max_lifetime, p.fusion_min_bytes
        out: list[BlockLifecycle] = []
        append = out.append
        upcast_blocks: list[BlockLifecycle] = []
        persistent_sizes: dict[int, int] = {}
        for b in blocks:
            kind = b.block_kind
            free_t = b.free_t
            # fold_fused
            if (fold and free_t is not None and b.op in FUSIBLE_OPS
                    and (free_t - b.alloc_t) <= fuse_life
                    and b.size >= fuse_min and (kind is _ACT or kind is _TMP)):
                continue
            # mark_persistent
            if kind is _PARAM or kind is _OPT:
                if free_t is not None:
                    b = dataclasses.replace(b, free_t=None)
                persistent_sizes[b.size] = \
                    persistent_sizes.get(b.size, 0) + 1
                append(b)
                continue
            # batch_per_iteration
            if do_batch and kind is _IN:
                end = iteration_ends.get(b.iteration)
                if end is not None:
                    b = dataclasses.replace(b, free_t=end)
                append(b)
                continue
            # release_gradients (+ upcast injection bookkeeping)
            if kind is _GRAD and update_start_d is not None:
                if free_t is None:
                    if grad_mode == "eager_fused":
                        us = update_start_d.get(b.iteration)
                        if b.op == "scan_ys":
                            t = us
                        else:
                            t = b.alloc_t + p.eager_fuse_window
                            if us is not None:
                                t = min(t, us)
                    elif grad_mode == "at_update":
                        t = update_start_d.get(b.iteration)
                    else:  # at_next_iter
                        t = next_bwd.get(b.iteration + 1)
                    b = dataclasses.replace(b, free_t=t)
                    free_t = t
                if do_upcast:
                    us = update_start_d.get(b.iteration)
                    end = iteration_ends.get(b.iteration)
                    if (us is not None and end is not None and us < end
                            and (free_t is None or free_t >= us)):
                        upcast_blocks.append((b, us, end))
                append(b)
                continue
            append(b)
        # inject_optimizer_upcasts appends synthetic blocks at the tail,
        # in GRAD block order, ids descending from -100000
        bid = -100_000
        for b, us, end in upcast_blocks:
            append(BlockLifecycle(
                bid, int(b.size * p.upcast_factor), us, end,
                b.iteration, Phase.OPTIMIZER, "grad_upcast", b.scope,
                BlockKind.TEMP, b.shard_factor, b.shape))
            bid -= 1
        # second traversal: donation, output release, transient scale
        do_donate = p.donate_params or p.donate_opt_state
        do_release_out = p.release_outputs_next_iter and bool(iteration_ends)
        scale = p.transient_scale
        budgets: dict[int, dict[int, int]] = {}
        blocks2: list[BlockLifecycle] = []
        append2 = blocks2.append
        for b in out:
            if b.block_kind is _OUT:
                if do_donate:
                    budget = budgets.get(b.iteration)
                    if budget is None:
                        budget = budgets[b.iteration] = \
                            dict(persistent_sizes)
                    if budget.get(b.size, 0) > 0:
                        budget[b.size] -= 1
                        continue          # aliased: no new allocation
                if do_release_out and b.free_t is None:
                    end = iteration_ends.get(b.iteration + 1)
                    if end is not None:
                        b = dataclasses.replace(b, free_t=end)
            if (scale != 1.0 and b.free_t is not None
                    and b.block_kind in (_ACT, _TMP, _GRAD)):
                b = dataclasses.replace(b, size=int(b.size * scale))
            append2(b)
        blocks = blocks2
        if collective_specs and phase_bounds:
            blocks = self.inject_collectives(blocks, collective_specs,
                                             phase_bounds, num_iterations,
                                             shard_factor_fn)
        if shard_factor_fn is not None:
            blocks = self.apply_sharding(blocks, shard_factor_fn)
        return blocks


# -- request-driven serving workloads (ISSUE 9) ------------------------------
@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """One serving request of a :class:`RequestStream`.

    Ticks are discrete scheduler steps: the request arrives at
    ``arrival_t``, prefills ``prompt_len`` tokens the tick it joins a
    batch slot, then decodes one token per tick for ``decode_len``
    ticks and leaves. ``shared_prefix_len`` marks how many of its
    prompt tokens are the stream-wide common prefix (system prompt /
    few-shot header) eligible for prefix-cache page sharing.
    ``evict_at`` scripts a preemption: at that absolute tick the
    request is evicted (all private pages freed), re-queues, and
    re-prefills everything generated so far when a slot frees.
    """

    arrival_t: int
    prompt_len: int
    decode_len: int
    shared_prefix_len: int = 0
    evict_at: int | None = None


@dataclasses.dataclass(frozen=True)
class ServingKnobs:
    """The serving-runtime knobs the planner searches.

    ``page_size`` is the KV block granularity in tokens;
    ``max_concurrent`` caps in-flight sequences (arrivals queue);
    ``kv_dtype_bytes`` is the stored KV element width (2 = bf16,
    1 = fp8) scaling the per-token page bytes relative to the traced
    base dtype; ``prefix_cache`` enables shared-prompt page dedup;
    ``speculative_k`` reserves a k-token draft-KV scratch block per
    active request (speculative decoding).
    """

    page_size: int = 16
    max_concurrent: int = 8
    kv_dtype_bytes: int = 2
    prefix_cache: bool = True
    speculative_k: int = 0

    def signature(self) -> tuple:
        """Hashable identity for degradation-family separation."""
        return (self.page_size, self.max_concurrent,
                self.kv_dtype_bytes, self.prefix_cache,
                self.speculative_k)


@dataclasses.dataclass(frozen=True)
class RequestStream:
    """A concrete request timeline (the serving workload)."""

    requests: tuple = ()

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def max_seq_len(self) -> int:
        """Longest total sequence any request reaches — what a
        monolithic (non-paged) cache must provision per slot."""
        return max((r.prompt_len + r.decode_len for r in self.requests),
                   default=0)


@dataclasses.dataclass(frozen=True)
class RequestMix:
    """A request-mix distribution: deterministic stand-in for arrival
    randomness so serving decisions reproduce bit-identically.

    ``buckets`` is ``((prompt_len, decode_len, count), ...)``;
    ``stream()`` expands it round-robin across buckets with one arrival
    every ``arrival_period`` ticks — a worst-case-dense, fully
    deterministic timeline (no RNG anywhere near an admission answer).
    """

    buckets: tuple
    arrival_period: int = 1
    shared_prefix_len: int = 0

    def stream(self) -> RequestStream:
        remaining = [[int(p), int(d), int(c)] for p, d, c in self.buckets
                     if c > 0]
        reqs, t, i = [], 0, 0
        while remaining:
            b = remaining[i % len(remaining)]
            reqs.append(RequestSpec(
                arrival_t=t, prompt_len=b[0], decode_len=b[1],
                shared_prefix_len=min(self.shared_prefix_len, b[0])))
            b[2] -= 1
            if b[2] == 0:
                remaining.remove(b)
            t += self.arrival_period
            i += 1
        return RequestStream(tuple(reqs))

    @property
    def n_requests(self) -> int:
        return sum(c for _p, _d, c in self.buckets)

    def to_json(self) -> dict:
        return {"buckets": [list(b) for b in self.buckets],
                "arrival_period": self.arrival_period,
                "shared_prefix_len": self.shared_prefix_len}


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class ContinuousBatchingScheduler:
    """Lower a :class:`RequestStream` to a request-driven allocation
    stream (:class:`~repro.core.events.RequestBlocks`).

    This is the serving analogue of the periodic composer: a CPU-side
    replay of the continuous-batching runloop that emits one
    ``BlockLifecycle`` per KV page / scratch / per-request state block
    at the exact tick it is allocated and freed, so the allocator
    simulator sees the same dynamic pressure a paged-attention server
    produces — page-granular allocations (never one monolithic cache
    tensor), prefix-shared pages refcounted across requests, and
    speculative-decoding scratch riding along per active sequence.

    Determinism contract: identical (stream, knobs, byte parameters)
    inputs produce an identical lifecycle list — serving decisions and
    counter-offers reproduce bit-identically from cold services.
    """

    #: runaway guard: a lowering may not emit more lifecycles than this
    MAX_BLOCKS = 2_000_000

    def __init__(self, knobs: ServingKnobs = ServingKnobs()):
        if knobs.page_size <= 0 or knobs.max_concurrent <= 0 \
                or knobs.kv_dtype_bytes <= 0:
            raise ValueError(f"invalid serving knobs: {knobs}")
        self.knobs = knobs

    def page_bytes(self, kv_bytes_per_token: int,
                   base_dtype_bytes: int = 2) -> int:
        """Device bytes of one KV page under these knobs' dtype."""
        k = self.knobs
        tok = _ceil_div(int(kv_bytes_per_token) * k.kv_dtype_bytes,
                        max(int(base_dtype_bytes), 1))
        return k.page_size * max(tok, 1)

    def lower(self, stream: RequestStream, kv_bytes_per_token: int, *,
              resident_bytes_per_request: int = 0,
              base_dtype_bytes: int = 2):
        """Run the continuous-batching timeline; return RequestBlocks.

        ``kv_bytes_per_token`` is the per-token KV footprint at the
        model's base dtype (all layers summed); the knobs' dtype scales
        it. ``resident_bytes_per_request`` covers non-paged per-slot
        state (SSM / hybrid recurrent state — constant-size, not
        length-dependent, so it never pages).
        """
        from .events import RequestBlocks
        k = self.knobs
        page_b = self.page_bytes(kv_bytes_per_token, base_dtype_bytes)
        tok_b = max(page_b // k.page_size, 1)
        scratch_b = k.speculative_k * tok_b

        blocks: list[BlockLifecycle] = []
        next_bid = [1]

        def open_block(t: int, size: int, kind: BlockKind, op: str,
                       scope: str) -> int:
            bid = next_bid[0]
            next_bid[0] += 1
            blocks.append(BlockLifecycle(
                bid, int(size), int(t), None, 0, Phase.DECODE, op,
                scope, kind))
            return len(blocks) - 1

        def close_block(idx: int, t: int) -> None:
            blocks[idx] = dataclasses.replace(blocks[idx], free_t=int(t))

        # shared prefix pages: page index -> [block idx, refcount]
        shared_pages: dict[int, list] = {}
        live_now = [0]

        def acquire_shared(t: int, n_pages: int) -> list[int]:
            out = []
            for p in range(n_pages):
                ent = shared_pages.get(p)
                if ent is None or blocks[ent[0]].free_t is not None:
                    ent = [open_block(t, page_b, BlockKind.CACHE,
                                      "kv_page",
                                      f"serving/prefix/page{p}"), 0]
                    shared_pages[p] = ent
                    live_now[0] += page_b
                ent[1] += 1
                out.append(p)
            return out

        def release_shared(t: int, pages: list[int]) -> None:
            for p in pages:
                ent = shared_pages[p]
                ent[1] -= 1
                if ent[1] == 0:
                    close_block(ent[0], t)
                    live_now[0] -= page_b

        class _Active:
            __slots__ = ("r", "ridx", "tokens", "pages", "shared",
                         "aux", "decoded")

            def __init__(self):
                self.pages: list[int] = []      # private page block idxs
                self.shared: list[int] = []     # shared page indices
                self.aux: list[int] = []        # scratch/state block idxs

        waiting = sorted(range(len(stream.requests)),
                         key=lambda i: (stream.requests[i].arrival_t, i))
        waiting = list(waiting)
        requeued: list[int] = []            # evicted, FIFO, by index
        evicted_tokens: dict[int, int] = {}  # ridx -> tokens at eviction
        evicted_once: set[int] = set()       # scripted evictions fire once
        active: list[_Active] = []
        occupancy: list[int] = []
        live_paged: list[int] = []          # per-tick paged+aux live bytes
        evictions = 0
        t = 0

        def open_counted(t, size, kind, op, scope):
            live_now[0] += int(size)
            return open_block(t, size, kind, op, scope)

        def close_counted(idx, t):
            live_now[0] -= blocks[idx].size
            close_block(idx, t)

        def join(ridx: int, t: int) -> _Active:
            r = stream.requests[ridx]
            a = _Active()
            a.r, a.ridx = r, ridx
            a.tokens = evicted_tokens.pop(ridx, r.prompt_len)
            a.decoded = max(a.tokens - r.prompt_len, 0)
            shared_tokens = (r.shared_prefix_len if k.prefix_cache
                             else 0)
            n_shared = min(shared_tokens, a.tokens) // k.page_size
            if n_shared:
                a.shared = acquire_shared(t, n_shared)
            n_total = _ceil_div(a.tokens, k.page_size) if a.tokens else 0
            for p in range(len(a.shared), max(n_total, len(a.shared))):
                a.pages.append(open_counted(
                    t, page_b, BlockKind.CACHE, "kv_page",
                    f"serving/req{ridx}/page{p}"))
            if resident_bytes_per_request:
                a.aux.append(open_counted(
                    t, resident_bytes_per_request, BlockKind.CACHE,
                    "decode_state", f"serving/req{ridx}/state"))
            if scratch_b:
                a.aux.append(open_counted(
                    t, scratch_b, BlockKind.TEMP, "spec_scratch",
                    f"serving/req{ridx}/scratch"))
            return a

        def leave(a: _Active, t: int) -> None:
            for idx in a.pages:
                close_counted(idx, t)
            for idx in a.aux:
                close_counted(idx, t)
            if a.shared:
                release_shared(t, a.shared)

        while waiting or requeued or active:
            if len(blocks) > self.MAX_BLOCKS:
                raise ValueError(
                    f"request stream lowers to more than "
                    f"{self.MAX_BLOCKS} blocks — shrink the stream or "
                    f"raise the page size")
            # 1) departures: requests that finished last tick's decode
            still = []
            for a in active:
                if a.decoded >= a.r.decode_len:
                    leave(a, t)
                else:
                    still.append(a)
            active = still
            # 2) scripted evictions
            still = []
            for a in active:
                if a.r.evict_at is not None and t >= a.r.evict_at \
                        and a.ridx not in evicted_once:
                    evicted_once.add(a.ridx)
                    evicted_tokens[a.ridx] = a.tokens
                    leave(a, t)
                    requeued.append(a.ridx)
                    evictions += 1
                else:
                    still.append(a)
            active = still
            # 3) admissions: re-queued first, then arrivals in order
            while len(active) < k.max_concurrent and (
                    requeued
                    or (waiting and stream.requests[waiting[0]].arrival_t
                        <= t)):
                ridx = (requeued.pop(0) if requeued
                        else waiting.pop(0))
                active.append(join(ridx, t))
            # 4) decode one token per active request
            for a in active:
                a.tokens += 1
                a.decoded += 1
                if a.tokens > (len(a.shared) + len(a.pages)) \
                        * k.page_size:
                    p = len(a.shared) + len(a.pages)
                    a.pages.append(open_counted(
                        t, page_b, BlockKind.CACHE, "kv_page",
                        f"serving/req{a.ridx}/page{p}"))
            occupancy.append(len(active))
            live_paged.append(live_now[0])
            t += 1

        meta = {
            "workload": "request_stream",
            "ticks": t,
            "n_requests": len(stream.requests),
            "evictions": evictions,
            "page_bytes": page_b,
            "kv_bytes_per_token": tok_b,
            "resident_bytes_per_request": int(resident_bytes_per_request),
            "occupancy": occupancy,
            "live_paged": live_paged,
            "knobs": dataclasses.asdict(k),
        }
        return RequestBlocks(blocks, meta)
