"""Two-level memory-allocator simulation (paper §3.4 + released artifact).

Level 1 — the *framework* caching allocator. Default policy is a faithful
Python port of PyTorch's ``CUDACachingAllocator`` (c10/cuda, release/2.6):
512-byte rounding, small/large pools with 2 MiB / 20 MiB segments,
best-fit-with-coalescing (BFC), block splitting, segment caching, and the
reclaim-before-OOM ladder. Two further policies adapt the simulation to
the XLA world (DESIGN.md §2): ``XLA_BFC`` (TF/XLA GPU BFC: 256-byte
rounding, single pool, doubling region growth) and ``TPU_ARENA`` (TPU
runtime: compacting arena — per-program static assignment means external
fragmentation is resolved at compile time, so reserved ≈ rounded live).

Level 2 — the *device* allocator: grants whole segments against an HBM
capacity with its own page granularity. An OOM is signalled only when a
request fails at L1, L1 reclaims its cached segments, and the L2 grant
still fails — the complete chain the paper identifies as the true OOM
condition (§3.4(v)), which simpler simulators (DNNMem) omit.
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
from typing import Optional

import numpy as np

KiB = 1024
MiB = 1024 * 1024


class SimOOMError(Exception):
    """Raised when both allocator levels fail, post-reclaim (paper §3.4(v))."""

    def __init__(self, requested: int, reserved: int, capacity: int):
        self.requested, self.reserved, self.capacity = requested, reserved, capacity
        super().__init__(
            f"simulated OOM: request {requested} B with {reserved} B reserved "
            f"of {capacity} B capacity (after cache reclaim)"
        )


@dataclasses.dataclass(frozen=True)
class AllocatorPolicy:
    """Constants defining one framework-allocator behavior."""

    name: str
    min_block: int            # block-size rounding quantum
    small_size: int           # requests <= this use the small pool
    small_buffer: int         # segment size for small-pool requests
    large_buffer: int         # segment size for mid-size large requests
    min_large_alloc: int      # requests >= this size their own segment
    round_large: int          # granularity for own-segment sizing
    device_page: int          # L2 grant granularity
    split_remainder_large: int  # split a large block only if remainder > this
    single_pool: bool = False   # XLA BFC has no small/large split
    growth_doubling: bool = False  # XLA BFC grows regions by doubling
    arena: bool = False         # TPU arena: compacting, no external frag


# PyTorch CUDACachingAllocator constants (c10/cuda/CUDACachingAllocator.cpp).
CUDA_CACHING = AllocatorPolicy(
    name="cuda_caching", min_block=512, small_size=1 * MiB,
    small_buffer=2 * MiB, large_buffer=20 * MiB, min_large_alloc=10 * MiB,
    round_large=2 * MiB, device_page=2 * MiB, split_remainder_large=1 * MiB,
)

# TF/XLA GPU BFC allocator: 256-byte alignment, one pool, doubling regions.
XLA_BFC = AllocatorPolicy(
    name="xla_bfc", min_block=256, small_size=0,
    small_buffer=1 * MiB, large_buffer=1 * MiB, min_large_alloc=1 * MiB,
    round_large=1 * MiB, device_page=2 * MiB, split_remainder_large=256,
    single_pool=True, growth_doubling=True,
)

# TPU runtime arena: compile-time buffer assignment compacts temps, so the
# reserved footprint tracks rounded live bytes (512-byte lane alignment).
TPU_ARENA = AllocatorPolicy(
    name="tpu_arena", min_block=512, small_size=0,
    small_buffer=1 * MiB, large_buffer=1 * MiB, min_large_alloc=0,
    round_large=4 * KiB, device_page=4 * KiB, split_remainder_large=512,
    single_pool=True, arena=True,
)

# Host-side policies for the multi-space model (ISSUE 8). Pinned host
# memory is page-locked (cudaHostAlloc / TPU pinned pools): 4 KiB pages,
# arena semantics — a pinned pool never externally fragments in the way
# a device BFC does, so reserved tracks rounded live. Pageable host
# memory is plain malloc: 64-byte rounding, same arena accounting.
HOST_PINNED_ARENA = AllocatorPolicy(
    name="host_pinned", min_block=4 * KiB, small_size=0,
    small_buffer=1 * MiB, large_buffer=1 * MiB, min_large_alloc=0,
    round_large=4 * KiB, device_page=4 * KiB, split_remainder_large=4 * KiB,
    single_pool=True, arena=True,
)

HOST_PAGEABLE_MALLOC = AllocatorPolicy(
    name="host_pageable", min_block=64, small_size=0,
    small_buffer=1 * MiB, large_buffer=1 * MiB, min_large_alloc=0,
    round_large=4 * KiB, device_page=4 * KiB, split_remainder_large=64,
    single_pool=True, arena=True,
)

POLICIES = {p.name: p for p in (CUDA_CACHING, XLA_BFC, TPU_ARENA,
                                HOST_PINNED_ARENA, HOST_PAGEABLE_MALLOC)}


@dataclasses.dataclass(frozen=True)
class MemorySpaceSpec:
    """Per-space allocator configuration: which policy models the space
    and how much capacity it has (``None`` = unbounded — host RAM is
    effectively unbounded relative to HBM for estimation purposes)."""

    space: "object"                    # events.MemorySpace (no import cycle)
    policy: AllocatorPolicy
    capacity: int | None = None

    @property
    def bounded(self) -> bool:
        return self.capacity is not None


def default_space_specs(device_policy: AllocatorPolicy,
                        device_capacity: int | None = None,
                        host_pinned_capacity: int | None = None,
                        host_pageable_capacity: int | None = None) -> dict:
    """The standard three-space layout: the caller's device policy plus
    arena-modeled host spaces. Returns ``{MemorySpace: MemorySpaceSpec}``
    keyed by every member of :class:`~repro.core.events.MemorySpace`, so
    a replay engine can look any block's space up unconditionally."""
    from .events import MemorySpace
    return {
        MemorySpace.DEVICE_HBM: MemorySpaceSpec(
            MemorySpace.DEVICE_HBM, device_policy, device_capacity),
        MemorySpace.HOST_PINNED: MemorySpaceSpec(
            MemorySpace.HOST_PINNED, HOST_PINNED_ARENA,
            host_pinned_capacity),
        MemorySpace.HOST_PAGEABLE: MemorySpaceSpec(
            MemorySpace.HOST_PAGEABLE, HOST_PAGEABLE_MALLOC,
            host_pageable_capacity),
    }


def round_up(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q if q else x


# -- vectorized size policy (columnar replay engine) -------------------------
def round_up_array(x: np.ndarray, q: int) -> np.ndarray:
    """Elementwise ``round_up`` over an int64 array."""
    if not q:
        return x
    return (x + (q - 1)) // q * q


def round_size_array(sizes: np.ndarray, policy: AllocatorPolicy) -> np.ndarray:
    """Elementwise ``CachingAllocatorSim.round_size`` — request rounding
    for a whole event column in one shot."""
    return np.maximum(round_up_array(sizes, policy.min_block),
                      policy.min_block)


class DeviceAllocatorSim:
    """Level-2 simulator: grants segments against an HBM/VRAM capacity."""

    def __init__(self, capacity: int, page: int):
        self.capacity = capacity
        self.page = page
        self.reserved = 0
        self.peak_reserved = 0
        self.n_grants = 0
        self.n_returns = 0

    def grant(self, size: int) -> bool:
        size = round_up(size, self.page)
        if self.reserved + size > self.capacity:
            return False
        self.reserved += size
        self.peak_reserved = max(self.peak_reserved, self.reserved)
        self.n_grants += 1
        return True

    def release(self, size: int) -> None:
        self.n_returns += 1
        self.reserved -= round_up(size, self.page)


class _Block:
    """A block inside a segment; doubly linked for coalescing."""

    __slots__ = ("uid", "segment", "offset", "size", "requested", "free",
                 "prev", "next")

    def __init__(self, uid, segment, offset, size):
        self.uid = uid
        self.segment = segment
        self.offset = offset
        self.size = size
        self.requested = 0
        self.free = True
        self.prev: Optional[_Block] = None
        self.next: Optional[_Block] = None


class _Segment:
    __slots__ = ("sid", "pool", "size", "head", "live")

    def __init__(self, sid, pool, size, head):
        self.sid, self.pool, self.size, self.head = sid, pool, size, head
        self.live = 0            # in-use blocks inside this segment

    def fully_free(self) -> bool:
        return self.head.free and self.head.next is None


class _FreeIndex:
    """Best-fit index over free blocks: sorted (size, uid) list + map."""

    def __init__(self):
        self._keys: list[tuple[int, int]] = []
        self._blocks: dict[int, _Block] = {}

    def add(self, b: _Block) -> None:
        bisect.insort(self._keys, (b.size, b.uid))
        self._blocks[b.uid] = b

    def remove(self, b: _Block) -> None:
        i = bisect.bisect_left(self._keys, (b.size, b.uid))
        assert i < len(self._keys) and self._keys[i] == (b.size, b.uid)
        del self._keys[i]
        del self._blocks[b.uid]

    def best_fit(self, size: int) -> Optional[_Block]:
        i = bisect.bisect_left(self._keys, (size, -1))
        if i == len(self._keys):
            return None
        return self._blocks[self._keys[i][1]]

    def __len__(self):
        return len(self._keys)


class CachingAllocatorSim:
    """Level-1 framework caching-allocator simulator (BFC).

    The public surface is ``malloc(req) -> handle`` / ``free(handle)`` plus
    statistics, mirroring what the Simulator stage replays events through.
    """

    def __init__(self, policy: AllocatorPolicy, device: DeviceAllocatorSim):
        self.policy = policy
        self.device = device
        self._uid = itertools.count()
        self._sid = itertools.count()
        self._free_small = _FreeIndex()
        self._free_large = _FreeIndex()
        self._segments: dict[int, _Segment] = {}
        self._inuse: dict[int, _Block] = {}
        self._grow_next = policy.small_buffer  # growth_doubling cursor
        # statistics
        self.allocated = 0          # bytes of in-use (rounded) blocks
        self.reserved = 0           # bytes held in segments (cached incl.)
        self.peak_allocated = 0
        self.peak_reserved = 0
        self.n_splits = 0
        self.n_merges = 0
        self.n_cache_hits = 0
        self.timeline: list[tuple[int, int, int]] = []  # (t, allocated, reserved)
        # In-use device demand: bytes of segments holding >= 1 live block,
        # page-rounded as the device sees them. Its running max is the
        # single-replay capacity-sweep instrument (min_feasible_capacity):
        # cached-but-free segments are reclaimable under pressure, so the
        # true device requirement at any instant is the in-use demand.
        self.inuse_demand = 0
        self.max_inuse_demand = 0

    # -- size policy ------------------------------------------------------
    def round_size(self, size: int) -> int:
        return max(round_up(size, self.policy.min_block), self.policy.min_block)

    def _pool_of(self, size: int) -> _FreeIndex:
        if self.policy.single_pool or size > self.policy.small_size:
            return self._free_large
        return self._free_small

    def allocation_size(self, size: int) -> int:
        """Segment size requested from the device for a given block size."""
        p = self.policy
        if p.growth_doubling:
            seg = max(self._grow_next, round_up(size, p.round_large))
            return seg
        if not p.single_pool and size <= p.small_size:
            return p.small_buffer
        if size < p.min_large_alloc:
            return p.large_buffer
        return round_up(size, p.round_large)

    def _should_split(self, block: _Block, size: int) -> bool:
        remaining = block.size - size
        p = self.policy
        if p.single_pool or size <= p.small_size:
            return remaining >= p.min_block
        return remaining > p.split_remainder_large

    # -- segment machinery --------------------------------------------------
    def _new_segment(self, pool_name: str, seg_size: int) -> Optional[_Block]:
        if not self.device.grant(seg_size):
            return None
        sid = next(self._sid)
        blk = _Block(next(self._uid), sid, 0, seg_size)
        self._segments[sid] = _Segment(sid, pool_name, seg_size, blk)
        self.reserved += seg_size
        self.peak_reserved = max(self.peak_reserved, self.reserved)
        if self.policy.growth_doubling:
            # TF BFC (BFCAllocator::Extend): a request larger than the
            # growth cursor doubles the cursor until it covers the
            # request and allocates WITHOUT the post-allocation double
            # (increased_allocation short-circuit); only pool-growth
            # regions served at the cursor size double it for next time.
            if seg_size > self._grow_next:
                g = self._grow_next
                while g < seg_size:
                    g *= 2
                self._grow_next = min(g, 1 << 36)
            else:
                self._grow_next = min(self._grow_next * 2, 1 << 36)
        return blk

    def _release_segment(self, seg: _Segment) -> None:
        idx = self._free_small if seg.pool == "small" else self._free_large
        idx.remove(seg.head)
        self.device.release(seg.size)
        self.reserved -= seg.size
        del self._segments[seg.sid]

    def _release_cached(self, pool: Optional[str], need: int) -> int:
        """Free fully-cached segments (largest first); returns bytes freed.

        The reclaim target is compared in *device pages*: the retry grant
        needs ``round_up(need, device_page)`` bytes of device headroom,
        and each released segment returns ``round_up(seg, device_page)``
        — comparing raw segment bytes against raw ``need`` can stop the
        ladder one segment short of what the page-rounded grant actually
        requires, leaving the retry to fail (and the second rung to dump
        every cached segment) near capacity."""
        page = self.policy.device_page
        cands = [s for s in self._segments.values()
                 if s.fully_free() and (pool is None or s.pool == pool)]
        cands.sort(key=lambda s: -s.size)
        need_pages = round_up(need, page) if need else 0
        freed = 0
        freed_pages = 0
        for s in cands:
            self._release_segment(s)
            freed += s.size
            freed_pages += round_up(s.size, page)
            if need_pages and freed_pages >= need_pages:
                break
        return freed

    # -- public API ---------------------------------------------------------
    def malloc(self, req: int, t: int = 0) -> int:
        if self.policy.arena:
            return self._arena_malloc(req, t)
        return self.malloc_rounded(self.round_size(req), t)

    def malloc_rounded(self, size: int, t: int = 0) -> int:
        """``malloc`` for an already request-rounded size — the batched
        replay stepper rounds whole event columns with numpy up front and
        enters here, skipping the per-event size policy."""
        pool = self._pool_of(size)
        pool_name = "large" if pool is self._free_large else "small"
        block = pool.best_fit(size)
        if block is not None:
            self.n_cache_hits += 1
            pool.remove(block)
        else:
            seg_size = self.allocation_size(size)
            block = self._new_segment(pool_name, seg_size)
            if block is None:
                # L2 refused: reclaim ladder (paper §3.4(v)).
                self._release_cached(pool_name, seg_size)
                block = self._new_segment(pool_name, seg_size)
            if block is None:
                self._release_cached(None, 0)  # release everything cached
                block = self._new_segment(pool_name, seg_size)
            if block is None:
                raise SimOOMError(seg_size, self.device.reserved,
                                  self.device.capacity)
        if self._should_split(block, size):
            self.n_splits += 1
            rest = _Block(next(self._uid), block.segment,
                          block.offset + size, block.size - size)
            rest.prev, rest.next = block, block.next
            if block.next is not None:
                block.next.prev = rest
            block.next = rest
            block.size = size
            pool.add(rest)
        block.free = False
        block.requested = size
        self._inuse[block.uid] = block
        self.allocated += size
        self.peak_allocated = max(self.peak_allocated, self.allocated)
        seg = self._segments[block.segment]
        seg.live += 1
        if seg.live == 1:
            self.inuse_demand += round_up(seg.size, self.policy.device_page)
            if self.inuse_demand > self.max_inuse_demand:
                self.max_inuse_demand = self.inuse_demand
        self.timeline.append((t, self.allocated, self.reserved))
        return block.uid

    def free(self, handle: int, t: int = 0) -> None:
        if self.policy.arena:
            return self._arena_free(handle, t)
        block = self._inuse.pop(handle)
        self.allocated -= block.requested
        block.free = True
        block.requested = 0
        seg = self._segments[block.segment]
        seg.live -= 1
        if seg.live == 0:
            self.inuse_demand -= round_up(seg.size, self.policy.device_page)
        pool = self._free_small if seg.pool == "small" else self._free_large
        # coalesce with free neighbors (BFC merge)
        for nb_attr in ("prev", "next"):
            nb = getattr(block, nb_attr)
            if nb is not None and nb.free:
                pool.remove(nb)
                self.n_merges += 1
                lo, hi = (nb, block) if nb_attr == "prev" else (block, nb)
                lo.size += hi.size
                lo.next = hi.next
                if hi.next is not None:
                    hi.next.prev = lo
                if nb_attr == "prev":
                    block = lo
                if seg.head is hi:
                    seg.head = lo
        if block.offset == 0:
            seg.head = block
        pool.add(block)
        self.timeline.append((t, self.allocated, self.reserved))

    # -- arena mode (TPU) -----------------------------------------------------
    def _arena_malloc(self, req: int, t: int) -> int:
        size = self.round_size(req)
        live = self.allocated + size
        want = round_up(live, self.policy.device_page)
        if want > self.max_inuse_demand:   # arena demand = rounded live bytes
            self.max_inuse_demand = want
        if want > self.reserved:
            if not self.device.grant(want - self.reserved):
                # compaction is implicit; if live itself exceeds capacity -> OOM
                raise SimOOMError(want - self.reserved, self.device.reserved,
                                  self.device.capacity)
            self.reserved = want
            self.peak_reserved = max(self.peak_reserved, self.reserved)
        uid = next(self._uid)
        blk = _Block(uid, -1, 0, size)
        blk.requested = size
        blk.free = False
        self._inuse[uid] = blk
        self.allocated = live
        self.peak_allocated = max(self.peak_allocated, self.allocated)
        self.timeline.append((t, self.allocated, self.reserved))
        return uid

    def _arena_free(self, handle: int, t: int) -> None:
        blk = self._inuse.pop(handle)
        self.allocated -= blk.requested
        # arena shrinks lazily: reserved stays at high-water (runtime keeps it)
        self.timeline.append((t, self.allocated, self.reserved))

    # -- introspection ---------------------------------------------------------
    def segments_snapshot(self) -> list[dict]:
        out = []
        for s in self._segments.values():
            blocks, b = [], s.head
            while b is not None:
                blocks.append({"offset": b.offset, "size": b.size,
                               "free": b.free})
                b = b.next
            out.append({"sid": s.sid, "pool": s.pool, "size": s.size,
                        "blocks": blocks})
        return out

    def state_fingerprint(self) -> int:
        """Order-independent hash of the allocator's *behavioral* state.

        Two moments with equal fingerprints (and isomorphic live-handle
        patterns, which the Simulator checks separately) respond to
        identical future event streams with identical byte trajectories:
        the hash covers live/reserved byte counts, the doubling-growth
        cursor, and the full segment/block structure (sizes, free flags,
        offsets implied by in-segment order) — everything ``malloc`` and
        ``free`` consult. Segment ids are deliberately excluded; they
        only name segments, they never steer placement.
        """
        if self.policy.arena:
            live = tuple(sorted(b.requested for b in self._inuse.values()))
            return hash(("arena", self.allocated, self.reserved, live))
        segs = []
        for s in self._segments.values():
            blocks = []
            b = s.head
            while b is not None:
                blocks.append((b.size, b.free, b.requested))
                b = b.next
            segs.append((s.pool, s.size, tuple(blocks)))
        segs.sort()
        return hash((self.allocated, self.reserved, self._grow_next,
                     tuple(segs)))

    def stats(self) -> dict:
        return {
            "allocated": self.allocated,
            "reserved": self.reserved,
            "peak_allocated": self.peak_allocated,
            "peak_reserved": self.peak_reserved,
            "device_peak_reserved": self.device.peak_reserved,
            "n_splits": self.n_splits,
            "n_merges": self.n_merges,
            "n_cache_hits": self.n_cache_hits,
            "n_segments": len(self._segments),
            "max_inuse_demand": self.max_inuse_demand,
        }
