"""Analyzer — stage 2 of the xMem pipeline (paper §3.2).

Consumes the raw event stream and produces structured, attributed
``BlockLifecycle`` records:

* pairs alloc/free events into lifecycles (handling address reuse for
  external traces, where an address is recycled after a free);
* attributes each block to the operator / layer scope that produced it.
  For tracer-produced streams attribution is structural (name_stack).
  For *external* traces (JSON event dumps without linkage) we keep the
  paper's time-window containment attribution as a fallback:
  a block belongs to an operator window if its whole lifespan falls
  inside the window, or it is allocated inside the window and persists
  beyond the linked component;
* classifies blocks (param/grad/activation/...) from scope markers —
  e.g. blocks born under a ``transpose(...)`` scope are backward-pass
  artifacts, the JAX analogue of the paper's seq-number fwd→bwd linking;
* aggregates per-layer footprints — the per-layer/operator profile the
  paper identifies as the foundation for distributed planning (§6.2).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Iterable, Sequence

from .events import (TRACE_SCHEMA_VERSION, BlockKind, BlockLifecycle,
                     MemoryEvent, Trace, TraceSchemaError)


def load_trace(path: str) -> Trace:
    """Load a persisted trace dump for analysis.

    Delegates to ``Trace.load`` (which validates ``schema_version`` —
    dumps written by a newer tracer, or with an unknown payload format,
    raise :class:`TraceSchemaError` instead of mis-parsing) and wraps
    non-schema failures in the same error type with the analyzer's
    context attached, so callers get one clear failure mode.
    """
    try:
        return Trace.load(path)
    except TraceSchemaError:
        raise
    except (KeyError, ValueError, TypeError) as e:
        raise TraceSchemaError(
            f"{path}: not a valid xMem trace dump "
            f"(schema <= v{TRACE_SCHEMA_VERSION}): {e}") from e


def reconstruct_lifecycles(trace: Trace) -> list[BlockLifecycle]:
    """Pair alloc/free events into lifecycles (paper: 'reconstructed
    lifecycle entities'). Blocks lacking a free are persistent."""
    open_blocks: dict[int, MemoryEvent] = {}
    out: list[BlockLifecycle] = []
    for e in trace.events:
        if e.kind == "alloc":
            open_blocks[e.block_id] = e
        elif e.kind == "free":
            a = open_blocks.pop(e.block_id, None)
            if a is None:
                continue  # free without alloc: trace started mid-stream
            out.append(BlockLifecycle(
                a.block_id, a.size, a.t, e.t, a.iteration, a.phase,
                a.op, a.scope, a.block_kind, 1.0, a.shape, a.space))
    for a in open_blocks.values():  # persistent (no free observed)
        out.append(BlockLifecycle(
            a.block_id, a.size, a.t, None, a.iteration, a.phase,
            a.op, a.scope, a.block_kind, 1.0, a.shape, a.space))
    out.sort(key=lambda b: b.alloc_t)
    return out


def reconstruct_from_address_events(
        events: Sequence[dict]) -> list[BlockLifecycle]:
    """External-trace path: events carry ``addr`` (reused over time) rather
    than unique block ids — the exact problem the paper's Analyzer solves.
    Pairs by address while an address is live; reuse after free opens a
    new lifecycle."""
    live_addr: dict[int, tuple[int, dict]] = {}
    out: list[BlockLifecycle] = []
    next_id = 0
    for t, e in enumerate(sorted(events, key=lambda d: d["t"])):
        if e["kind"] == "alloc":
            live_addr[e["addr"]] = (next_id, {**e, "t": t})
            next_id += 1
        else:
            got = live_addr.pop(e["addr"], None)
            if got is None:
                continue
            bid, a = got
            out.append(BlockLifecycle(
                bid, a["size"], a["t"], t, a.get("iteration", 0),
                scope=a.get("scope", ""), op=a.get("op", "")))
    for bid, a in live_addr.values():
        out.append(BlockLifecycle(
            bid, a["size"], a["t"], None, a.get("iteration", 0),
            scope=a.get("scope", ""), op=a.get("op", "")))
    out.sort(key=lambda b: b.alloc_t)
    return out


@dataclasses.dataclass
class OpWindow:
    """An operator/component execution window for time-based attribution."""
    name: str
    start: int
    end: int
    component_end: int | None = None  # end of the linked high-level component


def attribute_by_time_window(blocks: Iterable[BlockLifecycle],
                             windows: Sequence[OpWindow]) -> list[BlockLifecycle]:
    """Paper §3.2 attribution fallback for traces without structural scopes.

    A block is attributed to window W if (i) its whole lifespan falls in W,
    or (ii) it is allocated in W and persists beyond W's linked component.
    Unattributed temporary blocks (allocated by higher-level script, not in
    any operator) are dropped — 'presumed less relevant for the target'.
    """
    ws = sorted(windows, key=lambda w: (w.start, -(w.end - w.start)))
    out = []
    for b in blocks:
        if b.scope:          # structural attribution already present
            out.append(b)
            continue
        owner = None
        for w in ws:
            if w.start <= b.alloc_t < w.end:
                end = b.free_t if b.free_t is not None else float("inf")
                comp_end = w.component_end if w.component_end is not None else w.end
                if end <= w.end or end > comp_end:
                    owner = w
                    # prefer the tightest (latest-starting) enclosing window
        if owner is not None:
            out.append(dataclasses.replace(b, scope=owner.name))
    return out


_BWD_MARKERS = ("transpose", "backward")

#: scope -> is-backward verdict memo; scope strings repeat heavily across
#: blocks (and are interned by the tracer), so the substring scans run
#: once per distinct scope instead of once per block
_BWD_SCOPE_MEMO: dict[str, bool] = {}


def _is_bwd_scope(scope: str) -> bool:
    v = _BWD_SCOPE_MEMO.get(scope)
    if v is None:
        v = _BWD_SCOPE_MEMO[scope] = any(m in scope for m in _BWD_MARKERS)
        if len(_BWD_SCOPE_MEMO) > 1 << 16:   # unbounded-growth guard
            _BWD_SCOPE_MEMO.clear()
    return v


def classify_blocks(blocks: Iterable[BlockLifecycle],
                    param_like_sizes: frozenset[int] = frozenset()
                    ) -> list[BlockLifecycle]:
    """Refine BlockKind using structural scope markers.

    * blocks born under a transpose scope are backward artifacts; those
      whose size matches a parameter are gradient candidates (the paper
      filters optimizer-state candidates by parameter-size match, §3.3(5));
    * everything else inside fwd/bwd keeps ACTIVATION.
    """
    out = []
    append = out.append
    _act, _tmp, _grad = BlockKind.ACTIVATION, BlockKind.TEMP, BlockKind.GRAD
    for b in blocks:
        bk = b.block_kind
        if ((bk is _act or bk is _tmp)
                and b.size in param_like_sizes
                and _is_bwd_scope(b.scope)):
            b = dataclasses.replace(b, block_kind=_grad)
        append(b)
    return out


def layer_report(blocks: Iterable[BlockLifecycle], depth: int = 2) -> dict:
    """Per-layer byte aggregation: {scope_prefix: {kind: bytes}}.

    This is the granular profile the paper names as the prerequisite for
    model/pipeline-parallel planning (§6.2); the distributed estimator and
    the sharding engine consume it.
    """
    rep: dict[str, dict[str, int]] = defaultdict(lambda: defaultdict(int))
    for b in blocks:
        prefix = "/".join(b.scope.split("/")[:depth]) if b.scope else "<root>"
        rep[prefix][b.block_kind.value] += b.size
        rep[prefix]["count"] += 1
    return {k: dict(v) for k, v in rep.items()}


def phase_peaks(blocks: Sequence[BlockLifecycle]) -> dict:
    """Peak live bytes per phase — quick structural summary."""
    from .events import peak_live_bytes
    by_phase: dict[str, list[BlockLifecycle]] = defaultdict(list)
    for b in blocks:
        by_phase[b.phase.value].append(b)
    return {ph: peak_live_bytes(bs) for ph, bs in by_phase.items()}
