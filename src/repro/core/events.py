"""Memory-event and lifecycle data structures for the xMem pipeline.

These mirror the entities in the paper (§2.2, §3.2):

* ``MemoryEvent`` — one allocation or deallocation, in *execution order*.
  The paper reconstructs these from PyTorch-profiler ``cpu_instant_event``
  rows; we emit them directly from the jaxpr interpreter (``tracer.py``)
  or reconstruct them from an external JSON trace (``analyzer.py``).
* ``BlockLifecycle`` — a reconstructed memory block: size + alloc/free
  position + attribution to the operator / layer scope that produced it.
  "Memory block" throughout this codebase refers to these entities,
  exactly as in the paper.
* ``Trace`` — an ordered event stream plus metadata (iteration boundaries,
  phases), the unit of data handed between pipeline stages.
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Iterable, Sequence

import numpy as np

#: On-disk trace schema. v1 = seed format (no version field, object event
#: list). v2 adds the version field, columnar payloads and phase/iteration
#: metadata for columnar traces. v3 adds per-block shape metadata (the
#: spec-driven per-device estimation input); v2 dumps load with shapes
#: unknown. v4 adds the memory-space column (host-offload semantics);
#: v3 dumps load with every event in DEVICE_HBM. v5 marks the
#: request-driven composition era (``ComposedBlocks`` workloads: periodic
#: training iterations AND continuous-batching request timelines compile
#: to the same replay currency); per-event payloads are unchanged, so v4
#: and v3 dumps load bit-identically. Loaders accept <= current, reject
#: newer.
TRACE_SCHEMA_VERSION = 5


class MemorySpace(enum.Enum):
    """Which physical memory a block resides in (multi-space model).

    DEVICE_HBM is the accelerator memory every pre-v4 trace implicitly
    assumed; the host spaces exist for offload semantics (optimizer
    state / activations parked on the host between uses, staged back
    via ``fetch_in`` transfer blocks). HOST_PINNED is page-locked
    memory (DMA-able, the space real offload implementations use);
    HOST_PAGEABLE models plain malloc-backed host memory.
    """

    DEVICE_HBM = "device_hbm"
    HOST_PINNED = "host_pinned"
    HOST_PAGEABLE = "host_pageable"


class BlockKind(enum.Enum):
    """Semantic class of a memory block (drives Orchestrator policy)."""

    PARAM = "param"
    GRAD = "grad"
    OPT_STATE = "opt_state"
    ACTIVATION = "activation"
    INPUT = "input"           # batch data
    OUTPUT = "output"         # step outputs (loss, metrics, new params)
    TEMP = "temp"             # operator-internal scratch
    COLLECTIVE = "collective"  # injected communication buffers (distributed)
    CACHE = "cache"           # KV / recurrent state (serving)


class Phase(enum.Enum):
    """Training-loop phase an event belongs to (paper: user_annotation)."""

    INIT = "init"                 # model/optimizer materialization
    FORWARD_BACKWARD = "fwd_bwd"  # loss + gradient computation
    OPTIMIZER = "optimizer"       # parameter/optimizer-state update
    DECODE = "decode"             # serving decode step
    DATA = "data"                 # host->device batch transfer


# Stable enum <-> small-int code tables for the columnar representation.
# Order is append-only: new members must be added at the end so codes in
# saved columnar dumps stay valid across versions.
PHASE_TABLE: tuple[Phase, ...] = tuple(Phase)
PHASE_CODE: dict[Phase, int] = {p: i for i, p in enumerate(PHASE_TABLE)}
KIND_TABLE: tuple[BlockKind, ...] = tuple(BlockKind)
KIND_CODE: dict[BlockKind, int] = {k: i for i, k in enumerate(KIND_TABLE)}
SPACE_TABLE: tuple[MemorySpace, ...] = tuple(MemorySpace)
SPACE_CODE: dict[MemorySpace, int] = {s: i for i, s in
                                      enumerate(SPACE_TABLE)}
#: Code 0 == DEVICE_HBM by construction — a missing v3 space column
#: loads as ``zeros`` and means "everything on device", bit-identically.
assert SPACE_TABLE[0] is MemorySpace.DEVICE_HBM


class StringInterner:
    """Append-only value table: intern() -> small int, table[i] -> value.

    Works for any hashable value — strings (op/scope tables) and shape
    tuples / ``None`` (shape tables) share the implementation."""

    __slots__ = ("table", "_index")

    def __init__(self, table: Sequence = ()):
        self.table: list = list(table)
        self._index: dict = {s: i for i, s in enumerate(self.table)}

    def intern(self, s) -> int:
        i = self._index.get(s)
        if i is None:
            i = self._index[s] = len(self.table)
            self.table.append(s)
        return i


def _shape_table_to_json(table: Sequence) -> list:
    return [None if s is None else list(s) for s in table]


def _shape_table_from_json(table: Sequence | None) -> list:
    if table is None:          # v2 dump: shapes unknown
        return [None]
    return [None if s is None else tuple(int(d) for d in s) for s in table]


@dataclasses.dataclass(slots=True)
class MemoryEvent:
    """One alloc/free in execution order.

    ``t`` is the event's position in the stream (a logical clock — the
    paper uses wall-clock CPU timestamps; execution order is what matters
    for the Simulator, so a logical clock loses nothing).
    """

    kind: str              # "alloc" | "free"
    block_id: int
    size: int              # bytes (pre-rounding; the allocator sim rounds)
    t: int
    iteration: int = 0
    phase: Phase = Phase.FORWARD_BACKWARD
    op: str = ""           # primitive name, e.g. "dot_general"
    scope: str = ""        # layer scope, e.g. "decoder/layers/attn/q_proj"
    block_kind: BlockKind = BlockKind.TEMP
    shape: tuple | None = None   # aval dims (spec-driven sharding input)
    space: MemorySpace = MemorySpace.DEVICE_HBM

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["phase"] = self.phase.value
        d["block_kind"] = self.block_kind.value
        d["shape"] = None if self.shape is None else list(self.shape)
        d["space"] = self.space.value
        return d

    @staticmethod
    def from_json(d: dict) -> "MemoryEvent":
        d = dict(d)
        d["phase"] = Phase(d["phase"])
        d["block_kind"] = BlockKind(d["block_kind"])
        shape = d.get("shape")   # absent in v1/v2 dumps
        d["shape"] = None if shape is None else tuple(shape)
        # absent in v1-v3 dumps: everything lived on device
        d["space"] = MemorySpace(d.get("space", "device_hbm"))
        return MemoryEvent(**d)


@dataclasses.dataclass(slots=True)
class BlockLifecycle:
    """A reconstructed memory block (paper §3.2).

    ``free_t is None`` → persistent for the rest of the trace (paper:
    "blocks lacking a deallocation event are considered persistent").
    ``shard_factor`` divides the size for per-device estimation in the
    distributed extension (paper §6.2); 1 on a single device. ``shape``
    carries the producing aval's dims so the spec-driven sharding engine
    can resolve a true PartitionSpec factor; ``None`` = unknown (external
    traces, synthetic blocks) and resolves to replicated.
    """

    block_id: int
    size: int
    alloc_t: int
    free_t: int | None
    iteration: int = 0
    phase: Phase = Phase.FORWARD_BACKWARD
    op: str = ""
    scope: str = ""
    block_kind: BlockKind = BlockKind.TEMP
    shard_factor: float = 1.0
    shape: tuple | None = None
    space: MemorySpace = MemorySpace.DEVICE_HBM

    @property
    def persistent(self) -> bool:
        return self.free_t is None

    @property
    def sharded_size(self) -> int:
        return max(int(self.size / self.shard_factor), 1) if self.size else 0

    def overlaps(self, t: int) -> bool:
        end = self.free_t if self.free_t is not None else float("inf")
        return self.alloc_t <= t < end


# -- columnar (structure-of-arrays) representations -------------------------
@dataclasses.dataclass
class ColumnarTrace:
    """Event stream as parallel numpy columns (the hot-path format).

    One row per event; ``kind`` is 1 for alloc / 0 for free, ``phase`` and
    ``block_kind`` are codes into :data:`PHASE_TABLE` / :data:`KIND_TABLE`,
    ``op``/``scope`` index the interned string tables and ``shape`` the
    interned shape-tuple table (entry ``None`` = unknown). Conversion to
    and from ``MemoryEvent`` lists is lossless (``test_columnar.py``).
    """

    kind: np.ndarray          # uint8: 1 = alloc, 0 = free
    block_id: np.ndarray      # int64
    size: np.ndarray          # int64, bytes (pre-rounding)
    t: np.ndarray             # int64 logical clock
    iteration: np.ndarray     # int64
    phase: np.ndarray         # uint8 codes -> PHASE_TABLE
    op: np.ndarray            # int32 -> op_table
    scope: np.ndarray         # int32 -> scope_table
    block_kind: np.ndarray    # uint8 codes -> KIND_TABLE
    op_table: list[str]
    scope_table: list[str]
    shape: np.ndarray | None = None     # int32 -> shape_table
    shape_table: list = dataclasses.field(default_factory=lambda: [None])
    space: np.ndarray | None = None     # uint8 codes -> SPACE_TABLE

    def __post_init__(self):
        if self.shape is None:
            self.shape = np.zeros(len(self.kind), dtype=np.int32)
        if self.space is None:   # pre-v4 trace: everything on device
            self.space = np.zeros(len(self.kind), dtype=np.uint8)

    def __len__(self) -> int:
        return int(self.kind.shape[0])

    @staticmethod
    def from_events(events: Sequence[MemoryEvent]) -> "ColumnarTrace":
        n = len(events)
        kind = np.empty(n, dtype=np.uint8)
        bid = np.empty(n, dtype=np.int64)
        size = np.empty(n, dtype=np.int64)
        t = np.empty(n, dtype=np.int64)
        it = np.empty(n, dtype=np.int64)
        phase = np.empty(n, dtype=np.uint8)
        op = np.empty(n, dtype=np.int32)
        scope = np.empty(n, dtype=np.int32)
        bkind = np.empty(n, dtype=np.uint8)
        shp = np.empty(n, dtype=np.int32)
        spc = np.empty(n, dtype=np.uint8)
        ops = StringInterner()
        scopes = StringInterner()
        shapes = StringInterner([None])
        for i, e in enumerate(events):
            kind[i] = 1 if e.kind == "alloc" else 0
            bid[i] = e.block_id
            size[i] = e.size
            t[i] = e.t
            it[i] = e.iteration
            phase[i] = PHASE_CODE[e.phase]
            op[i] = ops.intern(e.op)
            scope[i] = scopes.intern(e.scope)
            bkind[i] = KIND_CODE[e.block_kind]
            shp[i] = shapes.intern(e.shape)
            spc[i] = SPACE_CODE[e.space]
        return ColumnarTrace(kind, bid, size, t, it, phase, op, scope,
                             bkind, ops.table, scopes.table,
                             shp, shapes.table, spc)

    @staticmethod
    def from_columns(kind, bid, size, t, iteration, phase, op, scope,
                     bkind, op_table, scope_table,
                     shape=None, shape_table=None,
                     space=None) -> "ColumnarTrace":
        """Build from raw python lists (the tracer's direct-emission path:
        no ``MemoryEvent`` objects are ever constructed)."""
        return ColumnarTrace(
            np.asarray(kind, dtype=np.uint8),
            np.asarray(bid, dtype=np.int64),
            np.asarray(size, dtype=np.int64),
            np.asarray(t, dtype=np.int64),
            np.asarray(iteration, dtype=np.int64),
            np.asarray(phase, dtype=np.uint8),
            np.asarray(op, dtype=np.int32),
            np.asarray(scope, dtype=np.int32),
            np.asarray(bkind, dtype=np.uint8),
            list(op_table), list(scope_table),
            None if shape is None else np.asarray(shape, dtype=np.int32),
            [None] if shape_table is None else list(shape_table),
            None if space is None else np.asarray(space, dtype=np.uint8))

    def event_at(self, i: int) -> MemoryEvent:
        return MemoryEvent(
            "alloc" if self.kind[i] else "free", int(self.block_id[i]),
            int(self.size[i]), int(self.t[i]), int(self.iteration[i]),
            PHASE_TABLE[self.phase[i]], self.op_table[self.op[i]],
            self.scope_table[self.scope[i]], KIND_TABLE[self.block_kind[i]],
            self.shape_table[self.shape[i]], SPACE_TABLE[self.space[i]])

    def to_events(self) -> list[MemoryEvent]:
        return [self.event_at(i) for i in range(len(self))]

    def with_sizes(self, sizes: np.ndarray) -> "ColumnarTrace":
        """Same structure, new size column (sweep-point synthesis)."""
        return dataclasses.replace(
            self, size=np.asarray(sizes, dtype=np.int64))

    def to_json(self) -> dict:
        return {
            "kind": self.kind.tolist(),
            "block_id": self.block_id.tolist(),
            "size": self.size.tolist(),
            "t": self.t.tolist(),
            "iteration": self.iteration.tolist(),
            "phase": self.phase.tolist(),
            "op": self.op.tolist(),
            "scope": self.scope.tolist(),
            "block_kind": self.block_kind.tolist(),
            "op_table": self.op_table,
            "scope_table": self.scope_table,
            "shape": self.shape.tolist(),
            "shape_table": _shape_table_to_json(self.shape_table),
            "space": self.space.tolist(),
        }

    @staticmethod
    def from_json(d: dict) -> "ColumnarTrace":
        return ColumnarTrace.from_columns(
            d["kind"], d["block_id"], d["size"], d["t"], d["iteration"],
            d["phase"], d["op"], d["scope"], d["block_kind"],
            d["op_table"], d["scope_table"],
            d.get("shape"),                    # absent in v2 dumps
            _shape_table_from_json(d.get("shape_table")),
            d.get("space"))                    # absent in v2/v3 dumps


class LazyEvents(Sequence):
    """List-compatible view over a ``ColumnarTrace`` that materializes
    ``MemoryEvent`` objects only on first element access. ``len()`` (the
    dominant consumer on the fast path) never materializes."""

    def __init__(self, columns: ColumnarTrace):
        self.columns = columns
        self._mat: list[MemoryEvent] | None = None

    def _materialized(self) -> list[MemoryEvent]:
        if self._mat is None:
            self._mat = self.columns.to_events()
        return self._mat

    def __len__(self) -> int:
        return len(self.columns)

    def __getitem__(self, i):
        return self._materialized()[i]

    def __iter__(self):
        return iter(self._materialized())

    def __reduce__(self):
        # pickle only the columns (pool payloads stay lean); the
        # materialized object cache rebuilds on demand
        return (LazyEvents, (self.columns,))


@dataclasses.dataclass
class ColumnarBlocks:
    """Lifecycles as parallel numpy columns. ``free_t`` uses -1 as the
    persistent sentinel (``BlockLifecycle.free_t is None``)."""

    block_id: np.ndarray      # int64
    size: np.ndarray          # int64
    alloc_t: np.ndarray       # int64
    free_t: np.ndarray        # int64, -1 = persistent
    iteration: np.ndarray     # int64
    phase: np.ndarray         # uint8 codes
    op: np.ndarray            # int32 -> op_table
    scope: np.ndarray         # int32 -> scope_table
    block_kind: np.ndarray    # uint8 codes
    shard_factor: np.ndarray  # float64
    op_table: list[str]
    scope_table: list[str]
    shape: np.ndarray | None = None     # int32 -> shape_table
    shape_table: list = dataclasses.field(default_factory=lambda: [None])
    space: np.ndarray | None = None     # uint8 codes -> SPACE_TABLE

    def __post_init__(self):
        if self.shape is None:
            self.shape = np.zeros(len(self.block_id), dtype=np.int32)
        if self.space is None:   # pre-v4 payload: everything on device
            self.space = np.zeros(len(self.block_id), dtype=np.uint8)

    def __len__(self) -> int:
        return int(self.block_id.shape[0])

    @staticmethod
    def from_lifecycles(blocks: Sequence[BlockLifecycle]) -> "ColumnarBlocks":
        n = len(blocks)
        bid = np.empty(n, dtype=np.int64)
        size = np.empty(n, dtype=np.int64)
        at = np.empty(n, dtype=np.int64)
        ft = np.empty(n, dtype=np.int64)
        it = np.empty(n, dtype=np.int64)
        phase = np.empty(n, dtype=np.uint8)
        op = np.empty(n, dtype=np.int32)
        scope = np.empty(n, dtype=np.int32)
        bkind = np.empty(n, dtype=np.uint8)
        shard = np.empty(n, dtype=np.float64)
        shp = np.empty(n, dtype=np.int32)
        spc = np.empty(n, dtype=np.uint8)
        ops = StringInterner()
        scopes = StringInterner()
        shapes = StringInterner([None])
        for i, b in enumerate(blocks):
            bid[i] = b.block_id
            size[i] = b.size
            at[i] = b.alloc_t
            ft[i] = -1 if b.free_t is None else b.free_t
            it[i] = b.iteration
            phase[i] = PHASE_CODE[b.phase]
            op[i] = ops.intern(b.op)
            scope[i] = scopes.intern(b.scope)
            bkind[i] = KIND_CODE[b.block_kind]
            shard[i] = b.shard_factor
            shp[i] = shapes.intern(b.shape)
            spc[i] = SPACE_CODE[b.space]
        return ColumnarBlocks(bid, size, at, ft, it, phase, op, scope,
                              bkind, shard, ops.table, scopes.table,
                              shp, shapes.table, spc)

    def to_lifecycles(self) -> list[BlockLifecycle]:
        ft = self.free_t
        return [BlockLifecycle(
            int(self.block_id[i]), int(self.size[i]), int(self.alloc_t[i]),
            None if ft[i] < 0 else int(ft[i]), int(self.iteration[i]),
            PHASE_TABLE[self.phase[i]], self.op_table[self.op[i]],
            self.scope_table[self.scope[i]], KIND_TABLE[self.block_kind[i]],
            float(self.shard_factor[i]),
            self.shape_table[self.shape[i]],
            SPACE_TABLE[self.space[i]]) for i in range(len(self))]

    def sharded_sizes(self) -> np.ndarray:
        return sharded_sizes_array(self.size, self.shard_factor)

    def with_sizes(self, sizes: np.ndarray) -> "ColumnarBlocks":
        return dataclasses.replace(
            self, size=np.asarray(sizes, dtype=np.int64))

    def to_json(self) -> dict:
        """Schema-v4 columnar payload (shape + space columns included)
        — the persistent trace store's lifecycle format."""
        return {
            "block_id": self.block_id.tolist(),
            "size": self.size.tolist(),
            "alloc_t": self.alloc_t.tolist(),
            "free_t": self.free_t.tolist(),
            "iteration": self.iteration.tolist(),
            "phase": self.phase.tolist(),
            "op": self.op.tolist(),
            "scope": self.scope.tolist(),
            "block_kind": self.block_kind.tolist(),
            "shard_factor": self.shard_factor.tolist(),
            "op_table": self.op_table,
            "scope_table": self.scope_table,
            "shape": self.shape.tolist(),
            "shape_table": _shape_table_to_json(self.shape_table),
            "space": self.space.tolist(),
        }

    @staticmethod
    def from_json(d: dict) -> "ColumnarBlocks":
        space = d.get("space")                 # absent in v3 payloads
        return ColumnarBlocks(
            np.asarray(d["block_id"], dtype=np.int64),
            np.asarray(d["size"], dtype=np.int64),
            np.asarray(d["alloc_t"], dtype=np.int64),
            np.asarray(d["free_t"], dtype=np.int64),
            np.asarray(d["iteration"], dtype=np.int64),
            np.asarray(d["phase"], dtype=np.uint8),
            np.asarray(d["op"], dtype=np.int32),
            np.asarray(d["scope"], dtype=np.int32),
            np.asarray(d["block_kind"], dtype=np.uint8),
            np.asarray(d["shard_factor"], dtype=np.float64),
            list(d["op_table"]), list(d["scope_table"]),
            np.asarray(d["shape"], dtype=np.int32),
            _shape_table_from_json(d.get("shape_table")),
            None if space is None else np.asarray(space, dtype=np.uint8))


def sharded_sizes_array(size: np.ndarray, shard: np.ndarray) -> np.ndarray:
    """Vectorized ``BlockLifecycle.sharded_size`` — the one place the
    truncation semantics live for array code (exact: float division
    truncated toward zero, floor of 1, zero-size blocks stay 0)."""
    out = np.where(shard == 1.0, size,
                   np.maximum((size / shard).astype(np.int64), 1))
    return np.where(size == 0, 0, out).astype(np.int64)


class TraceSchemaError(ValueError):
    """A persisted trace file is incompatible with this code version."""


@dataclasses.dataclass
class Trace:
    """Ordered event stream + metadata — the inter-stage currency.

    ``events`` may be a plain list or a :class:`LazyEvents` view over a
    ``ColumnarTrace`` (hot-path traces are columnar-backed; objects
    materialize only if a consumer iterates). ``columnar()`` returns the
    SoA form, building and caching it on first use for object-backed
    traces. Mutating ``events`` after ``columnar()`` has been called is
    a contract violation (the two views would diverge).
    """

    events: list[MemoryEvent]
    num_iterations: int = 1
    meta: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.events)

    def columnar(self) -> ColumnarTrace:
        if isinstance(self.events, LazyEvents):
            return self.events.columns
        cols = self.meta.get("_columns")
        if cols is None:
            cols = ColumnarTrace.from_events(self.events)
            self.meta["_columns"] = cols
        return cols

    @staticmethod
    def from_columnar(columns: ColumnarTrace, num_iterations: int = 1,
                      meta: dict | None = None) -> "Trace":
        return Trace(LazyEvents(columns), num_iterations, meta or {})

    def iteration_slice(self, it: int) -> list[MemoryEvent]:
        return [e for e in self.events if e.iteration == it]

    def save(self, path: str, columnar: bool = False) -> None:
        """Persist as versioned JSON. ``columnar=True`` writes the SoA
        payload (phase/iteration carried as full per-event columns plus
        the trace-level metadata, so nothing is lost round-tripping)."""
        meta = {k: v for k, v in self.meta.items() if k != "_columns"}
        d: dict = {
            "schema_version": TRACE_SCHEMA_VERSION,
            "num_iterations": self.num_iterations,
            "meta": meta,
        }
        if columnar:
            d["format"] = "columnar"
            d["columns"] = self.columnar().to_json()
        else:
            d["format"] = "events"
            d["events"] = [e.to_json() for e in self.events]
        with open(path, "w") as f:
            json.dump(d, f)

    @staticmethod
    def load(path: str) -> "Trace":
        with open(path) as f:
            d = json.load(f)
        version = d.get("schema_version", 1)   # v1: seed dumps, no field
        if not isinstance(version, int) or version < 1 \
                or version > TRACE_SCHEMA_VERSION:
            raise TraceSchemaError(
                f"{path}: trace schema version {version!r} is not supported "
                f"by this build (max {TRACE_SCHEMA_VERSION}); re-dump the "
                f"trace with a matching version of the tracer")
        fmt = d.get("format", "events")
        if fmt == "columnar":
            return Trace.from_columnar(
                ColumnarTrace.from_json(d["columns"]),
                num_iterations=d["num_iterations"], meta=d.get("meta", {}))
        if fmt != "events" or "events" not in d:
            raise TraceSchemaError(
                f"{path}: unknown trace payload format {fmt!r}")
        return Trace(
            events=[MemoryEvent.from_json(e) for e in d["events"]],
            num_iterations=d["num_iterations"],
            meta=d.get("meta", {}),
        )


def lifecycles_to_events(blocks: Sequence[BlockLifecycle]) -> list[MemoryEvent]:
    """Expand lifecycles back into an ordered alloc/free event stream.

    Free events at the same logical time sort *before* alloc events — a
    block freed at t must be reusable by a block allocated at t (this is
    the paper's Fig-3 sensitivity: dealloc/alloc interleaving decides the
    peak; ties resolve in favor of reuse, matching allocator behavior
    where the framework frees an input before allocating the output of
    the next op at the same trace position).
    """
    evs: list[tuple[int, int, MemoryEvent]] = []
    horizon = 0
    for b in blocks:
        horizon = max(horizon, b.alloc_t + 1, (b.free_t or 0) + 1)
    for b in blocks:
        evs.append(
            (b.alloc_t, 1, MemoryEvent(
                "alloc", b.block_id, b.sharded_size, b.alloc_t, b.iteration,
                b.phase, b.op, b.scope, b.block_kind, b.shape, b.space))
        )
        if b.free_t is not None:
            evs.append(
                (b.free_t, 0, MemoryEvent(
                    "free", b.block_id, b.sharded_size, b.free_t, b.iteration,
                    b.phase, b.op, b.scope, b.block_kind, b.shape, b.space))
            )
    evs.sort(key=lambda x: (x[0], x[1]))
    return [e for _, _, e in evs]


# -- composed workloads (estimation fast path) ------------------------------
class ComposedBlocks:
    """Base class for composed allocation workloads.

    A composed workload is anything that compiles down to a flat
    :class:`BlockLifecycle` list — the replay currency both simulator
    engines consume. Two specializations exist:

    * :class:`PeriodicBlocks` — N training iterations in O(blocks)
      space (prefix / replicated cycle / suffix). The simulator keeps
      its dedicated fast paths (steady-state skipping, tiled columnar
      expansion) for this shape, so the training pipeline is
      byte-identical to the pre-``ComposedBlocks`` engine.
    * :class:`RequestBlocks` — a request-driven allocation stream
      (continuous-batching serving timeline: per-request join/leave,
      paged KV blocks, prefix-shared pages, speculative scratch). No
      periodic structure to exploit; replays through the ordinary flat
      paths of both engines.

    Subclasses provide ``materialize()``, ``num_blocks``,
    ``iter_groups()`` and a ``meta`` dict.
    """

    meta: dict

    @property
    def num_blocks(self) -> int:  # pragma: no cover — abstract
        raise NotImplementedError

    def materialize(self) -> list:  # pragma: no cover — abstract
        raise NotImplementedError

    def iter_groups(self):  # pragma: no cover — abstract
        raise NotImplementedError


@dataclasses.dataclass
class RequestBlocks(ComposedBlocks):
    """Flat request-driven allocation stream (serving workloads).

    Produced by the continuous-batching scheduler
    (``core.orchestrator.ContinuousBatchingScheduler.lower``): one
    lifecycle per KV page / scratch / per-request state block, at the
    exact tick it joins and leaves. ``meta`` carries the timeline
    accounting (ticks, occupancy, evictions, knobs).
    """

    blocks: list
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def num_blocks(self) -> int:
        return len(self.blocks)

    def materialize(self) -> list:
        return list(self.blocks)

    def iter_groups(self):
        yield from self.blocks


# -- periodic composition (estimation fast path) ----------------------------
#: Block-id namespace stride for replicated cycle instances. Instance k of
#: a PeriodicBlocks cycle re-ids block ``b`` as ``b + (k + 1) * STRIDE`` so
#: replicas never collide with prefix/suffix ids (small positive ints) or
#: synthetic orchestrator ids (small negative ints).
CYCLE_ID_STRIDE = 1 << 40


def shift_cycle_bid(bid: int, instance: int) -> int:
    return bid + (instance + 1) * CYCLE_ID_STRIDE


def split_cycle_bid(bid: int) -> tuple[int, int]:
    """Inverse of ``shift_cycle_bid``: (instance, raw_id). Instance is -1
    for prefix/suffix ids (small magnitudes, including the orchestrator's
    negative synthetic ids), which never carry a stride offset."""
    inst_plus1 = (bid + (CYCLE_ID_STRIDE >> 1)) // CYCLE_ID_STRIDE
    return inst_plus1 - 1, bid - inst_plus1 * CYCLE_ID_STRIDE


@dataclasses.dataclass
class PeriodicBlocks(ComposedBlocks):
    """N-iteration composition in O(blocks) space (fast path, ISSUE 1).

    ``prefix`` holds iteration 0 (params + optimizer-init included),
    ``cycle`` holds iteration 1 at its absolute times, replicated
    implicitly ``n_cycles`` times with a constant ``period`` offset
    (iterations 1..N-2), and ``suffix`` holds the final iteration at its
    true absolute times. The last iteration is kept concrete because
    grad-release policies treat it differently (no next iteration to
    free into); every middle iteration is an exact shifted copy of
    iteration 1 by construction, which is what makes steady-state replay
    and the periodic peak computations below *exact*, not approximate.
    """

    prefix: list[BlockLifecycle]
    cycle: list[BlockLifecycle]
    n_cycles: int                 # replica count of ``cycle`` (>= 0)
    period: int
    suffix: list[BlockLifecycle]
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def num_blocks(self) -> int:
        return (len(self.prefix) + self.n_cycles * len(self.cycle)
                + len(self.suffix))

    def materialize(self) -> list[BlockLifecycle]:
        """Expand to the flat lifecycle list the slow path would build."""
        out = list(self.prefix)
        for k in range(self.n_cycles):
            dt = k * self.period
            for b in self.cycle:
                out.append(BlockLifecycle(
                    shift_cycle_bid(b.block_id, k), b.size, b.alloc_t + dt,
                    None if b.free_t is None else b.free_t + dt,
                    b.iteration + k, b.phase, b.op, b.scope, b.block_kind,
                    b.shard_factor, b.shape, b.space))
        out.extend(self.suffix)
        return out

    def iter_groups(self):
        yield from self.prefix
        yield from self.cycle
        yield from self.suffix


def reduced_for_breakdown(pb: PeriodicBlocks,
                          max_cycles: int = 4) -> PeriodicBlocks:
    """Shrink a periodic composition to a bounded replica count without
    changing any liveness maximum (total or per-phase).

    Valid when every cycle block is freed (zero net bytes per replica) —
    then every middle window's liveness profile is an exact copy with an
    identical entering level, so deleting repeated windows preserves all
    peaks. The suffix (and nothing else) is shifted left to follow the
    kept replicas. Falls back to the original composition when a cycle
    block persists (per-replica drift) or when prefix events reach past
    the kept windows."""
    if pb.n_cycles <= max_cycles or max_cycles < 2:
        return pb
    if any(b.free_t is None for b in pb.cycle):
        return pb
    cycle_start = pb.meta.get("cycle_start")
    if cycle_start is None:
        return pb
    horizon = cycle_start + 2 * pb.period
    for b in pb.prefix:
        if b.alloc_t >= horizon or (b.free_t is not None
                                    and b.free_t > horizon):
            return pb
    dt = (pb.n_cycles - max_cycles) * pb.period
    suffix = [dataclasses.replace(
        b, alloc_t=b.alloc_t - dt,
        free_t=None if b.free_t is None else b.free_t - dt)
        for b in pb.suffix]
    return PeriodicBlocks(pb.prefix, pb.cycle, max_cycles, pb.period,
                          suffix, meta=pb.meta)


def periodic_peak_live(pb: PeriodicBlocks, pred=None) -> int:
    """Exact peak of live bytes over the full expansion, computed with
    integer deltas only (no lifecycle copies)."""
    deltas: dict[int, int] = {}

    def add(b: BlockLifecycle, dt: int) -> None:
        if pred is not None and not pred(b):
            return
        s = b.sharded_size
        deltas[b.alloc_t + dt] = deltas.get(b.alloc_t + dt, 0) + s
        if b.free_t is not None:
            deltas[b.free_t + dt] = deltas.get(b.free_t + dt, 0) - s

    for b in pb.prefix:
        add(b, 0)
    for k in range(pb.n_cycles):
        dt = k * pb.period
        for b in pb.cycle:
            add(b, dt)
    for b in pb.suffix:
        add(b, 0)
    peak, live = 0, 0
    for t in sorted(deltas):
        live += deltas[t]
        peak = max(peak, live)
    return peak


def periodic_phase_peaks(pb: PeriodicBlocks) -> dict:
    """Per-phase peak live bytes over the full expansion (exact)."""
    return periodic_breakdown_peaks(pb)[1]


def periodic_breakdown_peaks(pb: PeriodicBlocks) -> tuple[int, dict]:
    """(total peak live, per-phase peaks) in a single delta pass — the
    estimator's breakdown without lifecycle copies."""
    total: dict[int, int] = {}
    per: dict = {}

    def add(b: BlockLifecycle, dt: int) -> None:
        s = b.sharded_size
        at = b.alloc_t + dt
        d = per.get(b.phase)
        if d is None:
            d = per[b.phase] = {}
        total[at] = total.get(at, 0) + s
        d[at] = d.get(at, 0) + s
        ft = b.free_t
        if ft is not None:
            ft += dt
            total[ft] = total.get(ft, 0) - s
            d[ft] = d.get(ft, 0) - s

    for b in pb.prefix:
        add(b, 0)
    for k in range(pb.n_cycles):
        dt = k * pb.period
        for b in pb.cycle:
            add(b, dt)
    for b in pb.suffix:
        add(b, 0)

    def sweep(deltas: dict[int, int]) -> int:
        peak, live = 0, 0
        for t in sorted(deltas):
            live += deltas[t]
            if live > peak:
                peak = live
        return peak

    return sweep(total), {ph.value: sweep(d) for ph, d in
                          sorted(per.items(), key=lambda kv: kv[0].value)}


def periodic_breakdown_peaks_fast(pb: PeriodicBlocks) -> tuple[int, dict]:
    """Vectorized ``periodic_breakdown_peaks``: the delta sweep becomes
    argsort + cumsum, with liveness evaluated at the last event of each
    timestamp (equivalent to summing all deltas at equal t first).
    Output is identical to the dict-based sweep (tests/test_columnar.py).
    """
    def cols(blocks, reps=1, period=0):
        if not blocks:
            return None
        s, at, ft, ph = zip(*((b.sharded_size, b.alloc_t,
                               -1 if b.free_t is None else b.free_t,
                               PHASE_CODE[b.phase]) for b in blocks))
        s = np.array(s, np.int64)
        at = np.array(at, np.int64)
        ft = np.array(ft, np.int64)
        ph = np.array(ph, np.uint8)
        if reps > 1:
            dt = (np.arange(reps, dtype=np.int64) * period)[:, None]
            at = (at[None, :] + dt).ravel()
            ft = np.where(ft[None, :] < 0, np.int64(-1),
                          ft[None, :] + dt).ravel()
            s = np.broadcast_to(s, (reps, s.shape[0])).ravel()
            ph = np.broadcast_to(ph, (reps, ph.shape[0])).ravel()
        return s, at, ft, ph

    parts = [p for p in (
        cols(pb.prefix),
        cols(pb.cycle, max(pb.n_cycles, 0) or 1, pb.period)
        if pb.n_cycles > 0 else None,
        cols(pb.suffix)) if p is not None]
    if not parts:
        return 0, {}
    s = np.concatenate([p[0] for p in parts])
    at = np.concatenate([p[1] for p in parts])
    ft = np.concatenate([p[2] for p in parts])
    ph = np.concatenate([p[3] for p in parts])
    has_free = ft >= 0
    times = np.concatenate([at, ft[has_free]])
    deltas = np.concatenate([s, -s[has_free]])
    phases = np.concatenate([ph, ph[has_free]])

    def sweep(t, d):
        if t.size == 0:
            return 0
        order = np.argsort(t, kind="stable")
        t = t[order]
        cs = np.cumsum(d[order])
        last = np.empty(t.shape, bool)
        last[:-1] = t[1:] != t[:-1]
        last[-1] = True
        return max(int(cs[last].max()), 0)

    total = sweep(times, deltas)
    per = {}
    for code in np.unique(ph):
        mask = phases == code
        per[PHASE_TABLE[code].value] = sweep(times[mask], deltas[mask])
    per = {k: per[k] for k in sorted(per)}
    return total, per


def liveness_curve(blocks: Iterable[BlockLifecycle]) -> list[tuple[int, int]]:
    """(t, live_bytes) curve from lifecycles — the 'Tensor memory' series
    of the paper's Fig 1/6 (segment series comes from the Simulator)."""
    deltas: dict[int, int] = {}
    for b in blocks:
        deltas[b.alloc_t] = deltas.get(b.alloc_t, 0) + b.sharded_size
        if b.free_t is not None:
            deltas[b.free_t] = deltas.get(b.free_t, 0) - b.sharded_size
    curve, live = [], 0
    for t in sorted(deltas):
        live += deltas[t]
        curve.append((t, live))
    return curve


def peak_live_bytes(blocks: Iterable[BlockLifecycle]) -> int:
    curve = liveness_curve(blocks)
    return max((v for _, v in curve), default=0)
