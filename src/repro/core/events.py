"""Memory-event and lifecycle data structures for the xMem pipeline.

These mirror the entities in the paper (§2.2, §3.2):

* ``MemoryEvent`` — one allocation or deallocation, in *execution order*.
  The paper reconstructs these from PyTorch-profiler ``cpu_instant_event``
  rows; we emit them directly from the jaxpr interpreter (``tracer.py``)
  or reconstruct them from an external JSON trace (``analyzer.py``).
* ``BlockLifecycle`` — a reconstructed memory block: size + alloc/free
  position + attribution to the operator / layer scope that produced it.
  "Memory block" throughout this codebase refers to these entities,
  exactly as in the paper.
* ``Trace`` — an ordered event stream plus metadata (iteration boundaries,
  phases), the unit of data handed between pipeline stages.
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Iterable, Sequence


class BlockKind(enum.Enum):
    """Semantic class of a memory block (drives Orchestrator policy)."""

    PARAM = "param"
    GRAD = "grad"
    OPT_STATE = "opt_state"
    ACTIVATION = "activation"
    INPUT = "input"           # batch data
    OUTPUT = "output"         # step outputs (loss, metrics, new params)
    TEMP = "temp"             # operator-internal scratch
    COLLECTIVE = "collective"  # injected communication buffers (distributed)
    CACHE = "cache"           # KV / recurrent state (serving)


class Phase(enum.Enum):
    """Training-loop phase an event belongs to (paper: user_annotation)."""

    INIT = "init"                 # model/optimizer materialization
    FORWARD_BACKWARD = "fwd_bwd"  # loss + gradient computation
    OPTIMIZER = "optimizer"       # parameter/optimizer-state update
    DECODE = "decode"             # serving decode step
    DATA = "data"                 # host->device batch transfer


@dataclasses.dataclass
class MemoryEvent:
    """One alloc/free in execution order.

    ``t`` is the event's position in the stream (a logical clock — the
    paper uses wall-clock CPU timestamps; execution order is what matters
    for the Simulator, so a logical clock loses nothing).
    """

    kind: str              # "alloc" | "free"
    block_id: int
    size: int              # bytes (pre-rounding; the allocator sim rounds)
    t: int
    iteration: int = 0
    phase: Phase = Phase.FORWARD_BACKWARD
    op: str = ""           # primitive name, e.g. "dot_general"
    scope: str = ""        # layer scope, e.g. "decoder/layers/attn/q_proj"
    block_kind: BlockKind = BlockKind.TEMP

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["phase"] = self.phase.value
        d["block_kind"] = self.block_kind.value
        return d

    @staticmethod
    def from_json(d: dict) -> "MemoryEvent":
        d = dict(d)
        d["phase"] = Phase(d["phase"])
        d["block_kind"] = BlockKind(d["block_kind"])
        return MemoryEvent(**d)


@dataclasses.dataclass
class BlockLifecycle:
    """A reconstructed memory block (paper §3.2).

    ``free_t is None`` → persistent for the rest of the trace (paper:
    "blocks lacking a deallocation event are considered persistent").
    ``shard_factor`` divides the size for per-device estimation in the
    distributed extension (paper §6.2); 1 on a single device.
    """

    block_id: int
    size: int
    alloc_t: int
    free_t: int | None
    iteration: int = 0
    phase: Phase = Phase.FORWARD_BACKWARD
    op: str = ""
    scope: str = ""
    block_kind: BlockKind = BlockKind.TEMP
    shard_factor: float = 1.0

    @property
    def persistent(self) -> bool:
        return self.free_t is None

    @property
    def sharded_size(self) -> int:
        return max(int(self.size / self.shard_factor), 1) if self.size else 0

    def overlaps(self, t: int) -> bool:
        end = self.free_t if self.free_t is not None else float("inf")
        return self.alloc_t <= t < end


@dataclasses.dataclass
class Trace:
    """Ordered event stream + metadata — the inter-stage currency."""

    events: list[MemoryEvent]
    num_iterations: int = 1
    meta: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.events)

    def iteration_slice(self, it: int) -> list[MemoryEvent]:
        return [e for e in self.events if e.iteration == it]

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(
                {
                    "num_iterations": self.num_iterations,
                    "meta": self.meta,
                    "events": [e.to_json() for e in self.events],
                },
                f,
            )

    @staticmethod
    def load(path: str) -> "Trace":
        with open(path) as f:
            d = json.load(f)
        return Trace(
            events=[MemoryEvent.from_json(e) for e in d["events"]],
            num_iterations=d["num_iterations"],
            meta=d.get("meta", {}),
        )


def lifecycles_to_events(blocks: Sequence[BlockLifecycle]) -> list[MemoryEvent]:
    """Expand lifecycles back into an ordered alloc/free event stream.

    Free events at the same logical time sort *before* alloc events — a
    block freed at t must be reusable by a block allocated at t (this is
    the paper's Fig-3 sensitivity: dealloc/alloc interleaving decides the
    peak; ties resolve in favor of reuse, matching allocator behavior
    where the framework frees an input before allocating the output of
    the next op at the same trace position).
    """
    evs: list[tuple[int, int, MemoryEvent]] = []
    horizon = 0
    for b in blocks:
        horizon = max(horizon, b.alloc_t + 1, (b.free_t or 0) + 1)
    for b in blocks:
        evs.append(
            (b.alloc_t, 1, MemoryEvent(
                "alloc", b.block_id, b.sharded_size, b.alloc_t, b.iteration,
                b.phase, b.op, b.scope, b.block_kind))
        )
        if b.free_t is not None:
            evs.append(
                (b.free_t, 0, MemoryEvent(
                    "free", b.block_id, b.sharded_size, b.free_t, b.iteration,
                    b.phase, b.op, b.scope, b.block_kind))
            )
    evs.sort(key=lambda x: (x[0], x[1]))
    return [e for _, _, e in evs]


# -- periodic composition (estimation fast path) ----------------------------
#: Block-id namespace stride for replicated cycle instances. Instance k of
#: a PeriodicBlocks cycle re-ids block ``b`` as ``b + (k + 1) * STRIDE`` so
#: replicas never collide with prefix/suffix ids (small positive ints) or
#: synthetic orchestrator ids (small negative ints).
CYCLE_ID_STRIDE = 1 << 40


def shift_cycle_bid(bid: int, instance: int) -> int:
    return bid + (instance + 1) * CYCLE_ID_STRIDE


def split_cycle_bid(bid: int) -> tuple[int, int]:
    """Inverse of ``shift_cycle_bid``: (instance, raw_id). Instance is -1
    for prefix/suffix ids (small magnitudes, including the orchestrator's
    negative synthetic ids), which never carry a stride offset."""
    inst_plus1 = (bid + (CYCLE_ID_STRIDE >> 1)) // CYCLE_ID_STRIDE
    return inst_plus1 - 1, bid - inst_plus1 * CYCLE_ID_STRIDE


@dataclasses.dataclass
class PeriodicBlocks:
    """N-iteration composition in O(blocks) space (fast path, ISSUE 1).

    ``prefix`` holds iteration 0 (params + optimizer-init included),
    ``cycle`` holds iteration 1 at its absolute times, replicated
    implicitly ``n_cycles`` times with a constant ``period`` offset
    (iterations 1..N-2), and ``suffix`` holds the final iteration at its
    true absolute times. The last iteration is kept concrete because
    grad-release policies treat it differently (no next iteration to
    free into); every middle iteration is an exact shifted copy of
    iteration 1 by construction, which is what makes steady-state replay
    and the periodic peak computations below *exact*, not approximate.
    """

    prefix: list[BlockLifecycle]
    cycle: list[BlockLifecycle]
    n_cycles: int                 # replica count of ``cycle`` (>= 0)
    period: int
    suffix: list[BlockLifecycle]
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def num_blocks(self) -> int:
        return (len(self.prefix) + self.n_cycles * len(self.cycle)
                + len(self.suffix))

    def materialize(self) -> list[BlockLifecycle]:
        """Expand to the flat lifecycle list the slow path would build."""
        out = list(self.prefix)
        for k in range(self.n_cycles):
            dt = k * self.period
            for b in self.cycle:
                out.append(BlockLifecycle(
                    shift_cycle_bid(b.block_id, k), b.size, b.alloc_t + dt,
                    None if b.free_t is None else b.free_t + dt,
                    b.iteration + k, b.phase, b.op, b.scope, b.block_kind,
                    b.shard_factor))
        out.extend(self.suffix)
        return out

    def iter_groups(self):
        yield from self.prefix
        yield from self.cycle
        yield from self.suffix


def reduced_for_breakdown(pb: PeriodicBlocks,
                          max_cycles: int = 4) -> PeriodicBlocks:
    """Shrink a periodic composition to a bounded replica count without
    changing any liveness maximum (total or per-phase).

    Valid when every cycle block is freed (zero net bytes per replica) —
    then every middle window's liveness profile is an exact copy with an
    identical entering level, so deleting repeated windows preserves all
    peaks. The suffix (and nothing else) is shifted left to follow the
    kept replicas. Falls back to the original composition when a cycle
    block persists (per-replica drift) or when prefix events reach past
    the kept windows."""
    if pb.n_cycles <= max_cycles or max_cycles < 2:
        return pb
    if any(b.free_t is None for b in pb.cycle):
        return pb
    cycle_start = pb.meta.get("cycle_start")
    if cycle_start is None:
        return pb
    horizon = cycle_start + 2 * pb.period
    for b in pb.prefix:
        if b.alloc_t >= horizon or (b.free_t is not None
                                    and b.free_t > horizon):
            return pb
    dt = (pb.n_cycles - max_cycles) * pb.period
    suffix = [dataclasses.replace(
        b, alloc_t=b.alloc_t - dt,
        free_t=None if b.free_t is None else b.free_t - dt)
        for b in pb.suffix]
    return PeriodicBlocks(pb.prefix, pb.cycle, max_cycles, pb.period,
                          suffix, meta=pb.meta)


def periodic_peak_live(pb: PeriodicBlocks, pred=None) -> int:
    """Exact peak of live bytes over the full expansion, computed with
    integer deltas only (no lifecycle copies)."""
    deltas: dict[int, int] = {}

    def add(b: BlockLifecycle, dt: int) -> None:
        if pred is not None and not pred(b):
            return
        s = b.sharded_size
        deltas[b.alloc_t + dt] = deltas.get(b.alloc_t + dt, 0) + s
        if b.free_t is not None:
            deltas[b.free_t + dt] = deltas.get(b.free_t + dt, 0) - s

    for b in pb.prefix:
        add(b, 0)
    for k in range(pb.n_cycles):
        dt = k * pb.period
        for b in pb.cycle:
            add(b, dt)
    for b in pb.suffix:
        add(b, 0)
    peak, live = 0, 0
    for t in sorted(deltas):
        live += deltas[t]
        peak = max(peak, live)
    return peak


def periodic_phase_peaks(pb: PeriodicBlocks) -> dict:
    """Per-phase peak live bytes over the full expansion (exact)."""
    return periodic_breakdown_peaks(pb)[1]


def periodic_breakdown_peaks(pb: PeriodicBlocks) -> tuple[int, dict]:
    """(total peak live, per-phase peaks) in a single delta pass — the
    estimator's breakdown without lifecycle copies."""
    total: dict[int, int] = {}
    per: dict = {}

    def add(b: BlockLifecycle, dt: int) -> None:
        s = b.sharded_size
        at = b.alloc_t + dt
        d = per.get(b.phase)
        if d is None:
            d = per[b.phase] = {}
        total[at] = total.get(at, 0) + s
        d[at] = d.get(at, 0) + s
        ft = b.free_t
        if ft is not None:
            ft += dt
            total[ft] = total.get(ft, 0) - s
            d[ft] = d.get(ft, 0) - s

    for b in pb.prefix:
        add(b, 0)
    for k in range(pb.n_cycles):
        dt = k * pb.period
        for b in pb.cycle:
            add(b, dt)
    for b in pb.suffix:
        add(b, 0)

    def sweep(deltas: dict[int, int]) -> int:
        peak, live = 0, 0
        for t in sorted(deltas):
            live += deltas[t]
            if live > peak:
                peak = live
        return peak

    return sweep(total), {ph.value: sweep(d) for ph, d in
                          sorted(per.items(), key=lambda kv: kv[0].value)}


def liveness_curve(blocks: Iterable[BlockLifecycle]) -> list[tuple[int, int]]:
    """(t, live_bytes) curve from lifecycles — the 'Tensor memory' series
    of the paper's Fig 1/6 (segment series comes from the Simulator)."""
    deltas: dict[int, int] = {}
    for b in blocks:
        deltas[b.alloc_t] = deltas.get(b.alloc_t, 0) + b.sharded_size
        if b.free_t is not None:
            deltas[b.free_t] = deltas.get(b.free_t, 0) - b.sharded_size
    curve, live = [], 0
    for t in sorted(deltas):
        live += deltas[t]
        curve.append((t, live))
    return curve


def peak_live_bytes(blocks: Iterable[BlockLifecycle]) -> int:
    curve = liveness_curve(blocks)
    return max((v for _, v in curve), default=0)
