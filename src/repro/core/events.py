"""Memory-event and lifecycle data structures for the xMem pipeline.

These mirror the entities in the paper (§2.2, §3.2):

* ``MemoryEvent`` — one allocation or deallocation, in *execution order*.
  The paper reconstructs these from PyTorch-profiler ``cpu_instant_event``
  rows; we emit them directly from the jaxpr interpreter (``tracer.py``)
  or reconstruct them from an external JSON trace (``analyzer.py``).
* ``BlockLifecycle`` — a reconstructed memory block: size + alloc/free
  position + attribution to the operator / layer scope that produced it.
  "Memory block" throughout this codebase refers to these entities,
  exactly as in the paper.
* ``Trace`` — an ordered event stream plus metadata (iteration boundaries,
  phases), the unit of data handed between pipeline stages.
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Iterable, Sequence


class BlockKind(enum.Enum):
    """Semantic class of a memory block (drives Orchestrator policy)."""

    PARAM = "param"
    GRAD = "grad"
    OPT_STATE = "opt_state"
    ACTIVATION = "activation"
    INPUT = "input"           # batch data
    OUTPUT = "output"         # step outputs (loss, metrics, new params)
    TEMP = "temp"             # operator-internal scratch
    COLLECTIVE = "collective"  # injected communication buffers (distributed)
    CACHE = "cache"           # KV / recurrent state (serving)


class Phase(enum.Enum):
    """Training-loop phase an event belongs to (paper: user_annotation)."""

    INIT = "init"                 # model/optimizer materialization
    FORWARD_BACKWARD = "fwd_bwd"  # loss + gradient computation
    OPTIMIZER = "optimizer"       # parameter/optimizer-state update
    DECODE = "decode"             # serving decode step
    DATA = "data"                 # host->device batch transfer


@dataclasses.dataclass
class MemoryEvent:
    """One alloc/free in execution order.

    ``t`` is the event's position in the stream (a logical clock — the
    paper uses wall-clock CPU timestamps; execution order is what matters
    for the Simulator, so a logical clock loses nothing).
    """

    kind: str              # "alloc" | "free"
    block_id: int
    size: int              # bytes (pre-rounding; the allocator sim rounds)
    t: int
    iteration: int = 0
    phase: Phase = Phase.FORWARD_BACKWARD
    op: str = ""           # primitive name, e.g. "dot_general"
    scope: str = ""        # layer scope, e.g. "decoder/layers/attn/q_proj"
    block_kind: BlockKind = BlockKind.TEMP

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["phase"] = self.phase.value
        d["block_kind"] = self.block_kind.value
        return d

    @staticmethod
    def from_json(d: dict) -> "MemoryEvent":
        d = dict(d)
        d["phase"] = Phase(d["phase"])
        d["block_kind"] = BlockKind(d["block_kind"])
        return MemoryEvent(**d)


@dataclasses.dataclass
class BlockLifecycle:
    """A reconstructed memory block (paper §3.2).

    ``free_t is None`` → persistent for the rest of the trace (paper:
    "blocks lacking a deallocation event are considered persistent").
    ``shard_factor`` divides the size for per-device estimation in the
    distributed extension (paper §6.2); 1 on a single device.
    """

    block_id: int
    size: int
    alloc_t: int
    free_t: int | None
    iteration: int = 0
    phase: Phase = Phase.FORWARD_BACKWARD
    op: str = ""
    scope: str = ""
    block_kind: BlockKind = BlockKind.TEMP
    shard_factor: float = 1.0

    @property
    def persistent(self) -> bool:
        return self.free_t is None

    @property
    def sharded_size(self) -> int:
        return max(int(self.size / self.shard_factor), 1) if self.size else 0

    def overlaps(self, t: int) -> bool:
        end = self.free_t if self.free_t is not None else float("inf")
        return self.alloc_t <= t < end


@dataclasses.dataclass
class Trace:
    """Ordered event stream + metadata — the inter-stage currency."""

    events: list[MemoryEvent]
    num_iterations: int = 1
    meta: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.events)

    def iteration_slice(self, it: int) -> list[MemoryEvent]:
        return [e for e in self.events if e.iteration == it]

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(
                {
                    "num_iterations": self.num_iterations,
                    "meta": self.meta,
                    "events": [e.to_json() for e in self.events],
                },
                f,
            )

    @staticmethod
    def load(path: str) -> "Trace":
        with open(path) as f:
            d = json.load(f)
        return Trace(
            events=[MemoryEvent.from_json(e) for e in d["events"]],
            num_iterations=d["num_iterations"],
            meta=d.get("meta", {}),
        )


def lifecycles_to_events(blocks: Sequence[BlockLifecycle]) -> list[MemoryEvent]:
    """Expand lifecycles back into an ordered alloc/free event stream.

    Free events at the same logical time sort *before* alloc events — a
    block freed at t must be reusable by a block allocated at t (this is
    the paper's Fig-3 sensitivity: dealloc/alloc interleaving decides the
    peak; ties resolve in favor of reuse, matching allocator behavior
    where the framework frees an input before allocating the output of
    the next op at the same trace position).
    """
    evs: list[tuple[int, int, MemoryEvent]] = []
    horizon = 0
    for b in blocks:
        horizon = max(horizon, b.alloc_t + 1, (b.free_t or 0) + 1)
    for b in blocks:
        evs.append(
            (b.alloc_t, 1, MemoryEvent(
                "alloc", b.block_id, b.sharded_size, b.alloc_t, b.iteration,
                b.phase, b.op, b.scope, b.block_kind))
        )
        if b.free_t is not None:
            evs.append(
                (b.free_t, 0, MemoryEvent(
                    "free", b.block_id, b.sharded_size, b.free_t, b.iteration,
                    b.phase, b.op, b.scope, b.block_kind))
            )
    evs.sort(key=lambda x: (x[0], x[1]))
    return [e for _, _, e in evs]


def liveness_curve(blocks: Iterable[BlockLifecycle]) -> list[tuple[int, int]]:
    """(t, live_bytes) curve from lifecycles — the 'Tensor memory' series
    of the paper's Fig 1/6 (segment series comes from the Simulator)."""
    deltas: dict[int, int] = {}
    for b in blocks:
        deltas[b.alloc_t] = deltas.get(b.alloc_t, 0) + b.sharded_size
        if b.free_t is not None:
            deltas[b.free_t] = deltas.get(b.free_t, 0) - b.sharded_size
    curve, live = [], 0
    for t in sorted(deltas):
        live += deltas[t]
        curve.append((t, live))
    return curve


def peak_live_bytes(blocks: Iterable[BlockLifecycle]) -> int:
    curve = liveness_curve(blocks)
    return max((v for _, v in curve), default=0)
