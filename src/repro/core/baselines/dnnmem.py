"""DNNMem-reproduction: static computation-graph analysis + basic BFC.

Reproduced from the paper's description (§4.1.1, §5.1) — the original
source is not public, exactly as the xMem authors note. Captured
limitations (each is a deliberate *feature* of the reproduction, since
they drive the accuracy gap the paper measures):

1. Static graph only: analyzes the forward/backward graph; the optimizer
   phase is invisible, so stateful-optimizer memory (Adam's m/v) is
   missed — "estimations relatively more accurate for SGD" (paper §5.1).
2. Framework-level allocator only: one-level BFC, no device allocator,
   and crucially *no reclaim of cached segments* before declaring OOM.
3. No runtime/code sensitivity: gradient lifetimes follow static
   liveness (freed at last static use), so ``zero_grad`` placement and
   donation/fusion behaviors cannot be captured.
"""
from __future__ import annotations

import time

import jax

from ..allocator import CUDA_CACHING, CachingAllocatorSim, DeviceAllocatorSim
from ..analyzer import reconstruct_lifecycles
from ..events import BlockKind, lifecycles_to_events
from ..tracer import trace_fn
from .common import JobSpec


class DNNMemEstimator:
    name = "dnnmem"

    def __init__(self, policy=CUDA_CACHING):
        self.policy = policy
        self.last_runtime_s = 0.0

    def estimate(self, job: JobSpec, capacity: int = 1 << 62) -> int:
        t0 = time.perf_counter()
        flat_p = jax.tree_util.tree_leaves(job.params)
        flat_b = jax.tree_util.tree_leaves(job.batch)
        p_struct = jax.tree_util.tree_structure(job.params)
        b_struct = jax.tree_util.tree_structure(job.batch)

        def flat_fn(*leaves):
            return job.fwd_bwd_fn(
                jax.tree_util.tree_unflatten(p_struct, leaves[:len(flat_p)]),
                jax.tree_util.tree_unflatten(b_struct, leaves[len(flat_p):]))

        kinds = [BlockKind.PARAM] * len(flat_p) + [BlockKind.INPUT] * len(flat_b)
        trace, tracer = trace_fn(flat_fn, *(flat_p + flat_b),
                                 arg_kinds=kinds, scan_unroll_cap=2)
        blocks = reconstruct_lifecycles(trace)
        # static liveness: persistent params/inputs; grads freed at last
        # static use (which, for outputs, is "never" within the graph —
        # keep them alive to graph end; DNNMem has no optimizer phase)
        events = lifecycles_to_events(blocks)
        # one-level simulation: device has infinite pages but we track
        # against capacity WITHOUT the reclaim ladder
        device = DeviceAllocatorSim(1 << 62, self.policy.device_page)
        sim = CachingAllocatorSim(self.policy, device)
        handles = {}
        for e in events:
            if e.kind == "alloc":
                if e.size <= 0:
                    continue
                handles[e.block_id] = sim.malloc(e.size, t=e.t)
            else:
                h = handles.pop(e.block_id, None)
                if h is not None:
                    sim.free(h, t=e.t)
        peak = sim.peak_reserved
        self.last_oom_prediction = peak > capacity  # no reclaim modeled
        self.last_runtime_s = time.perf_counter() - t0
        return peak
