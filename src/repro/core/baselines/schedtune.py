"""SchedTune-reproduction: data-driven memory prediction (paper §5.2).

A ridge regression over job features (parameter bytes, batch size, depth,
width, optimizer statefulness, activation proxy) trained on historical
(configuration, measured-peak) pairs. Fast at inference (paper Table 4:
2 s), but exhibits the cold-start problem: configurations outside the
training distribution — new families, unseen batch ranges — degrade
sharply, which drives its Worst-quadrant PEF results (paper Fig. 8) and
the negative Transformer MCP (paper Table 3).

Implemented with plain numpy (closed-form ridge), no external ML deps.
"""
from __future__ import annotations

import time

import numpy as np

from .common import JobSpec


class SchedTuneEstimator:
    name = "schedtune"

    def __init__(self, l2: float = 1e-3):
        self.l2 = l2
        self.w: np.ndarray | None = None
        self.mu: np.ndarray | None = None
        self.sd: np.ndarray | None = None
        self.last_runtime_s = 0.0

    def fit(self, jobs: list[JobSpec], truths_bytes: list[int]) -> None:
        X = np.array([j.features() for j in jobs], dtype=np.float64)
        y = np.array(truths_bytes, dtype=np.float64) / 1e6  # MB target
        self.mu = X.mean(axis=0)
        self.sd = X.std(axis=0) + 1e-9
        Xn = (X - self.mu) / self.sd
        Xb = np.concatenate([Xn, np.ones((len(Xn), 1))], axis=1)
        A = Xb.T @ Xb + self.l2 * np.eye(Xb.shape[1])
        self.w = np.linalg.solve(A, Xb.T @ y)

    def estimate(self, job: JobSpec) -> int:
        t0 = time.perf_counter()
        if self.w is None:
            # cold start with no history at all: crude parametric guess
            est = (job.param_bytes() * 3 + job.batch_bytes() * 8)
            self.last_runtime_s = time.perf_counter() - t0
            return int(est)
        x = (np.array(job.features()) - self.mu) / self.sd
        xb = np.concatenate([x, [1.0]])
        est_mb = float(xb @ self.w)
        self.last_runtime_s = time.perf_counter() - t0
        return max(int(est_mb * 1e6), 1)
