"""Horus-style naive estimator: sum tensor sizes, ignore the allocator.

The paper cites Horus as "primarily sums tensor sizes" (§5.1) and uses it
to motivate allocator-aware simulation: without liveness or segment
modeling, estimates are either wild over-counts (every activation
coexists) or under-counts (ignores allocator rounding/caching). We follow
the common formulation: persistent state + gradients + every forward
activation, no liveness, no allocator.
"""
from __future__ import annotations

import time

import jax

from ..events import BlockKind
from ..tracer import trace_fn
from .common import JobSpec


class TensorSumEstimator:
    name = "tensorsum"

    def estimate(self, job: JobSpec) -> int:
        t0 = time.perf_counter()
        params_b = job.param_bytes()
        opt_b = job.opt_state_bytes()
        grads_b = params_b  # gradient per parameter
        batch_b = job.batch_bytes()
        # forward activations: one alloc per eqn output, no liveness
        flat_p = jax.tree_util.tree_leaves(job.params)
        flat_b = jax.tree_util.tree_leaves(job.batch)
        trace, _ = trace_fn(
            lambda *leaves: job.fwd_bwd_fn(
                jax.tree_util.tree_unflatten(
                    jax.tree_util.tree_structure(job.params),
                    leaves[:len(flat_p)]),
                jax.tree_util.tree_unflatten(
                    jax.tree_util.tree_structure(job.batch),
                    leaves[len(flat_p):])),
            *(flat_p + flat_b), scan_unroll_cap=1)
        act_b = sum(e.size for e in trace.events
                    if e.kind == "alloc"
                    and e.block_kind in (BlockKind.ACTIVATION, BlockKind.TEMP))
        # every tensor assumed simultaneously resident
        self.last_runtime_s = time.perf_counter() - t0
        return params_b + opt_b + grads_b + batch_b + act_b
