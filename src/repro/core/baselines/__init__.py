"""Baseline estimators reproduced from the paper's evaluation (§4.1.1).

Each baseline represents one methodology family:

* ``TensorSumEstimator``   — Horus-style: sums tensor sizes, no allocator,
                             no liveness (paper §5.1's "simpler static").
* ``DNNMemEstimator``      — static graph analysis + framework-level BFC
                             only: no device level, no cache reclaim, no
                             optimizer-phase capture, no code-placement
                             sensitivity (paper §5.1).
* ``SchedTuneEstimator``   — data-driven ridge regression on model/job
                             features; exhibits the cold-start problem on
                             unseen families (paper §5.2).
* ``DirectProbeEstimator`` — LLMem-style direct measurement: actually
                             compiles/measures scaled-down jobs and
                             extrapolates — high fidelity, but consumes
                             the very resources estimation should spare
                             (paper §5.3).

All share the ``estimate(job) -> int`` interface over a ``JobSpec``.
"""
from .common import JobSpec
from .tensorsum import TensorSumEstimator
from .dnnmem import DNNMemEstimator
from .schedtune import SchedTuneEstimator
from .directprobe import DirectProbeEstimator

__all__ = [
    "JobSpec", "TensorSumEstimator", "DNNMemEstimator",
    "SchedTuneEstimator", "DirectProbeEstimator",
]
