"""Shared job description consumed by all estimators (xMem + baselines)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax


@dataclasses.dataclass
class JobSpec:
    """One training-job configuration (paper notation: configuration j)."""

    name: str
    fwd_bwd_fn: Callable          # (params, batch) -> (loss, grads)
    params: Any                   # pytree of ShapeDtypeStruct
    batch: Any                    # pytree of ShapeDtypeStruct
    update_fn: Callable | None = None
    opt_init_fn: Callable | None = None
    meta: dict = dataclasses.field(default_factory=dict)
    # meta keys used by feature-based estimators / reporting:
    #   family, optimizer, batch_size, seq_len, d_model, n_layers,
    #   grad_release

    def param_bytes(self) -> int:
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self.params))

    def batch_bytes(self) -> int:
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self.batch))

    def opt_state_bytes(self) -> int:
        if self.opt_init_fn is None:
            return 0
        st = jax.eval_shape(self.opt_init_fn, self.params)
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(st))

    def features(self) -> list[float]:
        """Feature vector for data-driven estimators (SchedTune-style)."""
        m = self.meta
        return [
            self.param_bytes() / 1e6,
            self.batch_bytes() / 1e6,
            float(m.get("batch_size", 1)),
            float(m.get("seq_len", 0)),
            float(m.get("d_model", 0)),
            float(m.get("n_layers", 0)),
            float(m.get("optimizer_states", 0)),  # 0 sgd, 1 rmsprop, 2 adam
            self.param_bytes() / 1e6 * float(m.get("optimizer_states", 0)),
            float(m.get("batch_size", 1)) * float(m.get("seq_len", 1))
            * float(m.get("d_model", 1)) / 1e6,   # activation proxy
        ]
