"""LLMem-style direct-measurement estimator (paper §5.3).

LLMem estimates fine-tuning memory by *executing* scaled-down probes on
the target GPU and extrapolating the batch-dependent terms. Our analogue
measures the real XLA reservation (``compiled.memory_analysis()``) at two
reduced batch sizes and extrapolates linearly in batch:

    peak(B) ≈ fixed + slope * B

This is the methodology family that violates the zero-target-overhead
constraint: it must compile (and on real hardware, run) the job twice —
its measured runtime in Table-4-style benchmarks reflects that cost. It
also fails outright when even the probe exceeds capacity (paper §5.3
limitation (i)/(ii)), which we surface via ``ProbeOOMError``.
"""
from __future__ import annotations

import time
from typing import Any

import jax

from .common import JobSpec


class ProbeOOMError(RuntimeError):
    pass


def _scale_batch(tree: Any, factor: int) -> Any:
    def scale(leaf):
        if not leaf.shape:
            return leaf
        b = max(leaf.shape[0] // factor, 1)
        return jax.ShapeDtypeStruct((b,) + tuple(leaf.shape[1:]), leaf.dtype)
    return jax.tree_util.tree_map(scale, tree)


def measured_peak(job: JobSpec, batch=None) -> int:
    """Compile the full step and read XLA's true reservation."""
    batch = job.batch if batch is None else batch
    opt_state = (jax.eval_shape(job.opt_init_fn, job.params)
                 if job.opt_init_fn is not None else None)

    def full_step(params, opt_state, batch):
        loss, grads = job.fwd_bwd_fn(params, batch)
        if job.update_fn is None:
            return loss, grads
        new_p, new_s = job.update_fn(params, grads, opt_state)
        return loss, new_p, new_s

    compiled = jax.jit(full_step, donate_argnums=(0, 1)).lower(
        job.params, opt_state, batch).compile()
    ma = compiled.memory_analysis()
    return (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)


class DirectProbeEstimator:
    name = "directprobe"

    def __init__(self, probe_fractions=(4, 2), capacity: int | None = None):
        self.probe_fractions = probe_fractions
        self.capacity = capacity
        self.last_runtime_s = 0.0

    def estimate(self, job: JobSpec) -> int:
        t0 = time.perf_counter()
        f_small, f_large = self.probe_fractions
        b_small = _scale_batch(job.batch, f_small)
        b_large = _scale_batch(job.batch, f_large)
        n_full = max(jax.tree_util.tree_leaves(job.batch)[0].shape[0], 1)
        n_small = max(n_full // f_small, 1)
        n_large = max(n_full // f_large, 1)
        p_small = measured_peak(job, b_small)
        if self.capacity is not None and p_small > self.capacity:
            self.last_runtime_s = time.perf_counter() - t0
            raise ProbeOOMError("probe itself exceeds device capacity")
        if n_large == n_small:
            self.last_runtime_s = time.perf_counter() - t0
            return p_small
        p_large = measured_peak(job, b_large)
        slope = (p_large - p_small) / (n_large - n_small)
        fixed = p_small - slope * n_small
        self.last_runtime_s = time.perf_counter() - t0
        return int(fixed + slope * n_full)
