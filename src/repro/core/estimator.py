"""xMem estimator — the public API tying the pipeline together.

``XMemEstimator.estimate_training`` reproduces the paper's workflow:

1. trace the job's phases on CPU (jaxpr interpretation — zero accelerator
   use, milliseconds even for trillion-parameter configs);
2. reconstruct + classify lifecycles (Analyzer);
3. compose N iterations on one timeline — optimizer state materializes at
   the first update and persists (why the paper analyzes >= 2 iterations;
   we default to 3 like the paper);
4. orchestrate lifecycles (persistence, grad_release, donation, fusion
   folding, collective injection, sharding);
5. replay through the two-level allocator simulation -> peak estimate,
   usage curve, OOM verdict.

The estimator is a *first-class framework feature*: ``launch/train.py``
gates job admission on it, and the sharding engine feeds it per-tensor
shard factors for per-device estimates (the paper's §6.2 extension).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax

from .allocator import AllocatorPolicy, CUDA_CACHING
from .analyzer import classify_blocks, phase_peaks, reconstruct_lifecycles
from .events import BlockKind, BlockLifecycle, Phase, peak_live_bytes
from .orchestrator import CollectiveSpec, MemoryOrchestrator, OrchestratorPolicy
from .simulator import MemorySimulator, SimResult
from .tracer import trace_fn


def update_grad_coupling(update_fn: Callable, params, grads,
                         opt_state) -> str:
    """Taint analysis: does the optimizer update *couple* gradients?

    Per-leaf updates (SGD/Adam/... via tree.map) let XLA fuse each leaf's
    update into the backward pass, so gradients die eagerly. Cross-leaf
    coupling (global-norm clipping, Adafactor's global RMS) forces all
    gradients to coexist until the update. Also detects whether gradients
    are upcast to a wider dtype inside the update (f32 working copies —
    they add transient bytes during the optimizer phase).

    Returns {"coupling": "per_leaf"|"coupled", "upcasts": bool}.
    """
    args = (params, grads, opt_state) if opt_state is not None \
        else (params, grads)
    fn = update_fn if opt_state is not None \
        else (lambda p, g: update_fn(p, g, None))
    closed = jax.make_jaxpr(fn)(*args)
    jaxpr = closed.jaxpr
    n_params = len(jax.tree_util.tree_leaves(params))
    n_grads = len(jax.tree_util.tree_leaves(grads))
    taint: dict = {}
    for i, v in enumerate(jaxpr.invars):
        if n_params <= i < n_params + n_grads:
            taint[v] = frozenset({i - n_params})
    from jax.extend import core as jcore
    coupling = "per_leaf"
    upcasts = False
    for eqn in jaxpr.eqns:
        union: frozenset = frozenset()
        for v in eqn.invars:
            if isinstance(v, jcore.Literal):
                continue
            union = union | taint.get(v, frozenset())
        if len(union) > 1:
            coupling = "coupled"
        if union:
            if eqn.primitive.name == "convert_element_type":
                iv = eqn.invars[0]
                ov = eqn.outvars[0]
                try:
                    if ov.aval.dtype.itemsize > iv.aval.dtype.itemsize:
                        upcasts = True  # f32 working copies of grads
                except AttributeError:
                    pass
            for ov in eqn.outvars:
                taint[ov] = union
    return {"coupling": coupling, "upcasts": upcasts}


def flatten_kinds(args_with_kinds: Sequence[tuple]) -> tuple[list, list[BlockKind], list[str]]:
    """Flatten (pytree, kind, name) triples into tracer-aligned lists."""
    flat_args, kinds, scopes = [], [], []
    for tree, kind, name in args_with_kinds:
        leaves, _ = jax.tree_util.tree_flatten(tree)
        flat_args.extend(leaves)
        kinds.extend([kind] * len(leaves))
        scopes.extend([f"{name}[{i}]" for i in range(len(leaves))])
    return flat_args, kinds, scopes


@dataclasses.dataclass
class EstimateReport:
    peak_bytes: int               # reserved segments — THE estimate
    peak_tensor_bytes: int        # live-tensor peak (naive lower bound)
    persistent_bytes: int         # params + opt state + consts
    oom: bool
    sim: SimResult
    breakdown: dict               # per-kind / per-phase summary
    wall_time_s: float
    num_events: int

    def fits(self, capacity: int) -> bool:
        return self.peak_bytes <= capacity


class XMemEstimator:
    """Peak-memory estimator. Target-specific presets:

    * ``XMemEstimator.for_tpu()``   — XLA/TPU target: static buffer
      assignment compacts memory, so the arena policy (reserved ≈ rounded
      live) plus fusion folding and donation model the runtime; this is
      the mode the framework's admission gate uses.
    * ``XMemEstimator.for_torch_gpu()`` — paper-faithful mode: PyTorch
      CUDACachingAllocator simulation, eager semantics (no fusion
      folding, no donation, grads till zero_grad). Used by the
      reproduction benchmarks.
    """

    def __init__(self,
                 allocator_policy: AllocatorPolicy = CUDA_CACHING,
                 orchestrator_policy: OrchestratorPolicy | None = None,
                 iterations: int = 3,
                 scan_unroll_cap: int = 3,
                 capacity: int = 1 << 62):
        self.allocator_policy = allocator_policy
        self.orchestrator = MemoryOrchestrator(
            orchestrator_policy or OrchestratorPolicy())
        self.iterations = iterations
        self.scan_unroll_cap = scan_unroll_cap
        self.capacity = capacity

    @classmethod
    def for_tpu(cls, **kw) -> "XMemEstimator":
        from .allocator import TPU_ARENA
        kw.setdefault("allocator_policy", TPU_ARENA)
        kw.setdefault("orchestrator_policy", OrchestratorPolicy(
            grad_release="auto", donate_params=True, donate_opt_state=True,
            fusion_folding=True))
        return cls(**kw)

    def calibrate(self, samples: Sequence[tuple],
                  quantile: float = 0.9) -> float:
        """Fit the backend transient-scale constant from (job_kwargs,
        truth_bytes) pairs — the explicit version of the paper's Fig-6
        calibration loop. Model-independent: one constant per backend.

        ``quantile`` targets one-sided error: a scheduler pays far more
        for an underestimate (round-2 OOM, the PEF/MCP penalty of
        Eq. 5-7) than for slight headroom, so the default skews high —
        the same asymmetry the paper's allocator rounding induces.

        Each sample is ((fwd_bwd, params, batch, update_fn, opt_init_fn),
        truth). Returns the fitted scale (also applied to self)."""
        import numpy as _np
        ratios = []
        for (fwd_bwd, params, batch, update_fn, opt_init_fn), truth \
                in samples:
            rep = self.estimate_training(fwd_bwd, params, batch,
                                         update_fn=update_fn,
                                         opt_init_fn=opt_init_fn)
            t_est = rep.peak_tensor_bytes - rep.persistent_bytes
            t_true = truth - rep.persistent_bytes
            if t_est > 0 and t_true > 0:
                ratios.append(t_true / t_est)
        scale = float(_np.quantile(ratios, quantile)) if ratios else 1.0
        self.orchestrator.policy = dataclasses.replace(
            self.orchestrator.policy, transient_scale=scale)
        return scale

    @classmethod
    def for_torch_gpu(cls, grad_release: str = "at_update",
                      **kw) -> "XMemEstimator":
        kw.setdefault("allocator_policy", CUDA_CACHING)
        kw.setdefault("orchestrator_policy", OrchestratorPolicy(
            grad_release=grad_release, donate_params=False,
            donate_opt_state=False, fusion_folding=False))
        return cls(**kw)

    # -- phase tracing helpers -------------------------------------------------
    def _trace_phase(self, fn, args_with_kinds, phase, out_kinds=None):
        flat, kinds, scopes = flatten_kinds(args_with_kinds)

        def flat_fn(*leaves):
            idx, rebuilt = 0, []
            for tree, _, _ in args_with_kinds:
                leaves_i, treedef = jax.tree_util.tree_flatten(tree)
                n = len(leaves_i)
                rebuilt.append(jax.tree_util.tree_unflatten(
                    treedef, leaves[idx:idx + n]))
                idx += n
            return fn(*rebuilt)

        trace, tr = trace_fn(flat_fn, *flat, arg_kinds=kinds,
                             arg_scopes=scopes,
                             scan_unroll_cap=self.scan_unroll_cap,
                             phase=phase)
        if out_kinds is not None:
            for b, k in zip(tr.output_blocks, out_kinds):
                b.kind = k
        # push kinds back into the recorded alloc events
        kind_by_bid = {b.bid: b.kind for b in tr.blocks.values()}
        for e in trace.events:
            e.block_kind = kind_by_bid.get(e.block_id, e.block_kind)
        return trace, tr

    @staticmethod
    def _expand_out_kinds(example_out, kind_map: Callable) -> list[BlockKind]:
        leaves = jax.tree_util.tree_leaves(example_out)
        return [kind_map(i, len(leaves)) for i in range(len(leaves))]

    # -- composition -------------------------------------------------------------
    def _compose(self, fwd_tr, fwd_tracer, upd_tr, upd_tracer,
                 init_tr, init_tracer) -> tuple[list[BlockLifecycle], dict]:
        """Stitch per-phase traces into an N-iteration timeline."""
        blocks: list[BlockLifecycle] = []
        cursor = 0
        iteration_ends: dict[int, int] = {}
        update_start: dict[int, int] = {}
        bwd_start: dict[int, int] = {}
        next_bid = [0]

        def fresh_bid():
            next_bid[0] += 1
            return next_bid[0]

        def place(trace, tracer, it, phase, skip_inputs, persist_outputs,
                  output_kind=None, drop_outputs=False):
            nonlocal cursor
            lcs = reconstruct_lifecycles(trace)
            input_bids = {b.bid for b in tracer.input_blocks}
            output_bids = {b.bid for b in tracer.output_blocks}
            placed = []
            for lc in lcs:
                if lc.block_id in input_bids and skip_inputs:
                    continue
                is_out = lc.block_id in output_bids
                if is_out and drop_outputs:
                    continue
                kind = lc.block_kind
                if is_out and output_kind is not None:
                    kind = output_kind
                # persistent blocks (free_t None) stay persistent here; the
                # orchestrator decides their real release (grads, outputs)
                free_t = lc.free_t + cursor if lc.free_t is not None else None
                placed.append(dataclasses.replace(
                    lc, block_id=fresh_bid(), alloc_t=lc.alloc_t + cursor,
                    free_t=free_t, iteration=it, phase=phase,
                    block_kind=kind))
            cursor += len(trace.events) + 1
            return placed

        # t=0: persistent parameter blocks (one per leaf, from fwd inputs)
        for b in fwd_tracer.input_blocks:
            if b.kind is BlockKind.PARAM and b.size > 0:
                blocks.append(BlockLifecycle(
                    fresh_bid(), b.size, 0, None, 0, Phase.INIT,
                    "init", "params", BlockKind.PARAM))
        cursor += 1

        for it in range(self.iterations):
            # batch data arrives
            for b in fwd_tracer.input_blocks:
                if b.kind is BlockKind.INPUT and b.size > 0:
                    blocks.append(BlockLifecycle(
                        fresh_bid(), b.size, cursor, None, it, Phase.DATA,
                        "host_to_device", "batch", BlockKind.INPUT))
            cursor += 1
            bwd_start[it] = cursor
            blocks.extend(place(fwd_tr, fwd_tracer, it,
                                Phase.FORWARD_BACKWARD, skip_inputs=True,
                                persist_outputs=True))
            update_start[it] = cursor
            if it == 0 and init_tr is not None:
                # optimizer state materializes at the first update
                blocks.extend(place(init_tr, init_tracer, it, Phase.OPTIMIZER,
                                    skip_inputs=True, persist_outputs=True,
                                    output_kind=BlockKind.OPT_STATE))
            if upd_tr is not None:
                blocks.extend(place(upd_tr, upd_tracer, it, Phase.OPTIMIZER,
                                    skip_inputs=True, persist_outputs=True,
                                    output_kind=BlockKind.OUTPUT))
            iteration_ends[it] = cursor
        bwd_start[self.iterations] = cursor + 1  # sentinel for last grads
        meta = dict(iteration_ends=iteration_ends, update_start=update_start,
                    bwd_start=bwd_start, horizon=cursor + 2)
        return blocks, meta

    # -- public API ------------------------------------------------------------------
    def estimate_training(self,
                          fwd_bwd_fn: Callable,     # (params, batch) -> (loss, grads)
                          params, batch,
                          update_fn: Callable | None = None,  # (params, grads, opt_state) -> (params, opt_state)
                          opt_init_fn: Callable | None = None,  # params -> opt_state
                          shard_factor_fn=None,
                          collective_specs: Sequence[CollectiveSpec] = (),
                          capacity: int | None = None) -> EstimateReport:
        t0 = time.perf_counter()
        _policy_before = self.orchestrator.policy  # restored at the end
        try:
            return self._estimate_training(
                fwd_bwd_fn, params, batch, update_fn, opt_init_fn,
                shard_factor_fn, collective_specs, capacity, t0)
        finally:
            self.orchestrator.policy = _policy_before

    def _estimate_training(self, fwd_bwd_fn, params, batch, update_fn,
                           opt_init_fn, shard_factor_fn, collective_specs,
                           capacity, t0) -> EstimateReport:
        # --- stage 1: CPU traces (paper: profile first iterations) ---
        fwd_out_shape = jax.eval_shape(fwd_bwd_fn, params, batch)
        n_out = len(jax.tree_util.tree_leaves(fwd_out_shape))
        n_loss = len(jax.tree_util.tree_leaves(fwd_out_shape[0])) \
            if isinstance(fwd_out_shape, tuple) else 1
        fwd_out_kinds = [BlockKind.OUTPUT] * n_loss + \
                        [BlockKind.GRAD] * (n_out - n_loss)
        fwd_tr, fwd_tracer = self._trace_phase(
            fwd_bwd_fn,
            [(params, BlockKind.PARAM, "params"),
             (batch, BlockKind.INPUT, "batch")],
            Phase.FORWARD_BACKWARD, out_kinds=fwd_out_kinds)

        init_tr = init_tracer = upd_tr = upd_tracer = None
        opt_state = None
        if opt_init_fn is not None:
            opt_state = jax.eval_shape(opt_init_fn, params)
            init_tr, init_tracer = self._trace_phase(
                opt_init_fn, [(params, BlockKind.PARAM, "params")],
                Phase.OPTIMIZER,
                out_kinds=[BlockKind.OPT_STATE] * len(
                    jax.tree_util.tree_leaves(opt_state)))
        if update_fn is not None:
            grads = fwd_out_shape[1] if isinstance(fwd_out_shape, tuple) \
                else fwd_out_shape
            upd_args = [(params, BlockKind.PARAM, "params"),
                        (grads, BlockKind.GRAD, "grads")]
            if opt_state is not None:
                upd_args.append((opt_state, BlockKind.OPT_STATE, "opt_state"))
            upd_tr, upd_tracer = self._trace_phase(
                update_fn, upd_args, Phase.OPTIMIZER)

        # --- stage 2+3: analyze & compose iterations ---
        blocks, meta = self._compose(fwd_tr, fwd_tracer, upd_tr, upd_tracer,
                                     init_tr, init_tracer)
        param_sizes = frozenset(
            b.size for b in fwd_tracer.input_blocks
            if b.kind is BlockKind.PARAM)
        blocks = classify_blocks(blocks, param_sizes)

        # --- stage 4: orchestrate ---
        phase_bounds = {}
        for it, end in meta["iteration_ends"].items():
            phase_bounds[(it, Phase.FORWARD_BACKWARD.value)] = (
                meta["bwd_start"][it], meta["update_start"][it])
            phase_bounds[(it, Phase.OPTIMIZER.value)] = (
                meta["update_start"][it], end)
        # Resolve "auto" grad release: per-leaf updates fuse into the
        # backward under XLA (eager grad death); coupled updates (global
        # clipping etc.) keep every grad alive until the optimizer phase.
        if self.orchestrator.policy.grad_release == "auto":
            mode = "eager_fused"
            upcasts = False
            if update_fn is not None:
                grads_shape = fwd_out_shape[1] \
                    if isinstance(fwd_out_shape, tuple) else fwd_out_shape
                info = update_grad_coupling(
                    update_fn, params, grads_shape, opt_state)
                mode = "eager_fused" if info["coupling"] == "per_leaf" \
                    else "at_update"
                upcasts = info["upcasts"]
            self.orchestrator.policy = dataclasses.replace(
                self.orchestrator.policy, grad_release=mode,
                optimizer_upcast_coexist=(
                    self.orchestrator.policy.optimizer_upcast_coexist
                    and upcasts))

        # grad_release="at_next_iter" frees iteration i's gradients only
        # when iteration i+1's update completes new ones — the
        # grad-accumulation / zero_grad-at-start idiom (paper Fig 1 POS1);
        # hence update_start is passed as the next-iteration release point.
        blocks = self.orchestrator.run(
            blocks,
            iteration_ends=meta["iteration_ends"],
            update_start=meta["update_start"],
            next_bwd_start=meta["update_start"],
            collective_specs=collective_specs,
            phase_bounds=phase_bounds,
            num_iterations=self.iterations,
            shard_factor_fn=shard_factor_fn,
        )

        # --- stage 5: simulate ---
        sim = MemorySimulator(self.allocator_policy,
                              capacity or self.capacity).replay(blocks)
        persistent = sum(b.sharded_size for b in blocks if b.free_t is None
                         and b.block_kind in (BlockKind.PARAM,
                                              BlockKind.OPT_STATE))
        report = EstimateReport(
            peak_bytes=sim.peak_reserved,
            peak_tensor_bytes=sim.peak_allocated,
            persistent_bytes=persistent,
            oom=sim.oom,
            sim=sim,
            breakdown={
                "phase_peaks": phase_peaks(blocks),
                "num_blocks": len(blocks),
                "liveness_peak": peak_live_bytes(blocks),
            },
            wall_time_s=time.perf_counter() - t0,
            num_events=len(fwd_tr.events) + len(upd_tr.events if upd_tr else []),
        )
        return report

    def estimate_serving(self, decode_fn: Callable, params, cache, batch,
                         shard_factor_fn=None,
                         collective_specs: Sequence[CollectiveSpec] = (),
                         capacity: int | None = None) -> EstimateReport:
        """Single-phase estimate for a decode step with a persistent cache."""
        t0 = time.perf_counter()
        tr, tracer = self._trace_phase(
            decode_fn,
            [(params, BlockKind.PARAM, "params"),
             (cache, BlockKind.CACHE, "cache"),
             (batch, BlockKind.INPUT, "batch")],
            Phase.DECODE)
        blocks = reconstruct_lifecycles(tr)
        blocks = self.orchestrator.mark_persistent(
            blocks, kinds=(BlockKind.PARAM, BlockKind.CACHE))
        blocks = self.orchestrator.fold_fused(blocks)
        if shard_factor_fn is not None:
            blocks = self.orchestrator.apply_sharding(blocks, shard_factor_fn)
        sim = MemorySimulator(self.allocator_policy,
                              capacity or self.capacity).replay(blocks)
        return EstimateReport(
            peak_bytes=sim.peak_reserved, peak_tensor_bytes=sim.peak_allocated,
            persistent_bytes=sum(b.sharded_size for b in blocks
                                 if b.free_t is None),
            oom=sim.oom, sim=sim,
            breakdown={"num_blocks": len(blocks)},
            wall_time_s=time.perf_counter() - t0, num_events=len(tr.events))
