"""xMem estimator — the public API tying the pipeline together.

``XMemEstimator.estimate_training`` reproduces the paper's workflow:

1. trace the job's phases on CPU (jaxpr interpretation — zero accelerator
   use, milliseconds even for trillion-parameter configs);
2. reconstruct + classify lifecycles (Analyzer);
3. compose N iterations on one timeline — optimizer state materializes at
   the first update and persists (why the paper analyzes >= 2 iterations;
   we default to 3 like the paper);
4. orchestrate lifecycles (persistence, grad_release, donation, fusion
   folding, collective injection, sharding);
5. replay through the two-level allocator simulation -> peak estimate,
   usage curve, OOM verdict.

The estimator is a *first-class framework feature*: ``launch/train.py``
gates job admission on it, and the sharding engine feeds it per-tensor
shard factors for per-device estimates (the paper's §6.2 extension).

Fast path (ISSUE 1, default ``fastpath=True``):

* per-phase traces are cached (``core/cache.py``) so repeated estimates
  with an unchanged job structure skip ``make_jaxpr`` + interpretation;
* each phase is traced exactly once — abstract output shapes come from
  the trace itself (``make_jaxpr(..., return_shape=True)``) instead of
  separate ``eval_shape`` passes, and the gradient-coupling taint
  analysis reuses the already-traced update jaxpr;
* iterations 2..N-1 are composed as a periodic template
  (``PeriodicBlocks``) instead of per-iteration lifecycle copies —
  composition is O(blocks), independent of N;
* the simulator replays the template with steady-state detection and
  extrapolates once the allocator fingerprint stabilizes (paper §3.1).

``fastpath=False`` preserves the original seed pipeline verbatim; the
equivalence tests (tests/test_fastpath.py) assert both paths produce
identical estimates.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax

from ..obs import spans as obs_spans
from .allocator import AllocatorPolicy, CUDA_CACHING
from .analyzer import classify_blocks, phase_peaks
from .cache import (BlockInfo, GLOBAL_TRACE_CACHE, TraceCache, TracedPhase,
                    trace_key)
from .events import (BlockKind, BlockLifecycle, PeriodicBlocks, Phase,
                     peak_live_bytes, periodic_breakdown_peaks,
                     periodic_breakdown_peaks_fast, reduced_for_breakdown)
from .orchestrator import (CollectiveSpec, MemoryOrchestrator, OffloadPlan,
                           OrchestratorPolicy)
from .simulator import MemorySimulator, SimResult, split_blocks_by_space
from .tracer import trace_fn_with_shape


_EMPTY_TAINT: frozenset = frozenset()


def _taint_region(jaxpr, in_taints, state: dict,
                  const_taints=None) -> list:
    """Propagate per-gradient taint sets through one jaxpr region,
    recursing into call primitives (pjit / remat / custom_* / scan /
    while / cond). A union of more than one gradient index at a *plain*
    primitive marks the update as coupled; unioning at a call-primitive
    boundary does NOT — a ``pjit``-wrapped per-leaf update keeps its
    leaves separate inside the sub-jaxpr, which is where the verdict is
    decided (mis-reporting it as coupled forces all-grads-coexist and
    inflates the estimate). Returns the outvar taints."""
    from jax.extend import core as jcore
    taint: dict = {}
    for v, tt in zip(jaxpr.constvars, const_taints or ()):
        if tt:
            taint[v] = tt
    for v, tt in zip(jaxpr.invars, in_taints):
        if tt:
            taint[v] = tt

    def read(v):
        if isinstance(v, jcore.Literal):
            return _EMPTY_TAINT
        return taint.get(v, _EMPTY_TAINT)

    def closed_parts(j):
        if isinstance(j, jcore.ClosedJaxpr):
            return j.jaxpr, len(j.consts)
        return j, 0

    def run_fixpoint(body, consts_t, carry_t, xs_t, n_carry):
        """Scan/while bodies feed carry outputs back into carry inputs;
        iterate until the carry taints stop growing. Taint sets only
        grow and each pass moves taint at least one carry slot further,
        so the fixpoint arrives within n_carry+1 passes (a chain rotated
        through k carries needs k passes — two would miss couplings
        behind longer chains and underestimate)."""
        inner, n_inner_consts = closed_parts(body)
        carry_t = list(carry_t)
        outs = None
        for _ in range(n_carry + 1):
            outs = _taint_region(
                inner, list(consts_t) + carry_t + list(xs_t), state,
                const_taints=[_EMPTY_TAINT] * n_inner_consts)
            new_carry = [a | b for a, b in zip(carry_t, outs[:n_carry])]
            if new_carry == carry_t:
                break
            carry_t = new_carry
        return carry_t, outs[n_carry:] if outs else []

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        ins = [read(v) for v in eqn.invars]
        if name == "scan":
            p = eqn.params
            nc, ncar = p["num_consts"], p["num_carry"]
            carry_t, ys_t = run_fixpoint(
                p["jaxpr"], ins[:nc], ins[nc:nc + ncar],
                ins[nc + ncar:], ncar)
            out_taints = list(carry_t) + list(ys_t)
        elif name == "while":
            p = eqn.params
            cn, bn = p["cond_nconsts"], p["body_nconsts"]
            carry_t, _ = run_fixpoint(
                p["body_jaxpr"], ins[cn:cn + bn], ins[cn + bn:], (),
                len(ins) - cn - bn)
            # the loop condition runs too: a grad-norm convergence test
            # (`while norm(g) > eps`) unions gradients inside cond_jaxpr
            # — one pass over the converged carry taints catches it
            # (state flags only grow, cond feeds nothing back)
            cond_inner, cond_nc = closed_parts(p["cond_jaxpr"])
            _taint_region(cond_inner, list(ins[:cn]) + list(carry_t),
                          state, const_taints=[_EMPTY_TAINT] * cond_nc)
            out_taints = list(carry_t)
        elif name == "cond":
            branch_ins = ins[1:]
            out_taints = None
            for br in eqn.params["branches"]:
                inner, n_inner_consts = closed_parts(br)
                outs = _taint_region(
                    inner, branch_ins, state,
                    const_taints=[_EMPTY_TAINT] * n_inner_consts)
                out_taints = outs if out_taints is None else [
                    a | b for a, b in zip(out_taints, outs)]
            out_taints = out_taints or []
        else:
            sub = None
            for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                j = eqn.params.get(key)
                if isinstance(j, (jcore.Jaxpr, jcore.ClosedJaxpr)):
                    sub = j
                    break
            if sub is not None:
                inner, n_inner_consts = closed_parts(sub)
                out_taints = _taint_region(
                    inner, ins, state,
                    const_taints=[_EMPTY_TAINT] * n_inner_consts)
            else:
                union: frozenset = _EMPTY_TAINT
                for tt in ins:
                    if tt:
                        union = union | tt
                if len(union) > 1:
                    state["coupling"] = "coupled"
                if union and name == "convert_element_type":
                    iv = eqn.invars[0]
                    ov = eqn.outvars[0]
                    try:
                        if ov.aval.dtype.itemsize > iv.aval.dtype.itemsize:
                            state["upcasts"] = True  # f32 grad copies
                    except AttributeError:
                        pass
                out_taints = [union] * len(eqn.outvars)
        for ov, tt in zip(eqn.outvars, out_taints):
            if tt:
                taint[ov] = tt
    return [read(v) for v in jaxpr.outvars]


def _coupling_from_jaxpr(jaxpr, n_params: int, n_grads: int) -> dict:
    """Taint analysis over a (flat) update jaxpr — see
    ``update_grad_coupling`` for semantics. Recurses into nested call
    primitives: a jitted (pjit-wrapped) tree-mapped per-leaf optimizer
    stays "per_leaf" instead of being mis-unioned at the call boundary.
    """
    in_taints = []
    for i, _v in enumerate(jaxpr.invars):
        if n_params <= i < n_params + n_grads:
            in_taints.append(frozenset({i - n_params}))
        else:
            in_taints.append(_EMPTY_TAINT)
    state = {"coupling": "per_leaf", "upcasts": False}
    _taint_region(jaxpr, in_taints, state)
    return state


def update_grad_coupling(update_fn: Callable, params, grads,
                         opt_state) -> dict:
    """Taint analysis: does the optimizer update *couple* gradients?

    Per-leaf updates (SGD/Adam/... via tree.map) let XLA fuse each leaf's
    update into the backward pass, so gradients die eagerly. Cross-leaf
    coupling (global-norm clipping, Adafactor's global RMS) forces all
    gradients to coexist until the update. Also detects whether gradients
    are upcast to a wider dtype inside the update (f32 working copies —
    they add transient bytes during the optimizer phase).

    Returns {"coupling": "per_leaf"|"coupled", "upcasts": bool}.
    """
    args = (params, grads, opt_state) if opt_state is not None \
        else (params, grads)
    fn = update_fn if opt_state is not None \
        else (lambda p, g: update_fn(p, g, None))
    closed = jax.make_jaxpr(fn)(*args)
    n_params = len(jax.tree_util.tree_leaves(params))
    n_grads = len(jax.tree_util.tree_leaves(grads))
    return _coupling_from_jaxpr(closed.jaxpr, n_params, n_grads)


def flatten_kinds(args_with_kinds: Sequence[tuple]) -> tuple[list, list[BlockKind], list[str]]:
    """Flatten (pytree, kind, name) triples into tracer-aligned lists."""
    flat_args, kinds, scopes = [], [], []
    for tree, kind, name in args_with_kinds:
        leaves, _ = jax.tree_util.tree_flatten(tree)
        flat_args.extend(leaves)
        kinds.extend([kind] * len(leaves))
        scopes.extend([f"{name}[{i}]" for i in range(len(leaves))])
    return flat_args, kinds, scopes


@dataclasses.dataclass
class EstimateReport:
    peak_bytes: int               # reserved segments — THE estimate
    peak_tensor_bytes: int        # live-tensor peak (naive lower bound)
    persistent_bytes: int         # params + opt state + consts
    oom: bool
    sim: SimResult
    breakdown: dict               # per-kind / per-phase summary
    wall_time_s: float
    num_events: int
    cache_stats: dict = dataclasses.field(default_factory=dict)

    def fits(self, capacity: int) -> bool:
        return self.peak_bytes <= capacity


class XMemEstimator:
    """Peak-memory estimator. Target-specific presets:

    * ``XMemEstimator.for_tpu()``   — XLA/TPU target: static buffer
      assignment compacts memory, so the arena policy (reserved ≈ rounded
      live) plus fusion folding and donation model the runtime; this is
      the mode the framework's admission gate uses.
    * ``XMemEstimator.for_torch_gpu()`` — paper-faithful mode: PyTorch
      CUDACachingAllocator simulation, eager semantics (no fusion
      folding, no donation, grads till zero_grad). Used by the
      reproduction benchmarks.
    """

    def __init__(self,
                 allocator_policy: AllocatorPolicy = CUDA_CACHING,
                 orchestrator_policy: OrchestratorPolicy | None = None,
                 iterations: int = 3,
                 scan_unroll_cap: int = 3,
                 capacity: int = 1 << 62,
                 fastpath: bool = True,
                 trace_cache: TraceCache | None = None,
                 engine: str = "auto",
                 checkpoint: Callable[[str], None] | None = None):
        self.allocator_policy = allocator_policy
        self.orchestrator = MemoryOrchestrator(
            orchestrator_policy or OrchestratorPolicy())
        self.iterations = iterations
        self.scan_unroll_cap = scan_unroll_cap
        self.capacity = capacity
        self.fastpath = fastpath
        # replay engine: "auto" -> the columnar/vectorized engine on the
        # fast path, the per-event object interpreter on the reference
        # path (fastpath=False always replays through the object engine —
        # it IS the reference).
        if engine not in ("auto", "object", "columnar"):
            raise ValueError(f"unknown replay engine {engine!r}")
        if not fastpath:
            engine = "object"
        elif engine == "auto":
            engine = "columnar"
        self.engine = engine
        # fastpath estimators share the process-global cache by default so
        # per-decision estimator instances still hit warm traces; the
        # reference path never caches (seed semantics), including when a
        # cache is passed explicitly.
        # NOTE: explicit None check — an empty TraceCache is falsy
        # (__len__), so `trace_cache or GLOBAL_TRACE_CACHE` would
        # silently discard a fresh user-supplied cache
        self.trace_cache = ((GLOBAL_TRACE_CACHE if trace_cache is None
                             else trace_cache) if fastpath else None)
        # optional stage-boundary hook ("tracer" before a real trace,
        # "replay" before the allocator replay). The admission service
        # routes fault injection through it (ISSUE 6); None costs one
        # attribute test per stage and changes nothing.
        self.checkpoint = checkpoint

    @classmethod
    def for_tpu(cls, **kw) -> "XMemEstimator":
        from .allocator import TPU_ARENA
        kw.setdefault("allocator_policy", TPU_ARENA)
        kw.setdefault("orchestrator_policy", OrchestratorPolicy(
            grad_release="auto", donate_params=True, donate_opt_state=True,
            fusion_folding=True))
        return cls(**kw)

    def calibrate(self, samples: Sequence[tuple],
                  quantile: float = 0.9) -> float:
        """Fit the backend transient-scale constant from (job_kwargs,
        truth_bytes) pairs — the explicit version of the paper's Fig-6
        calibration loop. Model-independent: one constant per backend.

        ``quantile`` targets one-sided error: a scheduler pays far more
        for an underestimate (round-2 OOM, the PEF/MCP penalty of
        Eq. 5-7) than for slight headroom, so the default skews high —
        the same asymmetry the paper's allocator rounding induces.

        Each sample is ((fwd_bwd, params, batch, update_fn, opt_init_fn),
        truth). Returns the fitted scale (also applied to self)."""
        import numpy as _np
        ratios = []
        for (fwd_bwd, params, batch, update_fn, opt_init_fn), truth \
                in samples:
            rep = self.estimate_training(fwd_bwd, params, batch,
                                         update_fn=update_fn,
                                         opt_init_fn=opt_init_fn)
            t_est = rep.peak_tensor_bytes - rep.persistent_bytes
            t_true = truth - rep.persistent_bytes
            if t_est > 0 and t_true > 0:
                ratios.append(t_true / t_est)
        scale = float(_np.quantile(ratios, quantile)) if ratios else 1.0
        self.orchestrator.policy = dataclasses.replace(
            self.orchestrator.policy, transient_scale=scale)
        return scale

    @classmethod
    def for_torch_gpu(cls, grad_release: str = "at_update",
                      **kw) -> "XMemEstimator":
        kw.setdefault("allocator_policy", CUDA_CACHING)
        kw.setdefault("orchestrator_policy", OrchestratorPolicy(
            grad_release=grad_release, donate_params=False,
            donate_opt_state=False, fusion_folding=False))
        return cls(**kw)

    # -- phase tracing (fast path: cached TracedPhase entries) -----------------
    def _trace_phase(self, fn, args_with_kinds, phase,
                     out_kind_fn: Callable | None = None,
                     tag: str = "") -> TracedPhase:
        flat, kinds, scopes = flatten_kinds(args_with_kinds)
        treedefs = tuple(jax.tree_util.tree_structure(t)
                         for t, _, _ in args_with_kinds)
        cache = self.trace_cache
        key = None
        if cache is not None:
            key = trace_key(fn, tag, flat, treedefs, kinds,
                            self.scan_unroll_cap, phase)
            hit = cache.get(fn, key)
            if hit is not None:
                return hit
        if self.checkpoint is not None:
            self.checkpoint("tracer")

        def flat_fn(*leaves):
            idx, rebuilt = 0, []
            for tree, _, _ in args_with_kinds:
                leaves_i, treedef = jax.tree_util.tree_flatten(tree)
                n = len(leaves_i)
                rebuilt.append(jax.tree_util.tree_unflatten(
                    treedef, leaves[idx:idx + n]))
                idx += n
            return fn(*rebuilt)

        with obs_spans.span("estimator.trace", phase=str(phase),
                            tag=tag):
            trace, tr, out_shape, closed = trace_fn_with_shape(
                flat_fn, *flat, arg_kinds=kinds, arg_scopes=scopes,
                scan_unroll_cap=self.scan_unroll_cap, phase=phase)
        out_kinds = out_kind_fn(out_shape) if out_kind_fn is not None else None
        kind_by_bid = {}
        if out_kinds is not None:
            for b, k in zip(tr.output_blocks, out_kinds):
                b.kind = k
                kind_by_bid[b.bid] = k
        if kind_by_bid:
            # push reassigned kinds back into the recorded alloc events
            # (only outputs change post-trace; inputs are kinded at birth).
            # The trace is columnar-backed: rewrite the kind column in one
            # searchsorted sweep, plus any already-materialized event
            # objects so both views agree.
            import numpy as np
            from .events import KIND_CODE, LazyEvents
            cols = trace.columnar()
            bids = np.fromiter(kind_by_bid, np.int64, len(kind_by_bid))
            codes = np.fromiter((KIND_CODE[k] for k in kind_by_bid.values()),
                                np.uint8, len(kind_by_bid))
            order = np.argsort(bids)
            bids, codes = bids[order], codes[order]
            pos = np.searchsorted(bids, cols.block_id)
            pos_c = np.minimum(pos, len(bids) - 1)
            hit = bids[pos_c] == cols.block_id
            cols.block_kind[hit] = codes[pos_c[hit]]
            ev = trace.events
            materialized = (ev._mat if isinstance(ev, LazyEvents) else ev)
            if materialized is not None:
                for e in materialized:
                    k = kind_by_bid.get(e.block_id)
                    if k is not None:
                        e.block_kind = k
        entry = TracedPhase(
            trace=trace,
            lifecycles=tuple(tr.lifecycles()),
            input_blocks=tuple(BlockInfo(b.bid, b.size, b.kind, b.shape)
                               for b in tr.input_blocks),
            output_blocks=tuple(BlockInfo(b.bid, b.size, b.kind, b.shape)
                                for b in tr.output_blocks),
            out_shape=out_shape,
            closed_jaxpr=closed,
            arg_leaf_counts=tuple(
                len(jax.tree_util.tree_leaves(t))
                for t, _, _ in args_with_kinds),
        )
        if cache is not None:
            cache.put(fn, key, entry)
        return entry

    @staticmethod
    def _expand_out_kinds(example_out, kind_map: Callable) -> list[BlockKind]:
        leaves = jax.tree_util.tree_leaves(example_out)
        return [kind_map(i, len(leaves)) for i in range(len(leaves))]

    # -- periodic composition (fast path) --------------------------------------
    def _compose_periodic(self, fwd: TracedPhase, upd: TracedPhase | None,
                          init: TracedPhase | None
                          ) -> tuple[PeriodicBlocks, dict]:
        """Stitch phase traces into an N-iteration timeline in O(blocks).

        Iterations {0, 1, N-1} are materialized concretely; iterations
        2..N-2 are exact shifted copies of iteration 1 represented by the
        (cycle, n_cycles, period) template. The last iteration stays
        concrete because grad-release has no next iteration to free into.
        """
        N = self.iterations
        cursor = 0
        next_bid = [0]
        update_start: dict[int, int] = {}
        bwd_start: dict[int, int] = {}
        iteration_ends: dict[int, int] = {}

        def fresh_bid() -> int:
            next_bid[0] += 1
            return next_bid[0]

        def place(entry: TracedPhase, it: int, phase: Phase, target: list,
                  output_kind: BlockKind | None = None) -> None:
            nonlocal cursor
            input_bids = {b.bid for b in entry.input_blocks}
            output_bids = ({b.bid for b in entry.output_blocks}
                           if output_kind is not None else ())
            cur = cursor
            bid = next_bid[0]
            append = target.append
            for lc in entry.lifecycles:
                lcb = lc.block_id
                if lcb in input_bids:
                    continue
                ft = lc.free_t
                bid += 1
                append(BlockLifecycle(
                    bid, lc.size, lc.alloc_t + cur,
                    None if ft is None else ft + cur, it, phase, lc.op,
                    lc.scope,
                    output_kind if lcb in output_bids else lc.block_kind,
                    lc.shard_factor, lc.shape))
            next_bid[0] = bid
            cursor = cur + len(entry.trace.events) + 1

        def one_iteration(it: int, target: list, with_init: bool) -> None:
            nonlocal cursor
            for b in fwd.input_blocks:
                if b.kind is BlockKind.INPUT and b.size > 0:
                    target.append(BlockLifecycle(
                        fresh_bid(), b.size, cursor, None, it, Phase.DATA,
                        "host_to_device", "batch", BlockKind.INPUT,
                        1.0, b.shape))
            cursor += 1
            bwd_start[it] = cursor
            place(fwd, it, Phase.FORWARD_BACKWARD, target)
            update_start[it] = cursor
            if with_init and init is not None:
                place(init, it, Phase.OPTIMIZER, target,
                      output_kind=BlockKind.OPT_STATE)
            if upd is not None:
                place(upd, it, Phase.OPTIMIZER, target,
                      output_kind=BlockKind.OUTPUT)
            iteration_ends[it] = cursor

        prefix: list[BlockLifecycle] = []
        cycle: list[BlockLifecycle] = []
        suffix: list[BlockLifecycle] = []

        # t=0: persistent parameter blocks (one per leaf, from fwd inputs)
        for b in fwd.input_blocks:
            if b.kind is BlockKind.PARAM and b.size > 0:
                prefix.append(BlockLifecycle(
                    fresh_bid(), b.size, 0, None, 0, Phase.INIT,
                    "init", "params", BlockKind.PARAM, 1.0, b.shape))
        cursor += 1

        one_iteration(0, prefix, with_init=True)
        period = 0
        cycle_start = cursor
        if N >= 3:
            one_iteration(1, cycle, with_init=False)
            period = cursor - cycle_start
            # iterations 2..N-2 are implicit template replicas; synthetic
            # next-iteration keys let grad_release="at_next_iter" and
            # output release resolve the template's frees one period
            # ahead (shift-consistent for every replica, including the
            # one feeding the last iteration)
            update_start[2] = update_start[1] + period
            iteration_ends[2] = iteration_ends[1] + period
            cursor = cycle_start + (N - 2) * period
        if N >= 2:
            one_iteration(N - 1, suffix, with_init=False)

        n_cycles = max(N - 2, 0)
        meta = dict(iteration_ends=iteration_ends,
                    update_start=update_start, bwd_start=bwd_start,
                    horizon=cursor + 2, cycle_start=cycle_start,
                    period=period, n_cycles=n_cycles)
        pb = PeriodicBlocks(prefix, cycle, n_cycles, period, suffix,
                            meta={"cycle_start": cycle_start})
        return pb, meta

    # -- composition (reference/seed path) -------------------------------------
    def _compose_reference(self, fwd: TracedPhase, upd: TracedPhase | None,
                           init: TracedPhase | None
                           ) -> tuple[list[BlockLifecycle], dict]:
        """Seed composition: every iteration materialized concretely."""
        blocks: list[BlockLifecycle] = []
        cursor = 0
        iteration_ends: dict[int, int] = {}
        update_start: dict[int, int] = {}
        bwd_start: dict[int, int] = {}
        next_bid = [0]

        def fresh_bid():
            next_bid[0] += 1
            return next_bid[0]

        def place(entry: TracedPhase, it, phase, output_kind=None):
            nonlocal cursor
            input_bids = {b.bid for b in entry.input_blocks}
            output_bids = {b.bid for b in entry.output_blocks}
            placed = []
            # the seed re-derived lifecycles from the event stream on
            # every placement; kept verbatim so this path stays an honest
            # baseline (the fast path reuses the phase's precomputed
            # lifecycles instead)
            from .analyzer import reconstruct_lifecycles
            for lc in reconstruct_lifecycles(entry.trace):
                if lc.block_id in input_bids:
                    continue
                kind = lc.block_kind
                if lc.block_id in output_bids and output_kind is not None:
                    kind = output_kind
                free_t = lc.free_t + cursor if lc.free_t is not None else None
                placed.append(dataclasses.replace(
                    lc, block_id=fresh_bid(), alloc_t=lc.alloc_t + cursor,
                    free_t=free_t, iteration=it, phase=phase,
                    block_kind=kind))
            cursor += len(entry.trace.events) + 1
            return placed

        # t=0: persistent parameter blocks (one per leaf, from fwd inputs)
        for b in fwd.input_blocks:
            if b.kind is BlockKind.PARAM and b.size > 0:
                blocks.append(BlockLifecycle(
                    fresh_bid(), b.size, 0, None, 0, Phase.INIT,
                    "init", "params", BlockKind.PARAM, 1.0, b.shape))
        cursor += 1

        for it in range(self.iterations):
            # batch data arrives
            for b in fwd.input_blocks:
                if b.kind is BlockKind.INPUT and b.size > 0:
                    blocks.append(BlockLifecycle(
                        fresh_bid(), b.size, cursor, None, it, Phase.DATA,
                        "host_to_device", "batch", BlockKind.INPUT,
                        1.0, b.shape))
            cursor += 1
            bwd_start[it] = cursor
            blocks.extend(place(fwd, it, Phase.FORWARD_BACKWARD))
            update_start[it] = cursor
            if it == 0 and init is not None:
                # optimizer state materializes at the first update
                blocks.extend(place(init, it, Phase.OPTIMIZER,
                                    output_kind=BlockKind.OPT_STATE))
            if upd is not None:
                blocks.extend(place(upd, it, Phase.OPTIMIZER,
                                    output_kind=BlockKind.OUTPUT))
            iteration_ends[it] = cursor
        bwd_start[self.iterations] = cursor + 1  # sentinel for last grads
        meta = dict(iteration_ends=iteration_ends, update_start=update_start,
                    bwd_start=bwd_start, horizon=cursor + 2)
        return blocks, meta

    # -- public API ------------------------------------------------------------------
    def estimate_training(self,
                          fwd_bwd_fn: Callable,     # (params, batch) -> (loss, grads)
                          params, batch,
                          update_fn: Callable | None = None,  # (params, grads, opt_state) -> (params, opt_state)
                          opt_init_fn: Callable | None = None,  # params -> opt_state
                          shard_factor_fn=None,
                          collective_specs: Sequence[CollectiveSpec] = (),
                          capacity: int | None = None) -> EstimateReport:
        t0 = time.perf_counter()
        _policy_before = self.orchestrator.policy  # restored at the end
        impl = (self._estimate_training if self.fastpath
                else self._estimate_training_reference)
        try:
            return impl(fwd_bwd_fn, params, batch, update_fn, opt_init_fn,
                        shard_factor_fn, collective_specs, capacity, t0)
        finally:
            self.orchestrator.policy = _policy_before

    def trace_phases(self, fwd_bwd_fn, params, batch, update_fn=None,
                     opt_init_fn=None, fwd: TracedPhase | None = None
                     ) -> tuple[TracedPhase, TracedPhase | None,
                                TracedPhase | None]:
        """Stage 1: per-phase CPU traces (cached). Passing ``fwd`` skips
        the forward trace — the sweep service enters here with an
        interpolated forward phase and still gets the optimizer phases
        resolved (normally cache hits, they are batch-independent)."""
        def fwd_out_kinds(out_shape):
            n_out = len(jax.tree_util.tree_leaves(out_shape))
            n_loss = len(jax.tree_util.tree_leaves(out_shape[0])) \
                if isinstance(out_shape, tuple) else 1
            return [BlockKind.OUTPUT] * n_loss + \
                   [BlockKind.GRAD] * (n_out - n_loss)

        if fwd is None:
            fwd = self._trace_phase(
                fwd_bwd_fn,
                [(params, BlockKind.PARAM, "params"),
                 (batch, BlockKind.INPUT, "batch")],
                Phase.FORWARD_BACKWARD, out_kind_fn=fwd_out_kinds, tag="fwd")
        fwd_out_shape = fwd.out_shape

        init = upd = None
        opt_state = None
        if opt_init_fn is not None:
            init = self._trace_phase(
                opt_init_fn, [(params, BlockKind.PARAM, "params")],
                Phase.OPTIMIZER,
                out_kind_fn=lambda s: [BlockKind.OPT_STATE] * len(
                    jax.tree_util.tree_leaves(s)),
                tag="init")
            opt_state = init.out_shape
        if update_fn is not None:
            grads = fwd_out_shape[1] if isinstance(fwd_out_shape, tuple) \
                else fwd_out_shape
            upd_args = [(params, BlockKind.PARAM, "params"),
                        (grads, BlockKind.GRAD, "grads")]
            if opt_state is not None:
                upd_args.append((opt_state, BlockKind.OPT_STATE, "opt_state"))
            upd = self._trace_phase(update_fn, upd_args, Phase.OPTIMIZER,
                                    tag="upd")
        return fwd, upd, init

    def _estimate_training(self, fwd_bwd_fn, params, batch, update_fn,
                           opt_init_fn, shard_factor_fn, collective_specs,
                           capacity, t0) -> EstimateReport:
        cache = self.trace_cache
        h0 = cache.hits if cache is not None else 0
        m0 = cache.misses if cache is not None else 0

        # --- stage 1: CPU traces (paper: profile first iterations) ---
        fwd, upd, init = self.trace_phases(fwd_bwd_fn, params, batch,
                                           update_fn, opt_init_fn)

        cache_stats = {}
        if cache is not None:
            cache_stats = {"hits": cache.hits - h0,
                           "misses": cache.misses - m0,
                           "global": cache.stats()}
        return self.estimate_from_phases(
            fwd, upd, init, shard_factor_fn=shard_factor_fn,
            collective_specs=collective_specs, capacity=capacity, t0=t0,
            cache_stats=cache_stats)

    def estimate_from_phases(self, fwd: TracedPhase,
                             upd: TracedPhase | None = None,
                             init: TracedPhase | None = None, *,
                             shard_factor_fn=None,
                             collective_specs: Sequence[CollectiveSpec] = (),
                             capacity: int | None = None,
                             t0: float | None = None,
                             cache_stats: dict | None = None
                             ) -> EstimateReport:
        """Stages 2-5 (compose, classify, orchestrate, simulate) from
        already-traced phases. ``estimate_training`` lands here after
        stage 1; the sweep service (``core/sweep.py``) enters directly
        with cached or interpolated ``TracedPhase`` entries — including
        from pool workers, where no JAX tracing must happen."""
        if t0 is None:
            t0 = time.perf_counter()
        _policy_before = self.orchestrator.policy
        try:
            return self._estimate_from_phases(
                fwd, upd, init, shard_factor_fn, collective_specs,
                capacity, t0, cache_stats or {})
        finally:
            self.orchestrator.policy = _policy_before

    def _estimate_from_phases(self, fwd, upd, init, shard_factor_fn,
                              collective_specs, capacity, t0,
                              cache_stats) -> EstimateReport:
        # --- stage 2+3: analyze & compose iterations (periodic) ---
        pb, meta = self._compose_periodic(fwd, upd, init)
        concrete = pb.prefix + pb.cycle + pb.suffix
        param_sizes = frozenset(
            b.size for b in fwd.input_blocks if b.kind is BlockKind.PARAM)
        concrete = classify_blocks(concrete, param_sizes)

        # --- stage 4: orchestrate ---
        phase_bounds = {}
        for it, end in meta["iteration_ends"].items():
            if it not in meta["bwd_start"]:
                continue   # synthetic template key (fast path), not a
                           # concretely composed iteration
            phase_bounds[(it, Phase.FORWARD_BACKWARD.value)] = (
                meta["bwd_start"][it], meta["update_start"][it])
            phase_bounds[(it, Phase.OPTIMIZER.value)] = (
                meta["update_start"][it], end)
        # Resolve "auto" grad release: per-leaf updates fuse into the
        # backward under XLA (eager grad death); coupled updates (global
        # clipping etc.) keep every grad alive until the optimizer phase.
        if self.orchestrator.policy.grad_release == "auto":
            mode = "eager_fused"
            upcasts = False
            if upd is not None:
                # reuse the already-traced flat update jaxpr (its invars
                # are params|grads|opt_state leaves in flatten order) —
                # no extra make_jaxpr; verdict memoized on the entry
                if upd.coupling is None:
                    upd.coupling = _coupling_from_jaxpr(
                        upd.closed_jaxpr.jaxpr,
                        upd.arg_leaf_counts[0], upd.arg_leaf_counts[1])
                info = upd.coupling
                mode = "eager_fused" if info["coupling"] == "per_leaf" \
                    else "at_update"
                upcasts = info["upcasts"]
            self.orchestrator.policy = dataclasses.replace(
                self.orchestrator.policy, grad_release=mode,
                optimizer_upcast_coexist=(
                    self.orchestrator.policy.optimizer_upcast_coexist
                    and upcasts))

        # grad_release="at_next_iter" frees iteration i's gradients only
        # when iteration i+1's update completes new ones — the
        # grad-accumulation / zero_grad-at-start idiom (paper Fig 1 POS1);
        # hence update_start is passed as the next-iteration release point.
        concrete = self.orchestrator.run(
            concrete,
            iteration_ends=meta["iteration_ends"],
            update_start=meta["update_start"],
            next_bwd_start=meta["update_start"],
            collective_specs=collective_specs,
            phase_bounds=phase_bounds,
            num_iterations=self.iterations,
            shard_factor_fn=shard_factor_fn,
        )
        # host-offload rewrite (separate pass so run == run_unfused holds);
        # only *concretely composed* iterations get staging blocks — the
        # synthetic template keys (e.g. update_start[2] on the fast path)
        # are release markers, not iterations that exist in the timeline
        offload_stats = None
        opolicy = self.orchestrator.policy
        if opolicy.offload is not None and opolicy.offload.enabled:
            us_concrete = {it: t for it, t in meta["update_start"].items()
                           if it in meta["bwd_start"]}
            concrete, offload_stats = self.orchestrator.apply_offload(
                concrete, us_concrete, meta["iteration_ends"])

        # --- stage 5: simulate ---
        num_events = (len(fwd.trace.events)
                      + (len(upd.trace.events) if upd else 0)
                      + (len(init.trace.events) if init else 0))
        if self.checkpoint is not None:
            self.checkpoint("replay")
        sim_runner = MemorySimulator(self.allocator_policy,
                                     capacity or self.capacity,
                                     engine=self.engine)
        N = self.iterations
        prefix = [b for b in concrete if b.iteration == 0]
        cyc = [b for b in concrete if b.iteration == 1] if N >= 3 else []
        suffix = ([b for b in concrete if b.iteration == N - 1]
                  if N >= 2 else [])
        pb = PeriodicBlocks(prefix, cyc, pb.n_cycles, pb.period, suffix,
                            meta=pb.meta)
        with obs_spans.span("estimator.replay", engine=self.engine,
                            num_blocks=pb.num_blocks):
            sim = sim_runner.replay_spaces(pb)
        is_cycle = (lambda b: N >= 3 and b.iteration == 1)
        persistent = sum(
            b.sharded_size * (pb.n_cycles if is_cycle(b) else 1)
            for b in concrete
            if b.free_t is None and b.block_kind in (
                BlockKind.PARAM, BlockKind.OPT_STATE))
        # peaks computed on a bounded-replica reduction when middle
        # iterations carry no net bytes — O(blocks), independent of N;
        # the vectorized sweep is output-identical to the dict-based one.
        # Under offload the per-kind/per-phase breakdown describes the
        # *device* composition (what the capacity verdict is about).
        bd_pb = pb
        if offload_stats is not None:
            from .events import MemorySpace
            bd_pb = split_blocks_by_space(pb).get(
                MemorySpace.DEVICE_HBM,
                PeriodicBlocks([], [], pb.n_cycles, pb.period, [],
                               dict(pb.meta)))
        liveness_peak, phase_pk = periodic_breakdown_peaks_fast(
            reduced_for_breakdown(bd_pb))
        breakdown = {
            "phase_peaks": phase_pk,
            "num_blocks": pb.num_blocks,
            "liveness_peak": liveness_peak,
        }
        if offload_stats is not None:
            breakdown["space_peaks"] = sim.stats.get("space_peaks", {})
            breakdown["offload"] = offload_stats
        composition = pb
        report = EstimateReport(
            peak_bytes=sim.peak_reserved,
            peak_tensor_bytes=sim.peak_allocated,
            persistent_bytes=persistent,
            oom=sim.oom,
            sim=sim,
            breakdown=breakdown,
            wall_time_s=time.perf_counter() - t0,
            num_events=num_events,
            cache_stats=cache_stats or {},
        )
        report.composition = composition   # for capacity probing
        # min_feasible_capacity may reuse report.sim as its instrumented
        # probe, but only when this replay ran unconstrained
        report.sim_unbounded = (capacity or self.capacity) >= (1 << 62)
        return report

    def _estimate_training_reference(self, fwd_bwd_fn, params, batch,
                                     update_fn, opt_init_fn,
                                     shard_factor_fn, collective_specs,
                                     capacity, t0) -> EstimateReport:
        """Seed pipeline, preserved verbatim as the slow reference:
        separate ``eval_shape`` passes, a fresh coupling re-trace, fully
        materialized N-iteration composition, full event replay. The
        fast path must match it bit-for-bit on every estimate field
        (tests/test_fastpath.py)."""
        opolicy = self.orchestrator.policy
        if opolicy.offload is not None and opolicy.offload.enabled:
            raise NotImplementedError(
                "host offload needs the fast path (fastpath=True): the "
                "reference pipeline is frozen at seed semantics and has "
                "no multi-space replay")
        # --- stage 1: CPU traces (paper: profile first iterations) ---
        fwd_out_shape = jax.eval_shape(fwd_bwd_fn, params, batch)
        n_out = len(jax.tree_util.tree_leaves(fwd_out_shape))
        n_loss = len(jax.tree_util.tree_leaves(fwd_out_shape[0])) \
            if isinstance(fwd_out_shape, tuple) else 1
        fwd_out_kinds = [BlockKind.OUTPUT] * n_loss + \
                        [BlockKind.GRAD] * (n_out - n_loss)
        fwd = self._trace_phase(
            fwd_bwd_fn,
            [(params, BlockKind.PARAM, "params"),
             (batch, BlockKind.INPUT, "batch")],
            Phase.FORWARD_BACKWARD,
            out_kind_fn=lambda _s: fwd_out_kinds, tag="fwd")

        init = upd = None
        opt_state = None
        if opt_init_fn is not None:
            opt_state = jax.eval_shape(opt_init_fn, params)
            init = self._trace_phase(
                opt_init_fn, [(params, BlockKind.PARAM, "params")],
                Phase.OPTIMIZER,
                out_kind_fn=lambda _s: [BlockKind.OPT_STATE] * len(
                    jax.tree_util.tree_leaves(opt_state)),
                tag="init")
        if update_fn is not None:
            grads = fwd_out_shape[1] if isinstance(fwd_out_shape, tuple) \
                else fwd_out_shape
            upd_args = [(params, BlockKind.PARAM, "params"),
                        (grads, BlockKind.GRAD, "grads")]
            if opt_state is not None:
                upd_args.append((opt_state, BlockKind.OPT_STATE, "opt_state"))
            upd = self._trace_phase(update_fn, upd_args, Phase.OPTIMIZER,
                                    tag="upd")

        # --- stage 2+3: analyze & compose iterations ---
        blocks, meta = self._compose_reference(fwd, upd, init)
        param_sizes = frozenset(
            b.size for b in fwd.input_blocks if b.kind is BlockKind.PARAM)
        # frozen seed classifier: the baseline must not drift as the
        # shared analyzer gets optimized (same output, seed cost profile)
        classified = []
        for b in blocks:
            kind = b.block_kind
            if kind in (BlockKind.ACTIVATION, BlockKind.TEMP):
                in_bwd = any(m in b.scope for m in ("transpose", "backward"))
                if in_bwd and b.size in param_sizes:
                    kind = BlockKind.GRAD
            classified.append(dataclasses.replace(b, block_kind=kind))
        blocks = classified

        # --- stage 4: orchestrate ---
        phase_bounds = {}
        for it, end in meta["iteration_ends"].items():
            phase_bounds[(it, Phase.FORWARD_BACKWARD.value)] = (
                meta["bwd_start"][it], meta["update_start"][it])
            phase_bounds[(it, Phase.OPTIMIZER.value)] = (
                meta["update_start"][it], end)
        if self.orchestrator.policy.grad_release == "auto":
            mode = "eager_fused"
            upcasts = False
            if update_fn is not None:
                grads_shape = fwd_out_shape[1] \
                    if isinstance(fwd_out_shape, tuple) else fwd_out_shape
                info = update_grad_coupling(
                    update_fn, params, grads_shape, opt_state)
                mode = "eager_fused" if info["coupling"] == "per_leaf" \
                    else "at_update"
                upcasts = info["upcasts"]
            self.orchestrator.policy = dataclasses.replace(
                self.orchestrator.policy, grad_release=mode,
                optimizer_upcast_coexist=(
                    self.orchestrator.policy.optimizer_upcast_coexist
                    and upcasts))

        # frozen seed pass order (fold after the lifecycle passes) —
        # output-identical to the orchestrator's current fold-first
        # ``run``, kept verbatim so the baseline's cost profile is stable
        o = self.orchestrator
        blocks = o.mark_persistent(blocks)
        blocks = o.batch_per_iteration(blocks, meta["iteration_ends"])
        blocks = o.release_gradients(blocks, meta["update_start"],
                                     meta["update_start"])
        blocks = o.inject_optimizer_upcasts(blocks, meta["update_start"],
                                            meta["iteration_ends"])
        blocks = o.apply_donation(blocks)
        if o.policy.release_outputs_next_iter:
            blocks = o.release_step_outputs(blocks, meta["iteration_ends"])
        blocks = o.fold_fused(blocks)
        blocks = o.apply_transient_scale(blocks)
        if collective_specs and phase_bounds:
            blocks = o.inject_collectives(blocks, collective_specs,
                                          phase_bounds, self.iterations,
                                          shard_factor_fn)
        if shard_factor_fn is not None:
            blocks = o.apply_sharding(blocks, shard_factor_fn)

        # --- stage 5: simulate ---
        sim = MemorySimulator(self.allocator_policy,
                              capacity or self.capacity).replay(blocks)
        persistent = sum(b.sharded_size for b in blocks if b.free_t is None
                         and b.block_kind in (BlockKind.PARAM,
                                              BlockKind.OPT_STATE))
        report = EstimateReport(
            peak_bytes=sim.peak_reserved,
            peak_tensor_bytes=sim.peak_allocated,
            persistent_bytes=persistent,
            oom=sim.oom,
            sim=sim,
            breakdown={
                "phase_peaks": phase_peaks(blocks),
                "num_blocks": len(blocks),
                "liveness_peak": peak_live_bytes(blocks),
            },
            wall_time_s=time.perf_counter() - t0,
            num_events=(len(fwd.trace.events)
                        + (len(upd.trace.events) if upd else 0)
                        + (len(init.trace.events) if init else 0)),
        )
        report.composition = blocks
        report.sim_unbounded = (capacity or self.capacity) >= (1 << 62)
        return report

    # -- capacity probing -------------------------------------------------------
    def min_feasible_capacity(self, fwd_bwd_fn, params, batch,
                              update_fn=None, opt_init_fn=None,
                              shard_factor_fn=None,
                              collective_specs=(),
                              report: EstimateReport | None = None) -> int:
        """Smallest device capacity the job fits in, from one instrumented
        replay (plus bounded verification) — see
        ``MemorySimulator.min_feasible_capacity``. Passing an existing
        ``report`` reuses its composition and unbounded replay."""
        if report is None or getattr(report, "composition", None) is None:
            report = self.estimate_training(
                fwd_bwd_fn, params, batch, update_fn=update_fn,
                opt_init_fn=opt_init_fn, shard_factor_fn=shard_factor_fn,
                collective_specs=collective_specs)
        sim_runner = MemorySimulator(self.allocator_policy, 1 << 62,
                                     engine=self.engine)
        probe = (report.sim
                 if getattr(report, "sim_unbounded", False)
                 and not report.sim.oom else None)
        # under offload the capacity question is about device HBM only —
        # the probe stays valid because replay_spaces' primary result IS
        # the device sub-composition's replay
        comp = report.composition
        groups = split_blocks_by_space(comp)
        if len(groups) > 1:
            from .events import MemorySpace
            comp = groups.get(MemorySpace.DEVICE_HBM, [])
        return sim_runner.min_feasible_capacity(comp, probe=probe)

    def estimate_serving(self, decode_fn: Callable, params, cache, batch,
                         shard_factor_fn=None,
                         collective_specs: Sequence[CollectiveSpec] = (),
                         capacity: int | None = None) -> EstimateReport:
        """Single-phase estimate for a decode step with a persistent cache."""
        t0 = time.perf_counter()
        entry = self._trace_phase(
            decode_fn,
            [(params, BlockKind.PARAM, "params"),
             (cache, BlockKind.CACHE, "cache"),
             (batch, BlockKind.INPUT, "batch")],
            Phase.DECODE, tag="decode")
        blocks = list(entry.lifecycles)
        blocks = self.orchestrator.mark_persistent(
            blocks, kinds=(BlockKind.PARAM, BlockKind.CACHE))
        blocks = self.orchestrator.fold_fused(blocks)
        if shard_factor_fn is not None:
            blocks = self.orchestrator.apply_sharding(blocks, shard_factor_fn)
        sim = MemorySimulator(self.allocator_policy,
                              capacity or self.capacity,
                              engine=self.engine).replay(blocks)
        return EstimateReport(
            peak_bytes=sim.peak_reserved, peak_tensor_bytes=sim.peak_allocated,
            persistent_bytes=sum(b.sharded_size for b in blocks
                                 if b.free_t is None),
            oom=sim.oom, sim=sim,
            breakdown={"num_blocks": len(blocks)},
            wall_time_s=time.perf_counter() - t0,
            num_events=len(entry.trace.events))

    def estimate_request_stream(self, decode_fn: Callable, params, cache,
                                batch, *, stream, knobs=None,
                                kv_bytes_per_token: int,
                                resident_bytes_per_request: int = 0,
                                base_dtype_bytes: int = 2,
                                shard_factor_fn=None,
                                capacity: int | None = None
                                ) -> "ServingEstimate":
        """Estimate a serving runtime over a request-driven timeline.

        Two CPU-side components compose additively:

        * **step working set** — the decode step is traced once via the
          SAME trace key as :meth:`estimate_serving` (so a knob sweep
          over page size / concurrency / KV dtype re-lowers the request
          stream but never re-traces); its transient peak (activations
          above params+cache) is scaled batch-linearly from the traced
          batch to ``knobs.max_concurrent`` — decode activations are
          per-sequence, so the linear model is exact for attention-free
          layers and a documented upper bound for the rest;
        * **paged KV pressure** — the request stream is lowered by the
          continuous-batching scheduler to page-granular allocations
          and replayed through the allocator simulator exactly (no
          approximation: join/extend/leave/evict at the tick each
          happens).

        ``worst_case_peak_bytes`` is what the admission gate must trust;
        ``steady_state_peak_bytes`` (median live paged bytes) is what a
        capacity planner provisions for sustained load.
        """
        from .orchestrator import (ContinuousBatchingScheduler,
                                   ServingKnobs)
        t0 = time.perf_counter()
        knobs = knobs or ServingKnobs()
        entry = self._trace_phase(
            decode_fn,
            [(params, BlockKind.PARAM, "params"),
             (cache, BlockKind.CACHE, "cache"),
             (batch, BlockKind.INPUT, "batch")],
            Phase.DECODE, tag="decode")
        blocks = list(entry.lifecycles)
        blocks = self.orchestrator.mark_persistent(
            blocks, kinds=(BlockKind.PARAM, BlockKind.CACHE))
        blocks = self.orchestrator.fold_fused(blocks)
        if shard_factor_fn is not None:
            blocks = self.orchestrator.apply_sharding(blocks,
                                                      shard_factor_fn)
        step_sim = MemorySimulator(self.allocator_policy, self.capacity,
                                   engine=self.engine).replay(blocks)
        persistent_all = sum(b.sharded_size for b in blocks
                             if b.free_t is None)
        params_bytes = sum(b.sharded_size for b in blocks
                           if b.free_t is None
                           and b.block_kind == BlockKind.PARAM)
        transient = max(step_sim.peak_allocated - persistent_all, 0)
        traced_batch = _leading_dim(batch)
        transient_scaled = -(-transient * knobs.max_concurrent
                             // max(traced_batch, 1))

        sched = ContinuousBatchingScheduler(knobs)
        rb = sched.lower(stream, kv_bytes_per_token,
                         resident_bytes_per_request=resident_bytes_per_request,
                         base_dtype_bytes=base_dtype_bytes)
        paged_sim = MemorySimulator(self.allocator_policy, self.capacity,
                                    engine=self.engine).replay(rb)
        live = [v for v in rb.meta["live_paged"] if v > 0]
        live.sort()
        paged_steady = live[len(live) // 2] if live else 0
        tok_b = rb.meta["kv_bytes_per_token"]
        monolithic = knobs.max_concurrent * (
            stream.max_seq_len * tok_b + int(resident_bytes_per_request))

        worst = params_bytes + transient_scaled + paged_sim.peak_reserved
        steady = params_bytes + transient_scaled + paged_steady
        cap = capacity if capacity is not None else self.capacity
        return ServingEstimate(
            steady_state_peak_bytes=int(steady),
            worst_case_peak_bytes=int(worst),
            persistent_bytes=int(params_bytes),
            step_transient_bytes=int(transient_scaled),
            paged_kv_peak_bytes=int(paged_sim.peak_reserved),
            paged_kv_steady_bytes=int(paged_steady),
            monolithic_cache_bytes=int(monolithic),
            oom=worst > cap,
            sim=paged_sim,
            breakdown={
                "num_blocks": rb.num_blocks,
                "ticks": rb.meta["ticks"],
                "evictions": rb.meta["evictions"],
                "max_occupancy": max(rb.meta["occupancy"], default=0),
                "page_bytes": rb.meta["page_bytes"],
                "knobs": rb.meta["knobs"],
            },
            wall_time_s=time.perf_counter() - t0,
            num_events=len(entry.trace.events) + 2 * rb.num_blocks)


def _leading_dim(tree) -> int:
    """Batch size of a traced decode input: leading dim of the first
    array leaf (1 for scalars/empty trees)."""
    for leaf in jax.tree_util.tree_leaves(tree):
        shape = getattr(leaf, "shape", ())
        if shape:
            return int(shape[0])
    return 1


@dataclasses.dataclass
class ServingEstimate:
    """Serving-runtime estimate over a request-mix timeline.

    Unlike :class:`EstimateReport`'s single number, serving capacity has
    two operating points: the worst-case peak (admission gate — must fit
    or the server OOMs under the scripted burst) and the steady-state
    median (provisioning — what sustained load actually holds). The
    paged-vs-monolithic pair quantifies what paged attention buys."""

    steady_state_peak_bytes: int
    worst_case_peak_bytes: int
    persistent_bytes: int         # params (sharded) — always resident
    step_transient_bytes: int     # decode working set at max_concurrent
    paged_kv_peak_bytes: int      # allocator peak of the paged stream
    paged_kv_steady_bytes: int    # median live paged bytes
    monolithic_cache_bytes: int   # max_concurrent x max_seq full cache
    oom: bool
    sim: SimResult
    breakdown: dict
    wall_time_s: float
    num_events: int

    def fits(self, capacity: int) -> bool:
        return self.worst_case_peak_bytes <= capacity

    def to_json(self) -> dict:
        return {
            "steady_state_peak_bytes": self.steady_state_peak_bytes,
            "worst_case_peak_bytes": self.worst_case_peak_bytes,
            "persistent_bytes": self.persistent_bytes,
            "step_transient_bytes": self.step_transient_bytes,
            "paged_kv_peak_bytes": self.paged_kv_peak_bytes,
            "paged_kv_steady_bytes": self.paged_kv_steady_bytes,
            "monolithic_cache_bytes": self.monolithic_cache_bytes,
            "oom": self.oom,
            "breakdown": {k: v for k, v in self.breakdown.items()
                          if k != "knobs"},
            "knobs": self.breakdown.get("knobs", {}),
        }
