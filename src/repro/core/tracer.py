"""Dynamic memory tracing of JAX programs on CPU (paper stage 1, adapted).

The paper profiles the first training iterations with the PyTorch profiler
and reconstructs the memory-event stream. In JAX the program *is* data —
a jaxpr — so we obtain the same dynamic event stream by interpreting the
jaxpr of the step function eqn-by-eqn in execution order:

* each equation's outputs become ``alloc`` events sized by their avals;
* refcount liveness (uses remaining) emits ``free`` events at last use —
  exactly the alloc/free interleaving an eager executor would produce;
* layer/operator attribution comes structurally from ``name_stack``
  (the paper needs time-window heuristics because traces lack linkage;
  we keep that fallback in ``analyzer.py`` for external traces).

Control flow is handled like an executor would:
* ``scan``/``while``  — stacked loop outputs are allocated up-front (XLA
  preallocates loop outputs), then the body is unrolled for
  ``min(length, unroll_cap)`` iterations. Allocator state stabilizes
  within 2–3 iterations — the same observation the paper makes about
  training iterations (§3.1 fn. 2) applies to loop bodies, which is what
  makes a small cap sound.
* ``cond``            — the branch with the largest memory footprint is
  traced (conservative for peak estimation).
* ``pjit``/``remat``/``custom_*`` — inlined.

No computation is performed: tracing a trillion-parameter step costs
milliseconds and zero accelerator involvement — the paper's "zero
target-GPU overhead" requirement, kept intact.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.extend import core as jcore

try:  # DropVar is not re-exported via jax.extend.core
    from jax._src.core import DropVar as _DropVar
except ImportError:  # pragma: no cover - future-proofing
    _DropVar = ()

from .events import (KIND_CODE, PHASE_CODE, BlockKind, ColumnarTrace,
                     Phase, StringInterner, Trace)

# Primitive param keys that hold sub-jaxprs to inline.
_CALL_JAXPR_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


def aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(math.prod(shape)) * dtype.itemsize if len(shape) else dtype.itemsize


def aval_shape(aval) -> tuple | None:
    """Dims of an aval as a plain int tuple (None when shapeless)."""
    shape = getattr(aval, "shape", None)
    if shape is None:
        return None
    return tuple(int(d) for d in shape)


@dataclasses.dataclass
class _Block:
    bid: int
    size: int
    refs: int
    pinned: bool = False
    kind: BlockKind = BlockKind.TEMP
    freed: bool = False
    # lifecycle bookkeeping so lifecycles need no event re-pairing
    alloc_t: int = 0
    free_t: int | None = None
    op: str = ""
    scope: str = ""
    shape: tuple | None = None    # producing aval dims (sharding input)


class JaxprMemoryTracer:
    """Interprets a jaxpr into an ordered stream of MemoryEvents."""

    def __init__(self, scan_unroll_cap: int = 3, phase: Phase = Phase.FORWARD_BACKWARD,
                 iteration: int = 0):
        self.cap = scan_unroll_cap
        self.phase = phase
        self.iteration = iteration
        # Events are emitted straight into primitive columns (the
        # ColumnarTrace SoA layout) — MemoryEvent objects materialize
        # lazily, only if a consumer iterates trace.events.
        self._ev_kind: list[int] = []    # 1 = alloc, 0 = free
        self._ev_bid: list[int] = []
        self._ev_size: list[int] = []
        self._ev_t: list[int] = []
        self._ev_op: list[int] = []
        self._ev_scope: list[int] = []
        self._ev_bkind: list[int] = []
        self._ev_shape: list[int] = []
        self._ops = StringInterner()
        self._scopes = StringInterner()
        self._shapes = StringInterner([None])
        self.t = 0
        self._next_bid = 0
        self.blocks: dict[int, _Block] = {}
        self.input_blocks: list[_Block] = []
        self.output_blocks: list[_Block] = []

    @property
    def num_events(self) -> int:
        return len(self._ev_kind)

    # ---- block machinery -------------------------------------------------
    def _new_block(self, size: int, refs: int, op: str, scope: str,
                   kind: BlockKind, pinned: bool = False,
                   shape: tuple | None = None) -> _Block:
        b = _Block(self._next_bid, size, refs, pinned, kind,
                   alloc_t=self.t, op=op, scope=scope, shape=shape)
        self._next_bid += 1
        self.blocks[b.bid] = b
        self._ev_kind.append(1)
        self._ev_bid.append(b.bid)
        self._ev_size.append(size)
        self._ev_t.append(self.t)
        self._ev_op.append(self._ops.intern(op))
        self._ev_scope.append(self._scopes.intern(scope))
        self._ev_bkind.append(KIND_CODE[kind])
        self._ev_shape.append(self._shapes.intern(shape))
        self.t += 1
        return b

    def _retain(self, b: _Block, n: int) -> None:
        b.refs += n

    def _release(self, b: _Block, n: int = 1, op: str = "", scope: str = "") -> None:
        b.refs -= n
        if b.refs <= 0 and not b.pinned and not b.freed:
            b.freed = True
            b.free_t = self.t
            self._ev_kind.append(0)
            self._ev_bid.append(b.bid)
            self._ev_size.append(b.size)
            self._ev_t.append(self.t)
            self._ev_op.append(self._ops.intern(op))
            self._ev_scope.append(self._scopes.intern(scope))
            self._ev_bkind.append(KIND_CODE[b.kind])
            self._ev_shape.append(self._shapes.intern(b.shape))
            self.t += 1

    # ---- use counting ------------------------------------------------------
    @staticmethod
    def _use_counts(jaxpr: jcore.Jaxpr) -> dict:
        counts: dict[Any, int] = defaultdict(int)
        for eqn in jaxpr.eqns:
            for v in eqn.invars:
                if isinstance(v, jcore.Var):
                    counts[v] += 1
        for v in jaxpr.outvars:
            if isinstance(v, jcore.Var):
                counts[v] += 1
        return counts

    # ---- region interpretation ---------------------------------------------
    def _interpret_region(self, jaxpr: jcore.Jaxpr, bindings: Sequence[_Block],
                          consts: Sequence[_Block] = ()) -> list[_Block]:
        """Interpret a jaxpr with invars bound to existing blocks.

        Contract: caller's blocks are retained by their internal use count
        (pre-paid); returned outvar blocks carry one ref per outvar
        occurrence which the caller must dispose of.
        """
        counts = self._use_counts(jaxpr)
        env: dict[Any, _Block] = {}
        for v, b in zip(jaxpr.constvars, consts):
            env[v] = b
            self._retain(b, counts.get(v, 0))
        for v, b in zip(jaxpr.invars, bindings):
            env[v] = b
            self._retain(b, counts.get(v, 0))

        def read(v) -> _Block | None:
            if isinstance(v, jcore.Literal):
                return None
            return env.get(v)

        for eqn in jaxpr.eqns:
            scope = self._scope_of(eqn)
            op = eqn.primitive.name
            sub = self._sub_jaxpr(eqn)
            if eqn.primitive.name == "scan":
                out_blocks = self._do_scan(eqn, read, counts, scope)
            elif eqn.primitive.name == "while":
                out_blocks = self._do_while(eqn, read, counts, scope)
            elif eqn.primitive.name == "cond":
                out_blocks = self._do_cond(eqn, read, counts, scope)
            elif sub is not None:
                if isinstance(sub, jcore.ClosedJaxpr):
                    inner, const_vals = sub.jaxpr, sub.consts
                else:
                    inner, const_vals = sub, []
                const_blocks = [
                    self._new_block(int(getattr(c, "nbytes", 0) or 0), 1,
                                    "const", scope, BlockKind.TEMP)
                    for c in const_vals
                ]
                args = [read(v) or self._literal_block(v, scope)
                        for v in eqn.invars]
                out_blocks = self._interpret_region(inner, args, const_blocks)
                for cb in const_blocks:
                    self._release(cb, 1, op, scope)
            else:
                # plain primitive: allocate outputs, sized by avals
                out_blocks = []
                for ov in eqn.outvars:
                    n_uses = counts.get(ov, 0)
                    if isinstance(ov, _DropVar) or n_uses == 0:
                        out_blocks.append(None)
                        continue
                    out_blocks.append(self._new_block(
                        aval_bytes(ov.aval), n_uses, op, scope,
                        BlockKind.ACTIVATION, shape=aval_shape(ov.aval)))

            # bind outvars; region results need ref adjustment to use counts
            if sub is not None or eqn.primitive.name in ("scan", "while", "cond"):
                adjusted = []
                for ov, b in zip(eqn.outvars, out_blocks):
                    if b is None:
                        adjusted.append(None)
                        continue
                    n_uses = counts.get(ov, 0)
                    if isinstance(ov, _DropVar) or n_uses == 0:
                        self._release(b, 1, op, scope)
                        adjusted.append(None)
                        continue
                    self._retain(b, n_uses - 1)  # had 1 ownership ref
                    adjusted.append(b)
                out_blocks = adjusted

            for ov, b in zip(eqn.outvars, out_blocks):
                if b is not None and not isinstance(ov, _DropVar):
                    env[ov] = b

            # consume inputs (one release per occurrence — last use frees)
            for v in eqn.invars:
                b = read(v)
                if b is not None:
                    self._release(b, 1, op, scope)

        outs = []
        for v in jaxpr.outvars:
            if isinstance(v, jcore.Literal):
                outs.append(self._literal_block(v, "out"))
            else:
                outs.append(env[v])
        return outs

    def _literal_block(self, v, scope: str) -> _Block:
        # Literals are scalars embedded in the program — never materialized
        # as device buffers, so they carry zero size in the trace.
        return self._new_block(0, 1, "literal", scope, BlockKind.TEMP)

    # ---- control-flow handlers -------------------------------------------------
    def _do_scan(self, eqn, read, counts, scope) -> list[_Block]:
        p = eqn.params
        body: jcore.ClosedJaxpr = p["jaxpr"]
        length, n_const, n_carry = p["length"], p["num_consts"], p["num_carry"]
        inner = body.jaxpr
        in_blocks = [read(v) or self._literal_block(v, scope) for v in eqn.invars]
        consts = in_blocks[:n_const]
        carry = in_blocks[n_const:n_const + n_carry]
        xs = in_blocks[n_const + n_carry:]
        k = max(1, min(length, self.cap))

        # XLA preallocates stacked loop outputs (ys) before the loop runs.
        ys_vars = eqn.outvars[n_carry:]
        ys_blocks: list[_Block | None] = []
        for ov in ys_vars:
            if isinstance(ov, _DropVar):
                ys_blocks.append(None)
            else:
                ys_blocks.append(self._new_block(
                    aval_bytes(ov.aval), 1, "scan_ys", scope,
                    BlockKind.ACTIVATION, shape=aval_shape(ov.aval)))

        # _interpret_region is self-balancing on its bindings (it retains
        # internal uses itself), so consts need no pre-pay across
        # iterations. The per-iteration dynamic-slice of xs is consumption
        # *we* invent, so pre-pay one ref per simulated iteration.
        for b in xs:
            self._retain(b, k)

        owned_carry: list[_Block] | None = None
        cur_carry = carry
        for it in range(k):
            x_slices = []
            for xb, xv in zip(xs, inner.invars[n_const + n_carry:]):
                sl = self._new_block(aval_bytes(xv.aval), 1, "dynamic_slice",
                                     scope, BlockKind.ACTIVATION,
                                     shape=aval_shape(xv.aval))
                self._release(xb, 1, "dynamic_slice", scope)
                x_slices.append(sl)
            # body invars are [operand-consts..., carry..., x-slices...]
            body_out = self._interpret_region(
                inner, list(consts) + list(cur_carry) + x_slices,
                [self._new_block(getattr(c, "nbytes", 0), 1, "const", scope,
                                 BlockKind.TEMP) for c in body.consts])
            # x slices were consumed inside the body (pre-paid); drop our ref
            for sl in x_slices:
                self._release(sl, 1, "scan", scope)
            new_carry = body_out[:n_carry]
            y_out = body_out[n_carry:]
            # y slices are copied into the preallocated ys buffers
            for yb in y_out:
                if yb is not None:
                    self._release(yb, 1, "scan_ys_write", scope)
            # previous iteration's carry ownership is dropped
            if owned_carry is not None:
                for ob in owned_carry:
                    if ob not in new_carry:
                        self._release(ob, 1, "scan_carry", scope)
            owned_carry = new_carry
            cur_carry = new_carry

        out = list(cur_carry) + ys_blocks
        # carries produced by the body already carry an ownership ref; the
        # *initial* carries (k could be 0-trip in theory) are caller-owned,
        # so give them an extra ref to match the region contract.
        if owned_carry is None:
            for b in cur_carry:
                self._retain(b, 1)
        return out

    def _do_while(self, eqn, read, counts, scope) -> list[_Block]:
        p = eqn.params
        body: jcore.ClosedJaxpr = p["body_jaxpr"]
        cond: jcore.ClosedJaxpr = p["cond_jaxpr"]
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        in_blocks = [read(v) or self._literal_block(v, scope) for v in eqn.invars]
        body_consts = in_blocks[cn:cn + bn]
        carry = in_blocks[cn + bn:]
        k = max(1, self.cap)
        inner = body.jaxpr
        owned = None
        cur = carry
        for _ in range(k):
            out = self._interpret_region(
                inner, list(body_consts) + list(cur),
                [self._new_block(getattr(c, "nbytes", 0), 1, "const", scope,
                                 BlockKind.TEMP) for c in body.consts])
            if owned is not None:
                for ob in owned:
                    if ob not in out:
                        self._release(ob, 1, "while_carry", scope)
            owned = out
            cur = out
        if owned is None:
            for b in cur:
                self._retain(b, 1)
        return list(cur)

    def _do_cond(self, eqn, read, counts, scope) -> list[_Block]:
        branches = eqn.params["branches"]

        def footprint(br):
            return sum(aval_bytes(ov.aval) for e in br.jaxpr.eqns
                       for ov in e.outvars)

        br = max(branches, key=footprint)
        in_blocks = [read(v) or self._literal_block(v, scope)
                     for v in eqn.invars[1:]]  # drop predicate
        # release the predicate's eqn-level use happens in the epilogue
        return self._interpret_region(
            br.jaxpr, in_blocks,
            [self._new_block(getattr(c, "nbytes", 0), 1, "const", scope,
                             BlockKind.TEMP) for c in br.consts])

    # ---- helpers ------------------------------------------------------------
    @staticmethod
    def _sub_jaxpr(eqn):
        for key in _CALL_JAXPR_KEYS:
            if key in eqn.params:
                j = eqn.params[key]
                if isinstance(j, (jcore.Jaxpr, jcore.ClosedJaxpr)):
                    return j
        return None

    @staticmethod
    def _scope_of(eqn) -> str:
        try:
            return str(eqn.source_info.name_stack)
        except Exception:
            return ""

    def lifecycles(self):
        """BlockLifecycle records straight from the tracer's blocks —
        equivalent to ``reconstruct_lifecycles(trace)`` (alloc order is
        bid order; pinned/unfreed blocks are persistent) without
        re-pairing the event stream."""
        from .events import BlockLifecycle
        return [BlockLifecycle(b.bid, b.size, b.alloc_t, b.free_t,
                               self.iteration, self.phase, b.op, b.scope,
                               b.kind, 1.0, b.shape)
                for b in self.blocks.values()]

    # ---- top-level API --------------------------------------------------------
    def trace_closed_jaxpr(self, closed: jcore.ClosedJaxpr,
                           arg_kinds: Sequence[BlockKind] | None = None,
                           arg_scopes: Sequence[str] | None = None) -> Trace:
        jaxpr = closed.jaxpr
        counts = self._use_counts(jaxpr)
        const_blocks = []
        for c in closed.consts:
            b = self._new_block(int(getattr(c, "nbytes", 0)), 1, "const",
                                "consts", BlockKind.PARAM, pinned=True,
                                shape=aval_shape(c))
            const_blocks.append(b)
        in_blocks = []
        for i, v in enumerate(jaxpr.invars):
            kind = (arg_kinds[i] if arg_kinds is not None else BlockKind.INPUT)
            scope = (arg_scopes[i] if arg_scopes is not None else f"arg{i}")
            b = self._new_block(aval_bytes(v.aval), counts.get(v, 0), "input",
                                scope, kind, pinned=True,
                                shape=aval_shape(v.aval))
            in_blocks.append(b)
        self.input_blocks = in_blocks
        outs = self._interpret_region(jaxpr, in_blocks, const_blocks)
        for b in outs:
            if b is not None:
                b.pinned = True
                b.kind = b.kind if b.kind != BlockKind.ACTIVATION else BlockKind.OUTPUT
        self.output_blocks = [b for b in outs if b is not None]
        n = self.num_events
        # space column: the jaxpr interpreter only ever allocates device
        # memory — offload passes (orchestrator) rewrite spaces later
        columns = ColumnarTrace.from_columns(
            self._ev_kind, self._ev_bid, self._ev_size, self._ev_t,
            np.full(n, self.iteration, dtype=np.int64),
            np.full(n, PHASE_CODE[self.phase], dtype=np.uint8),
            self._ev_op, self._ev_scope, self._ev_bkind,
            self._ops.table, self._scopes.table,
            self._ev_shape, self._shapes.table,
            np.zeros(n, dtype=np.uint8))
        return Trace.from_columnar(columns, num_iterations=1,
                                   meta={"phase": self.phase.value})


def trace_fn(fn: Callable, *args, arg_kinds=None, arg_scopes=None,
             scan_unroll_cap: int = 3, phase: Phase = Phase.FORWARD_BACKWARD,
             iteration: int = 0, **kwargs) -> tuple[Trace, JaxprMemoryTracer]:
    """Trace ``fn(*args)`` into a memory-event stream.

    ``arg_kinds``/``arg_scopes`` are flat lists aligned with the flattened
    arguments (see ``estimator.flatten_kinds``).
    """
    trace, tr, _, _ = trace_fn_with_shape(
        fn, *args, arg_kinds=arg_kinds, arg_scopes=arg_scopes,
        scan_unroll_cap=scan_unroll_cap, phase=phase, iteration=iteration,
        **kwargs)
    return trace, tr


def trace_fn_with_shape(fn: Callable, *args, arg_kinds=None, arg_scopes=None,
                        scan_unroll_cap: int = 3,
                        phase: Phase = Phase.FORWARD_BACKWARD,
                        iteration: int = 0, **kwargs
                        ) -> tuple[Trace, JaxprMemoryTracer, Any, Any]:
    """``trace_fn`` plus the abstract output pytree and the closed jaxpr.

    The single ``make_jaxpr(..., return_shape=True)`` call replaces the
    separate ``eval_shape`` passes the estimator's slow path needs — one
    trace per phase instead of two (estimation fast path, ISSUE 1).
    """
    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args, **kwargs)
    tr = JaxprMemoryTracer(scan_unroll_cap=scan_unroll_cap, phase=phase,
                           iteration=iteration)
    trace = tr.trace_closed_jaxpr(closed, arg_kinds, arg_scopes)
    return trace, tr, out_shape, closed
