"""Failure-domain-aware fleet scheduling driven by the estimator
(ISSUE 7 tentpole).

The paper's admission service answers "does this job fit this device?";
this package asks the fleet-shaped question — *which* device, shared
with whom, and what happens when that device dies mid-run:

* :mod:`repro.sched.fleet` — :class:`Node` / :class:`Fleet` model with
  failure domains and the hard co-location invariant (co-resident safe
  thresholds never exceed capacity; any violation anywhere raises
  :class:`~repro.service.faults.ChaosSafetyViolation`);
* :mod:`repro.sched.scheduler` — :class:`FleetScheduler`: estimator-
  driven best-fit bin-packing with domain spreading, priority
  preemption, counter-offer backfill into fragmentation holes, and
  evacuation (fail / flap / shrink / straggler drain) that re-admits
  displaced jobs through ``train.elastic.shrink_and_replan`` and the
  remediation planner;
* :mod:`repro.sched.simulator` — :class:`FleetSimulator`: tick-driven
  chaos replay of thousands of arrivals with interleaved fleet events,
  scored by the two-round metrics plus fragmentation / evacuation
  latency / lost-vs-re-placed.
"""
from .fleet import (Assignment, Fleet, Node, NODE_DOWN,  # noqa: F401
                    NODE_DRAINED, NODE_UP)
from .scheduler import (EvacuationOutcome, FleetScheduler,  # noqa: F401
                        PlacementOutcome)
from .simulator import FleetOutcome, FleetSimulator, build_fleet  # noqa: F401
