"""Fleet model: heterogeneous nodes, failure domains, and the one
invariant everything else defends (ISSUE 7).

A :class:`Fleet` is a set of :class:`Node`\\ s — each with an HBM
capacity, a device class, and a failure domain — plus the set of live
:class:`Assignment`\\ s. An assignment charges each node it touches the
job's per-device **safe threshold** (Eq. 5: the estimate validated as a
max-runnable-memory cap — for degraded decisions that is the
margin-widened value), never the raw peak. The co-location invariant

    sum(co-resident safe thresholds on node n) <= capacity(n)

is enforced at **every** mutation: ``place`` refuses an over-commit
with :class:`~repro.service.faults.ChaosSafetyViolation` before any
state changes, and ``check_invariant`` re-verifies the whole fleet
after each fail / shrink / restore, so no scheduler bug — initial
placement, backfill, preemption, or post-evacuation re-placement — can
ever leave a device over-committed.

Failure semantics: ``fail`` takes a node down and returns every
displaced assignment (a multi-device assignment is displaced whole —
a job cannot run on half its mesh); ``shrink`` reduces a node's
*effective* capacity in place (partial HBM loss / MIG re-slice) and
evicts largest-share residents until the survivors fit; ``drain``
keeps the node up but unplaceable (straggler migration); ``restore``
brings a down/drained node back at its nominal capacity.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Iterable

from ..service.faults import ChaosSafetyViolation

NODE_UP = "up"
NODE_DOWN = "down"
NODE_DRAINED = "drained"


@dataclasses.dataclass(frozen=True)
class Node:
    """One schedulable device (a GPU host's accelerator)."""

    node_id: str
    capacity: int = 16 * 2**30      # nominal HBM bytes
    device: str = "sim"             # device class (jobs match on this)
    domain: str = "rack0"           # failure domain (spread target)


@dataclasses.dataclass
class Assignment:
    """One placed job: which nodes it occupies and what each is charged.

    ``shares`` maps node_id -> charged bytes; single-device jobs have
    one entry, mesh jobs one per device, each charged the per-device
    safe threshold. ``mesh`` keeps the (pod, data, model) carve so an
    evacuation can re-enter ``train.elastic.shrink_and_replan`` from
    the placement that just died."""

    job_id: str
    shares: dict
    priority: int = 0
    family: str = "workload"
    source: str = "decide"          # decide|counter-offer|evacuation|...
    topology: str | None = None     # mesh label for multi-device jobs
    mesh: tuple | None = None       # (pod, data, model) of the placement
    placed_tick: int = 0
    truth_bytes: int | None = None  # oracle peak (whole job, as placed)
    arrival: Any = None             # originating JobArrival (re-placement)
    ctx: Any = None                 # PlanContext (elastic re-planning)

    @property
    def total_bytes(self) -> int:
        return sum(self.shares.values())

    @property
    def n_devices(self) -> int:
        return len(self.shares)


class Fleet:
    """Thread-safe fleet state; see module docstring for the invariant."""

    def __init__(self, nodes: Iterable[Node]):
        nodes = list(nodes)
        ids = [n.node_id for n in nodes]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate node ids in {ids}")
        if not nodes:
            raise ValueError("a fleet needs at least one node")
        self.nodes: dict[str, Node] = {n.node_id: n for n in nodes}
        self._capacity = {n.node_id: int(n.capacity) for n in nodes}
        self._state = {n.node_id: NODE_UP for n in nodes}
        self.assignments: dict[str, Assignment] = {}
        self._resident: dict[str, set] = {n.node_id: set() for n in nodes}
        self._lock = threading.RLock()

    # -- queries -------------------------------------------------------------
    def node_ids(self) -> list[str]:
        return list(self.nodes)

    def state(self, node_id: str) -> str:
        return self._state[node_id]

    def is_up(self, node_id: str) -> bool:
        return self._state[node_id] == NODE_UP

    def up_nodes(self, device: str | None = None) -> list[str]:
        """Placeable nodes, optionally restricted to a device class."""
        with self._lock:
            return [nid for nid, n in self.nodes.items()
                    if self._state[nid] == NODE_UP
                    and (device is None or n.device == device)]

    def capacity_of(self, node_id: str) -> int:
        """Effective capacity (post-shrink), not the nominal one."""
        return self._capacity[node_id]

    def committed(self, node_id: str) -> int:
        with self._lock:
            return sum(self.assignments[j].shares[node_id]
                       for j in self._resident[node_id])

    def headroom(self, node_id: str) -> int:
        with self._lock:
            return self._capacity[node_id] - self.committed(node_id)

    def residents(self, node_id: str) -> list[Assignment]:
        with self._lock:
            return [self.assignments[j]
                    for j in sorted(self._resident[node_id])]

    def holes(self, device: str | None = None,
              empty_only: bool = False) -> list[tuple[str, int]]:
        """(node_id, headroom) of placeable nodes, largest hole first.
        ``empty_only`` restricts to nodes with no residents — the
        no-co-location baseline's placement rule."""
        with self._lock:
            out = []
            for nid in self.up_nodes(device):
                if empty_only and self._resident[nid]:
                    continue
                h = self.headroom(nid)
                if h > 0:
                    out.append((nid, h))
            out.sort(key=lambda p: (-p[1], p[0]))
            return out

    def fragmentation(self, device: str | None = None) -> float:
        """1 - largest free hole / total free bytes over up nodes: 0.0
        when all free memory is one contiguous (single-node) hole, ->1
        as the same total shatters across many small holes."""
        with self._lock:
            free = [self.headroom(nid) for nid in self.up_nodes(device)]
            free = [f for f in free if f > 0]
            total = sum(free)
            if total <= 0:
                return 0.0
            return 1.0 - max(free) / total

    def utilization(self) -> float:
        with self._lock:
            cap = sum(self._capacity[nid] for nid in self.up_nodes())
            if cap <= 0:
                return 0.0
            used = sum(self.committed(nid) for nid in self.up_nodes())
            return used / cap

    # -- mutation (every path defends the invariant) -------------------------
    def place(self, a: Assignment) -> None:
        """Commit an assignment. Raises :class:`ChaosSafetyViolation`
        (before any state changes) if any touched node would be
        over-committed, down, or drained — the scheduler-bug backstop
        behind every placement path."""
        with self._lock:
            if a.job_id in self.assignments:
                raise ValueError(f"job {a.job_id!r} is already placed")
            if not a.shares:
                raise ValueError("assignment with no shares")
            for nid, share in a.shares.items():
                if nid not in self.nodes:
                    raise KeyError(f"unknown node {nid!r}")
                if self._state[nid] != NODE_UP:
                    raise ChaosSafetyViolation(
                        f"placement of {a.job_id!r} on "
                        f"{self._state[nid]} node {nid!r}")
                if share < 0:
                    raise ValueError("negative share")
                if self.committed(nid) + share > self._capacity[nid]:
                    raise ChaosSafetyViolation(
                        f"placing {a.job_id!r} would commit "
                        f"{self.committed(nid) + share} > capacity "
                        f"{self._capacity[nid]} on node {nid!r}")
            self.assignments[a.job_id] = a
            for nid in a.shares:
                self._resident[nid].add(a.job_id)
            self.check_invariant()

    def remove(self, job_id: str) -> Assignment | None:
        with self._lock:
            a = self.assignments.pop(job_id, None)
            if a is not None:
                for nid in a.shares:
                    self._resident[nid].discard(job_id)
            return a

    def fail(self, node_id: str) -> list[Assignment]:
        """Node loss: mark down, displace every assignment touching it
        (multi-device assignments are displaced whole)."""
        with self._lock:
            self._state[node_id] = NODE_DOWN
            displaced = [self.remove(j)
                         for j in sorted(self._resident[node_id])]
            self.check_invariant()
            return [a for a in displaced if a is not None]

    def drain(self, node_id: str) -> list[Assignment]:
        """Straggler migration: keep the node up but unplaceable and
        displace its residents so the scheduler can move them."""
        with self._lock:
            self._state[node_id] = NODE_DRAINED
            displaced = [self.remove(j)
                         for j in sorted(self._resident[node_id])]
            self.check_invariant()
            return [a for a in displaced if a is not None]

    def shrink(self, node_id: str, frac: float) -> list[Assignment]:
        """Partial capacity loss: effective capacity *= ``frac``; evict
        largest-share residents until the survivors fit (each eviction
        displaces the whole assignment). The invariant holds on exit."""
        if not 0.0 <= frac <= 1.0:
            raise ValueError(f"shrink_frac must be in [0, 1], got {frac}")
        with self._lock:
            self._capacity[node_id] = int(self._capacity[node_id] * frac)
            displaced = []
            while (self._resident[node_id]
                   and self.committed(node_id) > self._capacity[node_id]):
                victim = max(self._resident[node_id],
                             key=lambda j: (
                                 self.assignments[j].shares[node_id], j))
                displaced.append(self.remove(victim))
            self.check_invariant()
            return displaced

    def restore(self, node_id: str) -> None:
        """Bring a down/drained node back at its nominal capacity."""
        with self._lock:
            self._state[node_id] = NODE_UP
            self._capacity[node_id] = int(self.nodes[node_id].capacity)
            self.check_invariant()

    def check_invariant(self) -> None:
        """Full-fleet verification: no node over-committed, no resident
        on a non-up node. Raises :class:`ChaosSafetyViolation`."""
        with self._lock:
            for nid in self.nodes:
                committed = self.committed(nid)
                if committed > self._capacity[nid]:
                    raise ChaosSafetyViolation(
                        f"node {nid!r} over-committed: {committed} > "
                        f"{self._capacity[nid]}")
                if self._state[nid] != NODE_UP and self._resident[nid]:
                    raise ChaosSafetyViolation(
                        f"{self._state[nid]} node {nid!r} still hosts "
                        f"{sorted(self._resident[nid])}")

    def snapshot(self) -> dict:
        """JSON-safe fleet state (daemon ``place``/``evacuate`` kinds)."""
        with self._lock:
            return {
                "nodes": {nid: {
                    "state": self._state[nid],
                    "capacity": self._capacity[nid],
                    "nominal_capacity": self.nodes[nid].capacity,
                    "device": self.nodes[nid].device,
                    "domain": self.nodes[nid].domain,
                    "committed": self.committed(nid),
                    "residents": sorted(self._resident[nid]),
                } for nid in self.nodes},
                "jobs": len(self.assignments),
                "fragmentation": self.fragmentation(),
                "utilization": self.utilization(),
            }
