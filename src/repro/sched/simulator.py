"""Fleet-scale chaos replay (ISSUE 7): thousands of arrivals through
the :class:`~repro.sched.scheduler.FleetScheduler` with node failures,
flaps, and capacity shrinks interleaved mid-stream.

Each arrival is one *tick*. Per tick the simulator (in order) restores
flapped nodes whose outage elapsed, polls the fault plan's fleet event
sites (``node.fail`` / ``node.flap`` / ``node.shrink``) and evacuates
the struck node, releases jobs whose ``duration_ticks`` elapsed, feeds
synthetic step times to the straggler detector (when given a
``step_time_fn``) and periodically migrates flagged nodes, then places
the arrival.

Scoring reuses the two-round machinery (``core/metrics.py``) exactly as
:class:`~repro.service.cluster.ClusterSimulator` does — a placed job is
an admit scored against the device capacity, a lost job is scored as a
rejection (so losing a *feasible* job costs the full ``-capacity``
round-1 penalty) — plus fleet-level metrics: fragmentation, evacuation
latency, and jobs lost vs. re-placed. Because every placement path ends
in :meth:`Fleet.place`, a single over-commit anywhere aborts the replay
with :class:`~repro.service.faults.ChaosSafetyViolation`; a completed
replay therefore certifies zero violations by construction.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

from ..core import metrics
from ..obs.metrics import Histogram
from ..service.cluster import JobArrival, score
from ..service.faults import FLEET_SITES
from .fleet import Fleet, Node
from .scheduler import EvacuationOutcome, FleetScheduler, PlacementOutcome


def build_fleet(n_nodes: int, capacity: int = 16 * 2**30, *,
                device: str = "sim", domains: int = 4,
                prefix: str = "node") -> Fleet:
    """Homogeneous fleet helper: ``n_nodes`` nodes striped round-robin
    across ``domains`` failure domains."""
    return Fleet(Node(node_id=f"{prefix}{i:03d}", capacity=capacity,
                      device=device, domain=f"rack{i % domains}")
                 for i in range(n_nodes))


@dataclasses.dataclass
class FleetOutcome:
    """Placements + evacuations + two-round records + fleet summary."""

    placements: list[PlacementOutcome]
    evacuations: list[EvacuationOutcome]
    records: list[metrics.RunRecord]
    summary: dict

    @property
    def displaced_accounted(self) -> bool:
        """True when every job an evacuation displaced is accounted —
        re-placed somewhere or explicitly reported lost."""
        return all(len(e.displaced) == len(e.replaced) + len(e.lost)
                   for e in self.evacuations)


class FleetSimulator:
    """Replays an arrival trace through a fleet scheduler under chaos."""

    def __init__(self, scheduler: FleetScheduler,
                 truth_fn: Callable | None = None):
        self.scheduler = scheduler
        self.truth_fn = truth_fn

    def replay(self, arrivals: Sequence[JobArrival], *, faults=None,
               deadline_s: float | None = None,
               step_time_fn: Callable[[str, int], float] | None = None,
               migrate_every: int = 32) -> FleetOutcome:
        """Replay the trace; ``faults`` (a ``FaultPlan``) is injected
        into the admission service for the duration — its tracer/replay/
        store sites degrade estimates as usual while its fleet event
        sites kill, flap, and shrink nodes mid-stream. ``step_time_fn``
        (node_id, tick) -> seconds drives the straggler detector;
        flagged nodes are drained and migrated every ``migrate_every``
        ticks."""
        service = self.scheduler.service
        if faults is not None:
            with service.inject_faults(faults):
                return self._replay(arrivals, faults, deadline_s,
                                    step_time_fn, migrate_every)
        return self._replay(arrivals, None, deadline_s, step_time_fn,
                            migrate_every)

    def _replay(self, arrivals, faults, deadline_s, step_time_fn,
                migrate_every) -> FleetOutcome:
        sched = self.scheduler
        fleet = sched.fleet
        if deadline_s is not None and sched.deadline_s is None:
            sched.deadline_s = deadline_s
        t0 = time.perf_counter()
        placements: list[PlacementOutcome] = []
        evacuations: list[EvacuationOutcome] = []
        records: list[metrics.RunRecord] = []
        flap_restore: dict[str, int] = {}   # node_id -> restore tick
        depart_at: dict[str, int] = {}      # job_id -> departure tick
        for tick, job in enumerate(arrivals):
            for nid in [n for n, due in flap_restore.items()
                        if due <= tick]:
                fleet.restore(nid)
                del flap_restore[nid]
            if faults is not None:
                evacuations.extend(
                    self._fault_events(faults, tick, flap_restore))
            for jid in [j for j, due in depart_at.items() if due <= tick]:
                sched.release(jid)
                del depart_at[jid]
            if step_time_fn is not None:
                for nid in fleet.up_nodes():
                    sched.note_step_time(nid, step_time_fn(nid, tick))
                if tick and tick % migrate_every == 0:
                    evacuations.extend(sched.migrate_stragglers(tick))
            out = sched.place(job, tick)
            placements.append(out)
            if out.placed and job.duration_ticks is not None:
                depart_at[job.job_id] = tick + max(1, job.duration_ticks)
            records.append(self._record(job, out))
        fleet.check_invariant()             # certify the final state too
        wall = time.perf_counter() - t0
        summary = score(records)
        # per-replay latency distribution through the registry Histogram
        # type: the incremental sum observes walls in append order, so
        # the mean/max stay bit-for-bit with the old list arithmetic
        evac_h = Histogram("xmem_replay_evacuation_seconds")
        for e in evacuations:
            evac_h.observe(e.wall_s)
        summary.update(
            wall_s=wall,
            arrivals_per_s=(len(arrivals) / wall
                            if wall > 0 and arrivals else 0.0),
            violations=0,                   # an over-commit would have raised
            fragmentation=fleet.fragmentation(),
            utilization=fleet.utilization(),
            evacuation_latency_s=evac_h.mean,
            evacuation_latency_max_s=(evac_h.max if evac_h.count
                                      else 0.0),
            **sched.counters)
        return FleetOutcome(placements, evacuations, records, summary)

    # -- fault event polling -------------------------------------------------
    def _fault_events(self, faults, tick: int, flap_restore: dict
                      ) -> list[EvacuationOutcome]:
        """Consume any fleet event sites armed for this tick. The
        struck node is the spec's ``node`` or, unpinned, the busiest up
        node — chaos aims where it hurts most."""
        poll = getattr(faults, "poll", None)
        if poll is None:
            return []
        out = []
        for site in FLEET_SITES:
            spec = poll(site)
            if spec is None:
                continue
            nid = spec.node or self._busiest()
            if nid is None or not self.scheduler.fleet.is_up(nid):
                continue
            evac = self.scheduler.evacuate_node(
                nid, site, tick, shrink_frac=spec.shrink_frac)
            if site == "node.flap":
                flap_restore[nid] = tick + max(1, spec.down_for)
            out.append(evac)
        return out

    def _busiest(self) -> str | None:
        fleet = self.scheduler.fleet
        up = fleet.up_nodes()
        if not up:
            return None
        return max(up, key=lambda nid: (len(fleet.residents(nid)),
                                        fleet.committed(nid), nid))

    # -- scoring -------------------------------------------------------------
    def _record(self, job: JobArrival, out: PlacementOutcome
                ) -> metrics.RunRecord:
        """Two-round record for one arrival. Placed = admit (estimate
        vs. the device capacity the arrival names); a counter-offer /
        elastic placement runs a different plan, so — as in
        ``ClusterSimulator`` — its truth falls back to the charged
        estimate. Lost = rejection: estimate pinned above capacity so a
        feasible job lost costs the round-1 ``-capacity`` penalty and an
        infeasible one scores as a correct rejection."""
        cap = job.capacity
        if out.placed:
            if out.offer is not None:
                est = out.assignment.total_bytes
                truth = est
            else:
                est = out.decision.peak_bytes
                truth = job.truth_bytes
                if truth is None and self.truth_fn is not None:
                    truth = self.truth_fn(out.decision)
                if truth is None:
                    truth = est
            est = min(est, cap)             # placed => charged within cap
        else:
            est = cap + 1
            truth = job.truth_bytes
            if truth is None:
                # no oracle: score the loss as feasible-but-bounced (the
                # decision's peak when one was made, else the device
                # capacity) — losing a job only earns the correct-
                # rejection credit when its true peak exceeds the
                # device, never as a reward for having no room
                truth = (out.decision.peak_bytes
                         if out.decision is not None else cap)
        return metrics.RunRecord(
            config=job.job_id, family=job.family,
            estimator="fleet_scheduler", device=job.device,
            capacity=cap, estimate=int(est), truth=int(truth),
            runtime_s=out.wall_s)
