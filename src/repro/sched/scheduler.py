"""Failure-aware fleet scheduler driven entirely by the estimator
(ISSUE 7 tentpole).

Placement policy, in order:

1. **Admission** — the job is decided by the
   :class:`~repro.service.admission.AdmissionService` against the best
   capacity the fleet could currently give it (the largest headroom
   hole; with preemption rights, headroom plus evictable lower-priority
   shares). The decision's *safe threshold* — margin-widened when a
   degraded rung answered — is what every node is charged; raw peaks
   never touch the books, so failures cost headroom, never safety.
2. **Bin-packing** — best-fit into the smallest adequate hole (keeps
   the big holes whole for big jobs, i.e. minimizes fragmentation),
   tie-broken by spreading a job family across failure domains.
3. **Priority preemption** — a higher-priority job that fits nowhere
   may evict the cheapest set of strictly-lower-priority residents;
   victims re-enter placement (without cascade-preemption rights) and
   are re-placed or reported lost.
4. **Counter-offer backfill** — a rejection whose arrival carries a
   :class:`~repro.plan.PlanContext` comes back with ranked
   :class:`~repro.plan.CounterOffer`\\ s sized to the largest hole; the
   first offer whose per-device safe threshold fits a (set of)
   fragmentation hole(s) is placed instead of losing the job.

Evacuation (node fail / flap / shrink / straggler drain): displaced
jobs re-enter admission — through
:func:`repro.train.elastic.shrink_and_replan` when they carry a plan
context (re-carve the mesh to the surviving devices, re-admit with
spec-driven per-device factors, apply the planner's counter-offer when
the old policy no longer fits), else through plain placement on warm
caches. Every re-placement goes through :meth:`Fleet.place`, which
re-verifies the co-location invariant; an over-commit anywhere raises
:class:`~repro.service.faults.ChaosSafetyViolation`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

from ..obs import CounterDict, Observability
from ..obs import spans as obs_spans
from ..service.cluster import JobArrival
from ..train.elastic import StragglerMonitor
from .fleet import Assignment, Fleet


@dataclasses.dataclass
class PlacementOutcome:
    """What happened to one arrival."""

    job_id: str
    placed: bool
    kind: str                       # placed|backfill|preempt|evacuation|lost
    assignment: Assignment | None = None
    decision: Any = None            # AdmissionDecision (None: no capacity)
    offer: Any = None               # CounterOffer used by a backfill
    preempted: list = dataclasses.field(default_factory=list)
    preempted_lost: list = dataclasses.field(default_factory=list)
    reason: str = ""
    wall_s: float = 0.0

    @property
    def node_ids(self) -> list[str]:
        return (sorted(self.assignment.shares)
                if self.assignment is not None else [])

    def to_json(self) -> dict:
        d = {"job_id": self.job_id, "placed": self.placed,
             "kind": self.kind, "nodes": self.node_ids,
             "reason": self.reason, "preempted": list(self.preempted),
             "preempted_lost": list(self.preempted_lost)}
        if self.assignment is not None:
            d["shares"] = dict(self.assignment.shares)
            d["topology"] = self.assignment.topology
        if self.decision is not None:
            d["peak_bytes"] = self.decision.peak_bytes
            d["safe_threshold"] = self.decision.safe_threshold
            d["rung"] = self.decision.rung
            d["degraded"] = self.decision.degraded
        if self.offer is not None:
            d["offer"] = self.offer.to_json()
        return d


@dataclasses.dataclass
class EvacuationOutcome:
    """One fleet fault event and where its displaced jobs went."""

    node_id: str
    event: str                      # node.fail|node.flap|node.shrink|straggler
    displaced: list
    replaced: list                  # job ids re-placed somewhere
    lost: list                      # job ids that fit nowhere
    wall_s: float = 0.0             # evacuation latency

    def to_json(self) -> dict:
        return {"node": self.node_id, "event": self.event,
                "displaced": list(self.displaced),
                "replaced": list(self.replaced),
                "lost": list(self.lost), "wall_s": self.wall_s}


class FleetScheduler:
    """Estimator-driven bin-packing over a :class:`Fleet`.

    ``colocate=False`` is the no-co-location baseline (one job per
    node, exclusive) the fleet metrics compare against; ``preempt`` /
    ``backfill`` gate policies 3 and 4; ``deadline_s`` is the default
    per-decision answer budget (jobs may carry their own)."""

    def __init__(self, service, fleet: Fleet, *, colocate: bool = True,
                 preempt: bool = True, backfill: bool = True,
                 deadline_s: float | None = None,
                 obs: Observability | None = None):
        self.service = service
        self.fleet = fleet
        self.colocate = colocate
        self.preempt = preempt
        self.backfill = backfill
        self.deadline_s = deadline_s
        self._node_index = {nid: i for i, nid in enumerate(fleet.nodes)}
        self.monitor = StragglerMonitor(len(fleet.nodes))
        self.obs = obs if obs is not None else Observability(enabled=False)
        self.counters = CounterDict(
            ("placed", "colocated", "backfills", "preemptions",
             "preempted_lost", "lost", "evacuations", "evacuated",
             "re_placed", "lost_after_evacuation", "migrations"),
            registry=self.obs.registry, name="xmem_fleet_events_total",
            label="event", help="Fleet scheduler placement/evacuation events")
        self._m_evac_s = self.obs.registry.histogram(
            "xmem_fleet_evacuation_seconds",
            help="Evacuation latency (displacement to re-placement)")
        self.obs.registry.register_collector("xmem_fleet", lambda: {
            "fragmentation": self.fleet.fragmentation(),
            "utilization": self.fleet.utilization(),
            "jobs_resident": len(self.fleet.assignments)})

    # -- placement -----------------------------------------------------------
    def place(self, job: JobArrival, tick: int = 0, *,
              allow_preempt: bool | None = None,
              source: str = "decide") -> PlacementOutcome:
        """Place one arrival (see module docstring for the policy)."""
        with obs_spans.span("fleet.place", job_id=job.job_id,
                            source=source):
            out = self._place(job, tick, allow_preempt=allow_preempt,
                              source=source)
        self._audit_place(out, tick)
        return out

    def _place(self, job: JobArrival, tick: int = 0, *,
               allow_preempt: bool | None = None,
               source: str = "decide") -> PlacementOutcome:
        t0 = time.perf_counter()
        allow_preempt = (self.preempt if allow_preempt is None
                         else allow_preempt)
        cap = self._best_capacity(job, allow_preempt)
        if cap <= 0:
            return self._lost(job, None, "no capacity in the fleet",
                              t0, tick)
        req = job.request()
        req.capacity = cap
        if req.deadline_s is None:
            req.deadline_s = self.deadline_s
        if not self.backfill:
            req.meta.pop("plan", None)
        decision = self.service.decide(req)
        threshold = decision.safe_threshold
        if decision.admit:
            nodes = self._pick_nodes(threshold, family=job.family,
                                     device=job.device)
            if nodes is not None:
                a = self._assignment(job, {nodes[0]: threshold},
                                     decision, tick, source=source)
                self.fleet.place(a)
                self._count_place(a)
                return PlacementOutcome(
                    job.job_id, True, source, assignment=a,
                    decision=decision,
                    wall_s=time.perf_counter() - t0)
        if allow_preempt and job.priority > 0:
            out = self._try_preempt(job, decision, threshold, tick, t0)
            if out is not None:
                return out
        if self.backfill and decision.counter_offers:
            out = self._try_backfill(job, decision, tick, t0)
            if out is not None:
                return out
        return self._lost(job, decision,
                          f"safe threshold {threshold} fits no hole",
                          t0, tick)

    def release(self, job_id: str) -> Assignment | None:
        """Voluntary departure (the job finished)."""
        return self.fleet.remove(job_id)

    def _assignment(self, job: JobArrival, shares: dict, decision,
                    tick: int, *, source: str, topology: str | None = None,
                    mesh: tuple | None = None,
                    offer=None) -> Assignment:
        return Assignment(
            job_id=job.job_id, shares=shares, priority=job.priority,
            family=job.family, source=source, topology=topology,
            mesh=mesh, placed_tick=tick, truth_bytes=job.truth_bytes,
            arrival=job, ctx=job.plan)

    def _count_place(self, a: Assignment) -> None:
        self.counters["placed"] += 1
        if any(len(self.fleet.residents(nid)) > 1 for nid in a.shares):
            self.counters["colocated"] += 1

    def _audit_place(self, out: PlacementOutcome, tick: int) -> None:
        """One audit record per placement attempt, chained to the
        admission decision's correlation ID (the same ID the planner's
        counter-offer record carries — reject → plan → place is one
        trail)."""
        if self.obs.audit is None:
            return
        # "outcome", not "kind": the record kind is "place"
        rec = {"job_id": out.job_id, "placed": out.placed,
               "outcome": out.kind, "nodes": out.node_ids, "tick": tick,
               "reason": out.reason, "wall_s": round(out.wall_s, 6)}
        cid = None
        if out.decision is not None:
            cid = getattr(out.decision, "correlation_id", None)
            rec.update(rung=out.decision.rung,
                       peak_bytes=out.decision.peak_bytes,
                       safe_threshold=out.decision.safe_threshold,
                       degraded=out.decision.degraded)
        if out.offer is not None:
            rec["offer"] = {"knob": out.offer.knob,
                            "safe_threshold": out.offer.safe_threshold}
        if out.preempted or out.preempted_lost:
            rec["preempted"] = list(out.preempted)
            rec["preempted_lost"] = list(out.preempted_lost)
        self.obs.record("place", correlation_id=cid, **rec)

    def _audit_evacuation(self, out: EvacuationOutcome,
                          tick: int) -> None:
        if self.obs.audit is None:
            return
        self.obs.record(
            "evacuate", node=out.node_id, event=out.event, tick=tick,
            displaced=list(out.displaced), replaced=list(out.replaced),
            lost=list(out.lost), wall_s=round(out.wall_s, 6))

    def _lost(self, job: JobArrival, decision, reason: str, t0: float,
              tick: int) -> PlacementOutcome:
        self.counters["lost"] += 1
        return PlacementOutcome(job.job_id, False, "lost",
                                decision=decision, reason=reason,
                                wall_s=time.perf_counter() - t0)

    # -- capacity + node selection -------------------------------------------
    def _best_capacity(self, job: JobArrival, allow_preempt: bool) -> int:
        """The most memory the fleet could give this job right now —
        the capacity its admission decision (and any planner search) is
        made against. With preemption rights: headroom plus the shares
        of strictly-lower-priority residents."""
        best = 0
        empty_only = not self.colocate
        for nid in self.fleet.up_nodes(job.device):
            if empty_only and self.fleet.residents(nid):
                continue
            h = self.fleet.headroom(nid)
            if allow_preempt and job.priority > 0:
                h += sum(a.shares[nid] for a in self.fleet.residents(nid)
                         if a.priority < job.priority)
            best = max(best, h)
        return best

    def _pick_nodes(self, threshold: int, n: int = 1, *,
                    family: str = "", device: str | None = None,
                    exclude=()) -> list[str] | None:
        """``n`` nodes with ``threshold`` headroom each: best-fit
        (smallest adequate hole first) with two spreading rules — a
        multi-device job prefers distinct failure domains (one rack
        loss displaces it anyway, but a flap of one node should not be
        *every* replica), and ties prefer the domain hosting the fewest
        same-family residents (anti-affinity)."""
        holes = self.fleet.holes(device, empty_only=not self.colocate)
        fits = [(nid, h) for nid, h in holes
                if h >= threshold and nid not in exclude]
        if len(fits) < n:
            return None
        fam_load: dict[str, int] = {}
        for a in self.fleet.assignments.values():
            if a.family != family:
                continue
            for nid in a.shares:
                dom = self.fleet.nodes[nid].domain
                fam_load[dom] = fam_load.get(dom, 0) + 1
        ranked = sorted(fits, key=lambda p: (
            p[1], fam_load.get(self.fleet.nodes[p[0]].domain, 0), p[0]))
        chosen: list[str] = []
        used_domains: set[str] = set()
        for nid, _h in ranked:                  # pass 1: fresh domains
            if len(chosen) == n:
                break
            if self.fleet.nodes[nid].domain in used_domains:
                continue
            chosen.append(nid)
            used_domains.add(self.fleet.nodes[nid].domain)
        for nid, _h in ranked:                  # pass 2: fill remainder
            if len(chosen) == n:
                break
            if nid not in chosen:
                chosen.append(nid)
        return chosen if len(chosen) == n else None

    # -- preemption ----------------------------------------------------------
    def _try_preempt(self, job: JobArrival, decision, threshold: int,
                     tick: int, t0: float) -> PlacementOutcome | None:
        """Evict the cheapest set of strictly-lower-priority residents
        that frees ``threshold`` on one node. Victims re-enter
        placement without cascade-preemption rights."""
        best = None                 # (n_evicted, bytes_evicted, nid, victims)
        for nid in self.fleet.up_nodes(job.device):
            headroom = self.fleet.headroom(nid)
            evictable = sorted(
                (a for a in self.fleet.residents(nid)
                 if a.priority < job.priority),
                key=lambda a: (-a.shares[nid], a.job_id))
            freed, victims = headroom, []
            for a in evictable:
                if freed >= threshold:
                    break
                freed += a.shares[nid]
                victims.append(a)
            if freed >= threshold and victims:
                key = (len(victims), sum(a.total_bytes for a in victims),
                       nid)
                if best is None or key < best[:3]:
                    best = (*key, victims)
        if best is None:
            return None
        _n, _b, nid, victims = best
        for a in victims:
            self.fleet.remove(a.job_id)
        a_new = self._assignment(job, {nid: threshold}, decision, tick,
                                 source="preempt")
        self.fleet.place(a_new)
        self.counters["preemptions"] += 1
        self._count_place(a_new)
        replaced, lost = [], []
        for victim in victims:
            out = self._replace(victim, tick)
            (replaced if out is not None and out.placed
             else lost).append(victim.job_id)
        self.counters["preempted_lost"] += len(lost)
        return PlacementOutcome(
            job.job_id, True, "preempt", assignment=a_new,
            decision=decision, preempted=replaced, preempted_lost=lost,
            wall_s=time.perf_counter() - t0)

    # -- counter-offer backfill ----------------------------------------------
    def _try_backfill(self, job: JobArrival, decision, tick: int,
                      t0: float) -> PlacementOutcome | None:
        """Place the first (cheapest) counter-offer whose per-device
        safe threshold fits the fleet's fragmentation holes — a
        topology offer needs ``n_devices`` adequate holes."""
        for offer in decision.counter_offers:
            threshold = offer.safe_threshold
            nodes = self._pick_nodes(threshold, n=offer.n_devices,
                                     family=job.family, device=job.device)
            if nodes is None:
                continue
            topo = offer.topology
            a = self._assignment(
                job, {nid: threshold for nid in nodes}, decision, tick,
                source="counter-offer",
                topology=topo.label if topo is not None else None,
                mesh=((topo.pod, topo.data, topo.model)
                      if topo is not None else None))
            self.fleet.place(a)
            self.counters["backfills"] += 1
            self._count_place(a)
            return PlacementOutcome(
                job.job_id, True, "backfill", assignment=a,
                decision=decision, offer=offer,
                wall_s=time.perf_counter() - t0)
        return None

    # -- evacuation ----------------------------------------------------------
    def evacuate_node(self, node_id: str, event: str, tick: int = 0, *,
                      shrink_frac: float = 0.5) -> EvacuationOutcome:
        """Apply a fleet fault event and re-place everything it
        displaced. ``event``: ``node.fail`` / ``node.flap`` (down, the
        simulator restores it later) / ``node.shrink`` (partial
        capacity loss, node stays up)."""
        t0 = time.perf_counter()
        with obs_spans.span("fleet.evacuate", node=node_id, event=event):
            if event == "node.shrink":
                displaced = self.fleet.shrink(node_id, shrink_frac)
            else:
                displaced = self.fleet.fail(node_id)
            self.monitor.forget(self._node_index[node_id])
            replaced, lost = self._replace_all(displaced, tick)
        self.counters["evacuations"] += 1
        self.counters["evacuated"] += len(displaced)
        self.counters["re_placed"] += len(replaced)
        self.counters["lost_after_evacuation"] += len(lost)
        out = EvacuationOutcome(
            node_id, event, [a.job_id for a in displaced], replaced,
            lost, wall_s=time.perf_counter() - t0)
        self._m_evac_s.observe(out.wall_s)
        self._audit_evacuation(out, tick)
        return out

    def _replace_all(self, displaced, tick: int) -> tuple[list, list]:
        replaced, lost = [], []
        for a in displaced:
            out = self._replace(a, tick)
            (replaced if out is not None and out.placed
             else lost).append(a.job_id)
        return replaced, lost

    def _replace(self, a: Assignment, tick: int
                 ) -> PlacementOutcome | None:
        """Re-admission of a displaced job: the elastic
        shrink-and-replan path when it carries a plan context, plain
        (cache-warm) placement otherwise. Either way the re-placement
        goes through ``Fleet.place`` — the invariant is re-verified."""
        job = a.arrival
        if job is None:
            return None
        if a.ctx is not None:
            out = self._replace_elastic(a, job, tick)
            if out is not None:
                return out
        return self.place(job, tick, allow_preempt=False,
                          source="evacuation")

    def _replace_elastic(self, a: Assignment, job: JobArrival, tick: int
                         ) -> PlacementOutcome | None:
        """ISSUE 5/7 wiring: re-carve the displaced job's mesh to the
        devices that still have room, re-admit on the new topology with
        spec-driven factors, and apply the planner's counter-offer when
        the old policy no longer fits (``shrink_and_replan``)."""
        from ..train.elastic import MeshPlan, shrink_and_replan
        t0 = time.perf_counter()
        ctx = a.ctx
        holes = self.fleet.holes(job.device,
                                 empty_only=not self.colocate)
        if not holes:
            return None
        cur = MeshPlan(*(a.mesh or (1, 1, 1)))
        avail = max(min(len(holes), cur.devices), 1)
        try:
            rp = shrink_and_replan(
                ctx.cfg, ctx.policy, ctx.shape, cur,
                available_devices=avail, hbm_bytes=holes[0][1],
                service=self.service, space=ctx.space)
        except Exception:   # noqa: BLE001 — elastic replan is best-effort;
            return None     # the plain placement path still runs
        if not rp.admitted:
            return None
        decision = rp.decision
        offer = rp.offer
        threshold = (decision.safe_threshold if decision.admit
                     else offer.safe_threshold)
        nodes = self._pick_nodes(threshold, n=rp.plan.devices,
                                 family=job.family, device=job.device)
        if nodes is None:
            return None
        a2 = self._assignment(
            job, {nid: threshold for nid in nodes}, decision, tick,
            source="evacuation", topology=rp.topology.label,
            mesh=(rp.plan.pod, rp.plan.data, rp.plan.model))
        self.fleet.place(a2)
        self._count_place(a2)
        return PlacementOutcome(
            job.job_id, True, "evacuation", assignment=a2,
            decision=decision, offer=offer,
            wall_s=time.perf_counter() - t0)

    # -- straggler migration -------------------------------------------------
    def note_step_time(self, node_id: str, step_time_s: float) -> None:
        """Feed per-node step timings to the MAD straggler detector."""
        self.monitor.record(self._node_index[node_id], step_time_s)

    def straggler_nodes(self) -> list[str]:
        lag = set(self.monitor.stragglers())
        return [nid for nid, i in self._node_index.items() if i in lag]

    def migrate_stragglers(self, tick: int = 0) -> list[EvacuationOutcome]:
        """Drain each flagged node, re-place its residents elsewhere
        (the drained node is unplaceable during the migration), then
        restore it with a cleared timing window."""
        out = []
        for nid in self.straggler_nodes():
            if not self.fleet.is_up(nid):
                continue
            t0 = time.perf_counter()
            displaced = self.fleet.drain(nid)
            replaced, lost = self._replace_all(displaced, tick)
            self.fleet.restore(nid)
            self.monitor.forget(self._node_index[nid])
            self.counters["migrations"] += len(displaced)
            self.counters["evacuated"] += len(displaced)
            self.counters["re_placed"] += len(replaced)
            self.counters["lost_after_evacuation"] += len(lost)
            ev = EvacuationOutcome(
                nid, "straggler", [a.job_id for a in displaced],
                replaced, lost, wall_s=time.perf_counter() - t0)
            self._m_evac_s.observe(ev.wall_s)
            self._audit_evacuation(ev, tick)
            out.append(ev)
        return out

    def stats(self) -> dict:
        return {**self.counters,
                "fragmentation": self.fleet.fragmentation(),
                "utilization": self.fleet.utilization(),
                "jobs_resident": len(self.fleet.assignments)}
