"""Sharding rule engine: per-tensor PartitionSpecs with divisibility-aware
fallback (DESIGN.md §5).

Layout strategy (Megatron-style TP + DP/FSDP + EP):
* attention qkv: column-parallel on ``model``; output proj row-parallel;
* MLP gate/up column-parallel, down row-parallel;
* MoE expert stacks sharded on the expert dim over ``model`` (EP);
* embedding sharded on vocab over ``model`` (falls back to d_model when
  vocab isn't divisible — e.g. internvl2's 151655); LM head sharded on
  vocab (keeps the [B,S,V] logits tensor vocab-sharded — materializing
  unsharded 32k x 152k logits would be terabytes);
* Mamba/xLSTM inner dims column/row-parallel like MLPs;
* batch dims of activations/inputs sharded over ``(pod, data)``;
* FSDP (``fsdp=True``): the largest remaining unsharded weight dim is
  additionally sharded over the fsdp axes — ZeRO-3-style parameter
  sharding, required to fit the 398B/1T configs;
* every rule checks divisibility: if a dim doesn't divide the axis size
  the axis is dropped for that dim (replicated) rather than failing.

The same rules produce the xMem estimator's per-block ``shard_factor``
(paper §6.2 distributed extension).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = False                  # ZeRO-3 param sharding over data
    fsdp_axes: tuple[str, ...] = ("data",)
    batch_axes: tuple[str, ...] = ("pod", "data")
    model_axis: str = "model"


# (path regex, spec template for the LAST n dims of the tensor)
# "M" = model axis, "F" = fsdp candidate preference marker, None = replicated
_RULES: list[tuple[str, tuple]] = [
    # templates bind to TRAILING dims, so ("M", None) covers both the
    # [V, D] text embedding and the [K, V, D] audio codebook stack
    (r"\['embed'\]$", ("M", None)),        # vocab-sharded embedding
    (r"\['head'\]$", (None, "M")),         # [D, V] vocab-sharded logits
    (r"\['attn'\]\['wq'\]", (None, "M")),
    (r"\['attn'\]\['wk'\]", (None, "M")),
    (r"\['attn'\]\['wv'\]", (None, "M")),
    (r"\['attn'\]\['wo'\]", ("M", None)),
    (r"\['mlp'\]\['w_gate'\]", (None, "M")),
    (r"\['mlp'\]\['w_up'\]", (None, "M")),
    (r"\['mlp'\]\['w_down'\]", ("M", None)),
    (r"\['moe'\]\['router'\]", (None, None)),        # replicated router
    (r"\['moe'\]\['we_gate'\]", ("M", None, None)),  # EP on expert dim
    (r"\['moe'\]\['we_up'\]", ("M", None, None)),
    (r"\['moe'\]\['we_down'\]", ("M", None, None)),
    (r"\['mamba'\]\['in_proj'\]", (None, "M")),
    (r"\['mamba'\]\['out_proj'\]", ("M", None)),
    (r"\['mamba'\]\['conv_w'\]", (None, "M")),
    (r"\['mamba'\]\['conv_b'\]", ("M",)),
    (r"\['mamba'\]\['x_proj'\]", ("M", None)),
    (r"\['mamba'\]\['dt_proj'\]", (None, "M")),
    (r"\['mamba'\]\['dt_bias'\]", ("M",)),
    (r"\['mamba'\]\['A_log'\]", ("M", None)),
    (r"\['mamba'\]\['D'\]", ("M",)),
    (r"\['(wq|wk)'\]", (None, "M")),       # xlstm mLSTM projections
    (r"\['wv'\]", (None, "M")),
    (r"\['w_gate'\]", (None, "M")),
    (r"\['w_out'\]", ("M", None)),
    (r"\['w_(z|i|f|o)'\]", (None, "M")),   # sLSTM input mats
    (r"\['r_(z|i|f|o)'\]", (None, None, None)),  # block-diag recurrent
]


def _axis_size(mesh, name: str) -> int:
    if isinstance(mesh, dict):     # {axis: size} — estimator-side use
        return mesh.get(name, 1)
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    total = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        total *= _axis_size(mesh, a)
    return dim % total == 0 and dim >= total


def spec_for_path(path: str, shape: tuple, mesh: Mesh,
                  policy: ShardingPolicy) -> P:
    """Resolve the PartitionSpec for one parameter leaf."""
    template = None
    for pat, tmpl in _RULES:
        if re.search(pat, path):
            template = tmpl
            break
    nd = len(shape)
    spec: list = [None] * nd
    if template is not None:
        # template binds to the trailing dims (stacked scan dims lead)
        k = min(len(template), nd)
        for i in range(k):
            t = template[len(template) - k + i]
            dim_idx = nd - k + i
            if t == "M" and policy.model_axis in mesh.axis_names \
                    and _fits(shape[dim_idx], mesh, policy.model_axis):
                spec[dim_idx] = policy.model_axis
        # vocab-shard fallback: embed [V, D] with V not divisible by the
        # model axis (internvl2's 151655) -> shard d_model instead
        if re.search(r"\['embed'\]$", path) and nd >= 2 \
                and spec[nd - 2] is None and template[-2] == "M" \
                and _fits(shape[nd - 1], mesh, policy.model_axis):
            spec[nd - 1] = policy.model_axis
    if policy.fsdp:
        axes = tuple(a for a in policy.fsdp_axes if a in mesh.axis_names)
        if axes:
            # shard the largest remaining unsharded dim over fsdp axes
            cands = [(shape[i], i) for i in range(nd)
                     if spec[i] is None and _fits(shape[i], mesh, axes)]
            if cands:
                _, idx = max(cands)
                spec[idx] = axes if len(axes) > 1 else axes[0]
    return P(*spec)


def param_shardings(abstract_params, cfg: ModelConfig, mesh: Mesh,
                    policy: ShardingPolicy | None = None):
    """Pytree of NamedShardings aligned with the abstract param tree."""
    policy = policy or ShardingPolicy()
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = []
    for key_path, leaf in flat:
        path = jax.tree_util.keystr(key_path)
        spec = spec_for_path(path, leaf.shape, mesh, policy)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch_specs, mesh: Mesh,
                    policy: ShardingPolicy | None = None):
    """Inputs: batch dim sharded over (pod, data)."""
    policy = policy or ShardingPolicy()
    axes = tuple(a for a in policy.batch_axes if a in mesh.axis_names)

    def spec(leaf):
        nd = len(leaf.shape)
        if nd == 0 or not axes or not _fits(leaf.shape[0], mesh, axes):
            return NamedSharding(mesh, P())
        s = [axes if len(axes) > 1 else axes[0]] + [None] * (nd - 1)
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map(spec, batch_specs)


def opt_state_shardings(abstract_opt_state, mesh: Mesh,
                        policy: ShardingPolicy | None = None):
    """Optimizer state sharding: the largest divisible dim goes on the
    model axis and (with fsdp, or ZeRO-1 style regardless for 2D+ states)
    the next largest on the data axes — m/v mirror their parameter's
    dominant-dim layout; factored Adafactor rows/cols and scalar counters
    degrade gracefully to replication."""
    policy = policy or ShardingPolicy()
    fsdp_axes = tuple(a for a in policy.fsdp_axes if a in mesh.axis_names)

    def spec(leaf):
        shape = getattr(leaf, "shape", ())
        nd = len(shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        s: list = [None] * nd
        order = sorted(range(nd), key=lambda i: -shape[i])
        for i in order:
            if policy.model_axis in mesh.axis_names \
                    and _fits(shape[i], mesh, policy.model_axis):
                s[i] = policy.model_axis
                break
        if fsdp_axes:
            for i in order:
                if s[i] is None and _fits(shape[i], mesh, fsdp_axes):
                    s[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                    break
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map(spec, abstract_opt_state)


# decode-state layouts by cache key: (batch_dim, model_dim_candidates)
# model_dim_candidates are tried in order with divisibility checks;
# for k/v the sequence dim (context parallelism) is the fallback when
# GQA kv-head counts (2-24) don't divide the 16-way model axis.
_CACHE_LAYOUTS = {
    "k": (1, (3, 2)),            # [L, B, S, Hkv, hd]: B; Hkv else S
    "v": (1, (3, 2)),
    "mamba_h": (2, (3,)),        # [P, n, B, d_inner, N]: B; d_inner
    "mamba_conv": (2, (4,)),     # [P, n, B, K, d_inner]: B; d_inner
    "mlstm_C": (2, (5, 4)),      # [P, n, B, H, dk, dv]: B; dv else dk
    "mlstm_n": (2, (4,)),        # [P, n, B, H, dk]: B; dk
    "mlstm_m": (2, ()),          # [P, n, B, H]: B
    "slstm": (2, (3,)),          # [P, 4, B, D]: B; D
}


def cache_spec_for(path: str, shape: tuple, mesh,
                   policy: ShardingPolicy | None = None) -> P:
    """PartitionSpec for one decode-state leaf (layouts above): batch
    over (pod, data); the widest feature dim over model; KV caches fall
    back to sequence (context-parallel) sharding when kv-heads don't
    divide — an unsharded 32k-512k cache would be tens of GB/device."""
    policy = policy or ShardingPolicy()
    axis_names = mesh.keys() if isinstance(mesh, dict) else mesh.axis_names
    baxes = tuple(a for a in policy.batch_axes if a in axis_names)
    bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    nd = len(shape)
    s: list = [None] * nd
    layout = None
    for name, lay in _CACHE_LAYOUTS.items():
        if f"'{name}'" in path:
            layout = lay
            break
    if layout is not None:
        bdim, mdims = layout
        if bdim < nd and bspec is not None \
                and _fits(shape[bdim], mesh, baxes):
            s[bdim] = bspec
        if policy.model_axis in axis_names:
            for md in mdims:
                if md < nd and s[md] is None \
                        and _fits(shape[md], mesh, policy.model_axis):
                    s[md] = policy.model_axis
                    break
    return P(*s)


def cache_shardings(abstract_cache, mesh: Mesh,
                    policy: ShardingPolicy | None = None):
    """NamedShardings for a decode-state pytree (see cache_spec_for)."""
    policy = policy or ShardingPolicy()
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_cache)
    out = [NamedSharding(mesh, cache_spec_for(
        jax.tree_util.keystr(kp), leaf.shape, mesh, policy))
        for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
def shard_factor_fn(cfg: ModelConfig, mesh: Mesh,
                    policy: ShardingPolicy | None = None):
    """xMem hook: BlockLifecycle -> division factor for per-device sizes.

    Params/grads/opt-state: actual sharding factor from the rules
    (model x fsdp). Activations/inputs: batch axes. Collectives:
    unsharded (already per-device)."""
    from ..core.events import BlockKind
    policy = policy or ShardingPolicy()
    model = _axis_size(mesh, policy.model_axis)
    data = 1
    for a in policy.batch_axes:
        data *= _axis_size(mesh, a)
    fsdp = 1
    if policy.fsdp:
        for a in policy.fsdp_axes:
            fsdp *= _axis_size(mesh, a)

    # Large intermediates (FFN/expert projections, logits) inherit the
    # model-axis sharding of the weights that produce them via GSPMD
    # propagation; small ones (norms, gates) typically stay data-sharded
    # only. 64 MiB global is the empirical crossover on these configs.
    big_activation = 64 * 2**20

    def factor(block) -> float:
        k = block.block_kind
        if k in (BlockKind.PARAM, BlockKind.GRAD, BlockKind.OPT_STATE,
                 BlockKind.OUTPUT):
            return float(model * fsdp)
        if k in (BlockKind.ACTIVATION, BlockKind.TEMP, BlockKind.CACHE):
            if block.size >= big_activation:
                return float(data * model)
            return float(data)
        if k is BlockKind.INPUT:
            return float(data)
        return 1.0

    return factor
