"""Sharding rule engine: per-tensor PartitionSpecs with divisibility-aware
fallback (DESIGN.md §5).

Layout strategy (Megatron-style TP + DP/FSDP + EP):
* attention qkv: column-parallel on ``model``; output proj row-parallel;
* MLP gate/up column-parallel, down row-parallel;
* MoE expert stacks sharded on the expert dim over ``model`` (EP);
* embedding sharded on vocab over ``model`` (falls back to d_model when
  vocab isn't divisible — e.g. internvl2's 151655); LM head sharded on
  vocab (keeps the [B,S,V] logits tensor vocab-sharded — materializing
  unsharded 32k x 152k logits would be terabytes);
* Mamba/xLSTM inner dims column/row-parallel like MLPs;
* batch dims of activations/inputs sharded over ``(pod, data)``;
* FSDP (``fsdp=True``): the largest remaining unsharded weight dim is
  additionally sharded over the fsdp axes — ZeRO-3-style parameter
  sharding, required to fit the 398B/1T configs;
* every rule checks divisibility: if a dim doesn't divide the axis size
  the axis is dropped for that dim (replicated) rather than failing.

The same rules produce the xMem estimator's per-block ``shard_factor``
(paper §6.2 distributed extension).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = False                  # ZeRO-3 param sharding over data
    fsdp_axes: tuple[str, ...] = ("data",)
    batch_axes: tuple[str, ...] = ("pod", "data")
    model_axis: str = "model"


# (path regex, spec template for the LAST n dims of the tensor)
# "M" = model axis, "F" = fsdp candidate preference marker, None = replicated
_RULES: list[tuple[str, tuple]] = [
    # templates bind to TRAILING dims, so ("M", None) covers both the
    # [V, D] text embedding and the [K, V, D] audio codebook stack
    (r"\['embed'\]$", ("M", None)),        # vocab-sharded embedding
    (r"\['head'\]$", (None, "M")),         # [D, V] vocab-sharded logits
    (r"\['attn'\]\['wq'\]", (None, "M")),
    (r"\['attn'\]\['wk'\]", (None, "M")),
    (r"\['attn'\]\['wv'\]", (None, "M")),
    (r"\['attn'\]\['wo'\]", ("M", None)),
    (r"\['mlp'\]\['w_gate'\]", (None, "M")),
    (r"\['mlp'\]\['w_up'\]", (None, "M")),
    (r"\['mlp'\]\['w_down'\]", ("M", None)),
    (r"\['moe'\]\['router'\]", (None, None)),        # replicated router
    (r"\['moe'\]\['we_gate'\]", ("M", None, None)),  # EP on expert dim
    (r"\['moe'\]\['we_up'\]", ("M", None, None)),
    (r"\['moe'\]\['we_down'\]", ("M", None, None)),
    (r"\['mamba'\]\['in_proj'\]", (None, "M")),
    (r"\['mamba'\]\['out_proj'\]", ("M", None)),
    (r"\['mamba'\]\['conv_w'\]", (None, "M")),
    (r"\['mamba'\]\['conv_b'\]", ("M",)),
    (r"\['mamba'\]\['x_proj'\]", ("M", None)),
    (r"\['mamba'\]\['dt_proj'\]", (None, "M")),
    (r"\['mamba'\]\['dt_bias'\]", ("M",)),
    (r"\['mamba'\]\['A_log'\]", ("M", None)),
    (r"\['mamba'\]\['D'\]", ("M",)),
    (r"\['(wq|wk)'\]", (None, "M")),       # xlstm mLSTM projections
    (r"\['wv'\]", (None, "M")),
    (r"\['w_gate'\]", (None, "M")),
    (r"\['w_out'\]", ("M", None)),
    (r"\['w_(z|i|f|o)'\]", (None, "M")),   # sLSTM input mats
    (r"\['r_(z|i|f|o)'\]", (None, None, None)),  # block-diag recurrent
]


def _axis_size(mesh, name: str) -> int:
    if isinstance(mesh, dict):     # {axis: size} — estimator-side use
        return mesh.get(name, 1)
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _axis_names(mesh) -> tuple:
    """Axis names of a jax Mesh or an {axis: size} dict (estimator-side
    meshes need no device array)."""
    if isinstance(mesh, dict):
        return tuple(mesh.keys())
    return tuple(mesh.axis_names)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    total = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        total *= _axis_size(mesh, a)
    return dim % total == 0 and dim >= total


def spec_for_path(path: str, shape: tuple, mesh,
                  policy: ShardingPolicy) -> P:
    """Resolve the PartitionSpec for one parameter leaf. ``mesh`` may be
    a jax Mesh or an {axis: size} dict (spec-driven estimation needs no
    device array)."""
    axis_names = _axis_names(mesh)
    template = None
    for pat, tmpl in _RULES:
        if re.search(pat, path):
            template = tmpl
            break
    nd = len(shape)
    spec: list = [None] * nd
    if template is not None:
        # template binds to the trailing dims (stacked scan dims lead)
        k = min(len(template), nd)
        for i in range(k):
            t = template[len(template) - k + i]
            dim_idx = nd - k + i
            if t == "M" and policy.model_axis in axis_names \
                    and _fits(shape[dim_idx], mesh, policy.model_axis):
                spec[dim_idx] = policy.model_axis
        # vocab-shard fallback: embed [V, D] with V not divisible by the
        # model axis (internvl2's 151655) -> shard d_model instead
        if re.search(r"\['embed'\]$", path) and nd >= 2 \
                and spec[nd - 2] is None and template[-2] == "M" \
                and _fits(shape[nd - 1], mesh, policy.model_axis):
            spec[nd - 1] = policy.model_axis
    if policy.fsdp:
        axes = tuple(a for a in policy.fsdp_axes if a in axis_names)
        if axes:
            # shard the largest remaining unsharded dim over fsdp axes
            cands = [(shape[i], i) for i in range(nd)
                     if spec[i] is None and _fits(shape[i], mesh, axes)]
            if cands:
                _, idx = max(cands)
                spec[idx] = axes if len(axes) > 1 else axes[0]
    return P(*spec)


def param_shardings(abstract_params, cfg: ModelConfig, mesh: Mesh,
                    policy: ShardingPolicy | None = None):
    """Pytree of NamedShardings aligned with the abstract param tree."""
    policy = policy or ShardingPolicy()
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = []
    for key_path, leaf in flat:
        path = jax.tree_util.keystr(key_path)
        spec = spec_for_path(path, leaf.shape, mesh, policy)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_spec_for_shape(shape: tuple, mesh,
                         policy: ShardingPolicy | None = None) -> P:
    """Input rule as a pure shape function: batch (leading) dim over the
    batch axes, replicated when it does not divide."""
    policy = policy or ShardingPolicy()
    axes = tuple(a for a in policy.batch_axes if a in _axis_names(mesh))
    nd = len(shape)
    if nd == 0 or not axes or not _fits(shape[0], mesh, axes):
        return P()
    s = [axes if len(axes) > 1 else axes[0]] + [None] * (nd - 1)
    return P(*s)


def batch_shardings(batch_specs, mesh: Mesh,
                    policy: ShardingPolicy | None = None):
    """Inputs: batch dim sharded over (pod, data)."""
    policy = policy or ShardingPolicy()
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(
            mesh, batch_spec_for_shape(tuple(leaf.shape), mesh, policy)),
        batch_specs)


def opt_spec_for_shape(shape: tuple, mesh,
                       policy: ShardingPolicy | None = None) -> P:
    """Optimizer-state rule as a pure shape function: the largest
    divisible dim goes on the model axis and (with fsdp) the next
    largest on the fsdp axes; scalars and non-divisible dims degrade
    gracefully to replication."""
    policy = policy or ShardingPolicy()
    axis_names = _axis_names(mesh)
    fsdp_axes = tuple(a for a in policy.fsdp_axes if a in axis_names)
    nd = len(shape)
    if nd == 0:
        return P()
    s: list = [None] * nd
    order = sorted(range(nd), key=lambda i: -shape[i])
    for i in order:
        if policy.model_axis in axis_names \
                and _fits(shape[i], mesh, policy.model_axis):
            s[i] = policy.model_axis
            break
    if fsdp_axes:
        for i in order:
            if s[i] is None and _fits(shape[i], mesh, fsdp_axes):
                s[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                break
    return P(*s)


def opt_state_shardings(abstract_opt_state, mesh: Mesh,
                        policy: ShardingPolicy | None = None):
    """Optimizer state sharding — m/v mirror their parameter's
    dominant-dim layout; factored Adafactor rows/cols and scalar
    counters degrade gracefully to replication (see
    :func:`opt_spec_for_shape`)."""
    policy = policy or ShardingPolicy()
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, opt_spec_for_shape(
            tuple(getattr(leaf, "shape", ())), mesh, policy)),
        abstract_opt_state)


# decode-state layouts by cache key: (batch_dim, model_dim_candidates)
# model_dim_candidates are tried in order with divisibility checks;
# for k/v the sequence dim (context parallelism) is the fallback when
# GQA kv-head counts (2-24) don't divide the 16-way model axis.
_CACHE_LAYOUTS = {
    "k": (1, (3, 2)),            # [L, B, S, Hkv, hd]: B; Hkv else S
    "v": (1, (3, 2)),
    "mamba_h": (2, (3,)),        # [P, n, B, d_inner, N]: B; d_inner
    "mamba_conv": (2, (4,)),     # [P, n, B, K, d_inner]: B; d_inner
    "mlstm_C": (2, (5, 4)),      # [P, n, B, H, dk, dv]: B; dv else dk
    "mlstm_n": (2, (4,)),        # [P, n, B, H, dk]: B; dk
    "mlstm_m": (2, ()),          # [P, n, B, H]: B
    "slstm": (2, (3,)),          # [P, 4, B, D]: B; D
}


def cache_spec_for(path: str, shape: tuple, mesh,
                   policy: ShardingPolicy | None = None) -> P:
    """PartitionSpec for one decode-state leaf (layouts above): batch
    over (pod, data); the widest feature dim over model; KV caches fall
    back to sequence (context-parallel) sharding when kv-heads don't
    divide — an unsharded 32k-512k cache would be tens of GB/device."""
    policy = policy or ShardingPolicy()
    axis_names = mesh.keys() if isinstance(mesh, dict) else mesh.axis_names
    baxes = tuple(a for a in policy.batch_axes if a in axis_names)
    bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    nd = len(shape)
    s: list = [None] * nd
    layout = None
    for name, lay in _CACHE_LAYOUTS.items():
        if f"'{name}'" in path:
            layout = lay
            break
    if layout is not None:
        bdim, mdims = layout
        if bdim < nd and bspec is not None \
                and _fits(shape[bdim], mesh, baxes):
            s[bdim] = bspec
        if policy.model_axis in axis_names:
            for md in mdims:
                if md < nd and s[md] is None \
                        and _fits(shape[md], mesh, policy.model_axis):
                    s[md] = policy.model_axis
                    break
    return P(*s)


def cache_shardings(abstract_cache, mesh: Mesh,
                    policy: ShardingPolicy | None = None):
    """NamedShardings for a decode-state pytree (see cache_spec_for)."""
    policy = policy or ShardingPolicy()
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_cache)
    out = [NamedSharding(mesh, cache_spec_for(
        jax.tree_util.keystr(kp), leaf.shape, mesh, policy))
        for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# spec-driven per-device factors (paper §6.2, done right)
def spec_factor(spec: P, shape: tuple, mesh) -> float:
    """Division factor a PartitionSpec implies for a tensor's bytes.

    Per-device elements are ``prod(ceil(dim / axes))``; the factor is
    ``global / per_device``. Because every rule above drops an axis that
    does not divide its dim, the ceil is exact in practice — but it is
    kept so a hand-written non-divisible spec *under*-counts the factor
    (over-estimates per-device bytes) instead of the reverse: the safe
    direction for the paper's OOM-threshold guarantee."""
    if not shape:
        return 1.0
    glob = 1
    per_dev = 1
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    for dim, assigned in zip(shape, entries):
        dim = int(dim)
        glob *= dim
        if assigned is None:
            per_dev *= dim
            continue
        axes = assigned if isinstance(assigned, tuple) else (assigned,)
        total = 1
        for a in axes:
            total *= _axis_size(mesh, a)
        per_dev *= -(-dim // total) if total else dim
    if per_dev <= 0:
        return 1.0
    return glob / per_dev


class SpecShardFactors:
    """xMem hook: BlockLifecycle -> division factor, resolved from the
    *actual* PartitionSpecs the sharding engine would place.

    * PARAM / GRAD / OUTPUT / ``grad_upcast`` temps — matched by shape
      against the resolved per-leaf param specs (gradients and fresh
      params mirror their parameter's sharding under GSPMD). Ambiguous
      shapes take the **least-sharded** matching leaf: replication is the
      conservative direction for a safe OOM threshold.
    * OPT_STATE — :func:`opt_spec_for_shape` on the block's shape
      (identical to what ``opt_state_shardings`` places).
    * INPUT — :func:`batch_spec_for_shape` (batch dim over the batch
      axes, replicated when non-divisible).
    * CACHE — matched against the decode-state tree's resolved
      :func:`cache_spec_for` specs when a cache pytree is supplied.
    * ACTIVATION / TEMP — batch-dim sharding when the leading dim
      divides the batch axes AND is a multiple of the traced global
      batch, plus GSPMD-style propagation from producing weights: an
      activation whose trailing dim equals the *output width* of a
      column-parallel (model-axis-on-last-dim) weight inherits that
      model sharding — iff the width divides the axis.
    * COLLECTIVE — 1.0 (injected buffers are already per-device).

    Blocks without shape metadata (external traces, synthetic blocks)
    resolve by exact byte-size match against the param leaves, else
    replicate — never a blanket divisor, so the divisibility fallbacks
    can never be silently bypassed (the heuristic's underestimation bug).
    """

    def __init__(self, mesh, policy: ShardingPolicy | None = None, *,
                 params=None, opt_state=None, batch=None, cache=None):
        from ..core.events import BlockKind
        self._BK = BlockKind            # bound once: __call__ is per-block
        policy = policy or ShardingPolicy()
        self.mesh = dict(mesh) if isinstance(mesh, dict) else {
            a: _axis_size(mesh, a) for a in _axis_names(mesh)}
        self.policy = policy
        self.model = _axis_size(mesh, policy.model_axis)
        self.data_total = 1
        for a in policy.batch_axes:
            self.data_total *= _axis_size(mesh, a)

        # resolved param specs -> factor per shape (min = least sharded)
        self.param_factor_by_shape: dict[tuple, float] = {}
        self.param_factor_by_size: dict[int, float] = {}
        self.model_widths: set[int] = set()
        if params is not None:
            flat, _ = jax.tree_util.tree_flatten_with_path(params)
            for key_path, leaf in flat:
                shape = tuple(int(d) for d in getattr(leaf, "shape", ()))
                path = jax.tree_util.keystr(key_path)
                spec = spec_for_path(path, shape, self.mesh, policy)
                f = spec_factor(spec, shape, self.mesh)
                prev = self.param_factor_by_shape.get(shape)
                self.param_factor_by_shape[shape] = \
                    f if prev is None else min(prev, f)
                nbytes = _leaf_bytes(leaf)
                if nbytes:
                    prevs = self.param_factor_by_size.get(nbytes)
                    self.param_factor_by_size[nbytes] = \
                        f if prevs is None else min(prevs, f)
                # column-parallel output widths: model axis on last dim
                entries = tuple(spec)
                if shape and len(entries) == len(shape):
                    last = entries[-1]
                    axes = last if isinstance(last, tuple) else (last,)
                    if last is not None and policy.model_axis in axes:
                        self.model_widths.add(shape[-1])
        self.opt_factor_by_shape: dict[tuple, float] = {}
        if opt_state is not None:
            for leaf in jax.tree_util.tree_leaves(opt_state):
                shape = tuple(int(d) for d in getattr(leaf, "shape", ()))
                self.opt_factor_by_shape.setdefault(
                    shape, self._opt_factor(shape))
        # traced global batch extents (leading dims of the batch leaves)
        self.batch_extents: set[int] = set()
        if batch is not None:
            for leaf in jax.tree_util.tree_leaves(batch):
                shape = getattr(leaf, "shape", ())
                if len(shape):
                    self.batch_extents.add(int(shape[0]))
        self.cache_factor_by_shape: dict[tuple, float] = {}
        if cache is not None:
            flat, _ = jax.tree_util.tree_flatten_with_path(cache)
            for key_path, leaf in flat:
                shape = tuple(int(d) for d in getattr(leaf, "shape", ()))
                path = jax.tree_util.keystr(key_path)
                spec = cache_spec_for(path, shape, self.mesh, policy)
                f = spec_factor(spec, shape, self.mesh)
                prev = self.cache_factor_by_shape.get(shape)
                self.cache_factor_by_shape[shape] = \
                    f if prev is None else min(prev, f)

    # -- per-kind resolution -------------------------------------------------
    def _opt_factor(self, shape: tuple) -> float:
        return spec_factor(
            opt_spec_for_shape(shape, self.mesh, self.policy), shape,
            self.mesh)

    def _param_like(self, block) -> float:
        shape = block.shape
        if shape is not None:
            f = self.param_factor_by_shape.get(tuple(shape))
            if f is not None:
                return f
            return 1.0
        return self.param_factor_by_size.get(block.size, 1.0)

    def _activation(self, block) -> float:
        shape = block.shape
        if shape is None:
            return 1.0
        f = 1.0
        nd = len(shape)
        if nd and self.data_total > 1 and shape[0] % self.data_total == 0 \
                and (not self.batch_extents
                     or any(b and shape[0] % b == 0
                            for b in self.batch_extents)):
            f *= self.data_total
        if nd >= 2 and self.model > 1 and shape[-1] in self.model_widths \
                and shape[-1] % self.model == 0:
            f *= self.model
        return f

    def __call__(self, block) -> float:
        BlockKind = self._BK
        k = block.block_kind
        if k is BlockKind.PARAM or k is BlockKind.GRAD:
            return self._param_like(block)
        if k is BlockKind.OUTPUT:
            shape = block.shape
            if shape is not None:
                f = self.param_factor_by_shape.get(tuple(shape))
                if f is not None:
                    return f
                of = self.opt_factor_by_shape.get(tuple(shape))
                return of if of is not None else 1.0
            return self.param_factor_by_size.get(block.size, 1.0)
        if k is BlockKind.OPT_STATE:
            shape = block.shape
            if shape is not None:
                shape = tuple(shape)
                f = self.opt_factor_by_shape.get(shape)
                return f if f is not None else self._opt_factor(shape)
            return self.param_factor_by_size.get(block.size, 1.0)
        if k is BlockKind.INPUT:
            shape = block.shape
            if shape is None:
                return 1.0
            return spec_factor(
                batch_spec_for_shape(tuple(shape), self.mesh, self.policy),
                tuple(shape), self.mesh)
        if k is BlockKind.CACHE:
            shape = block.shape
            if shape is not None:
                return self.cache_factor_by_shape.get(tuple(shape), 1.0)
            return 1.0
        if k is BlockKind.ACTIVATION or k is BlockKind.TEMP:
            if block.op == "grad_upcast":     # f32 grad copies shard as grads
                return self._param_like(block)
            return self._activation(block)
        return 1.0


def _leaf_bytes(leaf) -> int:
    shape = getattr(leaf, "shape", None)
    dtype = getattr(leaf, "dtype", None)
    if shape is None or dtype is None:
        return 0
    n = 1
    for d in shape:
        n *= int(d)
    return n * dtype.itemsize


def _heuristic_factor_fn(cfg: ModelConfig, mesh,
                         policy: ShardingPolicy | None = None):
    """The pre-spec scalar heuristic, preserved verbatim as an explicit
    opt-in (``shard_factors='heuristic'``). It assumes perfect
    divisibility and applies model*fsdp uniformly — an *underestimate*
    whenever a vocab / kv-head / expert dim does not divide an axis;
    kept only for comparisons and legacy pins."""
    from ..core.events import BlockKind
    policy = policy or ShardingPolicy()
    model = _axis_size(mesh, policy.model_axis)
    data = 1
    for a in policy.batch_axes:
        data *= _axis_size(mesh, a)
    fsdp = 1
    if policy.fsdp:
        for a in policy.fsdp_axes:
            fsdp *= _axis_size(mesh, a)

    # Large intermediates (FFN/expert projections, logits) inherit the
    # model-axis sharding of the weights that produce them via GSPMD
    # propagation; small ones (norms, gates) typically stay data-sharded
    # only. 64 MiB global is the empirical crossover on these configs.
    big_activation = 64 * 2**20

    def factor(block) -> float:
        k = block.block_kind
        if k in (BlockKind.PARAM, BlockKind.GRAD, BlockKind.OPT_STATE,
                 BlockKind.OUTPUT):
            return float(model * fsdp)
        if k in (BlockKind.ACTIVATION, BlockKind.TEMP, BlockKind.CACHE):
            if block.size >= big_activation:
                return float(data * model)
            return float(data)
        if k is BlockKind.INPUT:
            return float(data)
        return 1.0

    return factor


def shard_factor_fn(cfg: ModelConfig, mesh,
                    policy: ShardingPolicy | None = None, *,
                    mode: str = "spec", params=None, opt_state=None,
                    batch=None, cache=None):
    """xMem hook: BlockLifecycle -> division factor for per-device sizes.

    ``mode="spec"`` (default) resolves each block's factor from the
    PartitionSpec the rule engine would actually place — honoring every
    divisibility fallback (non-divisible vocab / kv-heads replicate
    instead of being counted as sharded). ``params``/``opt_state``/
    ``batch``/``cache`` are abstract pytrees used to resolve leaf specs;
    ``params`` defaults to ``abstract_params(cfg)``.

    ``mode="heuristic"`` is the pre-spec scalar path (explicit opt-in;
    pinned by equivalence tests).
    """
    if mode == "heuristic":
        return _heuristic_factor_fn(cfg, mesh, policy)
    if mode != "spec":
        raise ValueError(f"unknown shard_factors mode {mode!r}")
    if params is None and cfg is not None:
        from ..models import model as M
        params = M.abstract_params(cfg)
    return SpecShardFactors(mesh, policy, params=params,
                            opt_state=opt_state, batch=batch, cache=cache)


def mesh_collective_specs(mesh, policy: ShardingPolicy | None = None):
    """Per-mesh-axis staging buffers for the Orchestrator's collective
    injection (paper §6.2/6.4's "inject simulated allreduce buffers",
    sized from the actual sharded tensors rather than a fixed factor —
    the dynamic ``source`` field is resolved by
    ``MemoryOrchestrator.inject_collectives`` against the composition's
    real per-device block sizes):

    * every data/batch axis — gradient all-reduce staging (largest
      per-device gradient block) at the end of fwd/bwd; skipped on axes
      that are ALSO fsdp axes, where ZeRO's reduce-scatter *replaces*
      the all-reduce (emitting both would double-count grad-sync
      staging at phase end and inflate exactly the fsdp topologies the
      admission gate targets);
    * every fsdp axis (ZeRO-3) — parameter all-gather working buffer
      (largest per-device param, unsharded along the axis: scale = axis
      size) spanning fwd/bwd, plus a gradient reduce-scatter staging
      buffer at its end;
    * the model axis — TP activation all-gather temporary (largest
      per-device activation, unsharded along the axis).
    """
    from ..core.events import Phase
    from ..core.orchestrator import CollectiveSpec
    policy = policy or ShardingPolicy()
    axis_names = _axis_names(mesh)
    specs: list[CollectiveSpec] = []
    fsdp_axes = set(policy.fsdp_axes) if policy.fsdp else set()
    for a in policy.batch_axes:
        if a in axis_names and _axis_size(mesh, a) > 1 \
                and a not in fsdp_axes:
            specs.append(CollectiveSpec(
                f"grad_allreduce[{a}]", 0, Phase.FORWARD_BACKWARD,
                at="phase_end", axis=a, collective="all_reduce",
                source="grads"))
    if policy.fsdp:
        for a in policy.fsdp_axes:
            if a in axis_names and _axis_size(mesh, a) > 1:
                n = _axis_size(mesh, a)
                specs.append(CollectiveSpec(
                    f"param_allgather[{a}]", 0, Phase.FORWARD_BACKWARD,
                    at="phase_start", axis=a, collective="all_gather",
                    source="params", scale=float(n)))
                specs.append(CollectiveSpec(
                    f"grad_reducescatter[{a}]", 0, Phase.FORWARD_BACKWARD,
                    at="phase_end", axis=a, collective="reduce_scatter",
                    source="grads"))
    m = policy.model_axis
    if m in axis_names and _axis_size(mesh, m) > 1:
        specs.append(CollectiveSpec(
            f"tp_allgather[{m}]", 0, Phase.FORWARD_BACKWARD,
            at="phase_start", axis=m, collective="all_gather",
            source="activations", scale=float(_axis_size(mesh, m))))
    return tuple(specs)
