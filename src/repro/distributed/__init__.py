"""Distribution: sharding rule engine + collective sizing."""
from .sharding import (ShardingPolicy, batch_shardings, cache_shardings,
                       opt_state_shardings, param_shardings, shard_factor_fn,
                       spec_for_path)

__all__ = ["ShardingPolicy", "batch_shardings", "cache_shardings",
           "opt_state_shardings", "param_shardings", "shard_factor_fn",
           "spec_for_path"]
