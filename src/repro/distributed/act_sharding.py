"""Activation sharding constraints via logical axis names.

GSPMD propagates weight shardings to most activations, but a few tensors
need explicit anchors — above all the [B, S, V] logits: without a
constraint the loss computation can pull a replicated copy (52 GiB/device
at 200k vocab). Model code calls ``constrain(x, ("batch", None,
"vocab"))`` with *logical* names; the launcher binds them to mesh axes
for the duration of tracing (contextvar — no-op outside a bound scope,
so smoke tests and single-device runs are untouched).
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_BINDING: contextvars.ContextVar = contextvars.ContextVar(
    "logical_axis_binding", default=None)


@contextlib.contextmanager
def logical_axis_rules(mesh: Mesh, rules: dict[str, tuple[str, ...] | str]):
    """Bind logical names ('batch', 'vocab', 'model', 'seq') to mesh axes.

    Rules values may name axes absent from the mesh — they're filtered,
    so the same rule set serves single-pod and multi-pod meshes.
    """
    filtered = {}
    for name, axes in rules.items():
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        filtered[name] = axes if len(axes) != 1 else axes[0]
    token = _BINDING.set((mesh, filtered))
    try:
        yield
    finally:
        _BINDING.reset(token)


DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "model": ("model",),
    "heads": ("model",),
    "seq": (),
}


def constrain(x, logical_dims: tuple):
    """Apply a sharding constraint by logical dim names (None = any)."""
    bound = _BINDING.get()
    if bound is None:
        return x
    mesh, rules = bound
    spec = []
    for i, d in enumerate(logical_dims):
        if d is None:
            spec.append(None)
            continue
        axes = rules.get(d, ())
        if not axes:
            spec.append(None)
            continue
        # divisibility guard
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        total = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            total *= sizes[a]
        if x.shape[i] % total:
            spec.append(None)
        else:
            spec.append(axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
