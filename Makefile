PYTHONPATH := src
export PYTHONPATH

.PHONY: test test-fast chaos-test bench bench-check serve-bench \
	plan-bench degrade-bench fleet-bench fleet-chaos offload-bench \
	serve-plan-bench obs-bench report

test:            ## tier-1 test suite
	python -m pytest -x -q

# test-fast includes the persistent-cache/service tests; only the
# socket round-trip and accumulation-hillclimb cases are slow-marked
test-fast:       ## tier-1 subset (<60 s): skips the slow smoke-arch suite
	python -m pytest -x -q -m "not slow"

# the ISSUE 6 fault matrix: degradation ladder, deadline budgets, store
# corruption/quarantine recovery, chaos replays, daemon hardening
chaos-test:      ## fault-injection + chaos acceptance suite
	python -m pytest -x -q tests/test_faults.py

bench:           ## full estimator benchmark; refreshes BENCH_estimator.json
	python -m benchmarks.perf_estimator

# gates replay throughput, mesh-sweep rate, warm service requests/s AND
# planner trace frugality
bench-check:     ## perf-regression gate vs checked-in BENCH_estimator.json
	python -m benchmarks.report --check

# merges the service_* keys into BENCH_estimator.json without re-running
# the full benchmark
serve-bench:     ## admission-service request-throughput benchmark only
	python -m benchmarks.perf_estimator --service-only

# merges the planner_* keys (plans/s + asserted trace budget) into
# BENCH_estimator.json without re-running the full benchmark
plan-bench:      ## remediation-planner benchmark only
	python -m benchmarks.perf_estimator --planner-only

# merges the degradation-ladder keys (degraded-rung rps, ladder
# overhead, deadline rescue) into BENCH_estimator.json
degrade-bench:   ## degradation-ladder benchmark only
	python -m benchmarks.perf_estimator --degrade-only

# merges the fleet_* keys (arrivals/s placed under chaos, evacuation
# latency, warm zero-retrace, co-location mcp gain) into
# BENCH_estimator.json — the ISSUE 7 perf gate's record
fleet-bench:     ## fleet-scheduler chaos benchmark only
	python -m benchmarks.perf_estimator --fleet-only

# the ISSUE 7 fleet fault matrix: node kill/flap/shrink x placement
# kinds, the co-location invariant, and the 1000-arrival chaos replay
fleet-chaos:     ## fleet-scheduler chaos + evacuation test suite
	python -m pytest -x -q tests/test_fleet.py

# merges the offload_* keys (zero-fresh-trace offload axis, per-space
# offers, offloaded-estimate overhead) into BENCH_estimator.json —
# the ISSUE 8 perf gate's record
offload-bench:   ## host-offload planning benchmark only
	python -m benchmarks.perf_estimator --offload-only

# merges the serving_* keys (serving-plan trace budget, request-stream
# replay ev/s, cold-service offer reproduction) into
# BENCH_estimator.json — the ISSUE 9 perf gate's record
serve-plan-bench:  ## request-driven serving benchmark only
	python -m benchmarks.perf_estimator --serving-only

# merges the obs_* keys (instrumented-vs-bare warm decide rps,
# bit-identity under instrumentation, Chrome-trace + Prometheus
# round-trips) into BENCH_estimator.json — the ISSUE 10 perf gate's
# record
obs-bench:       ## observability-overhead benchmark only
	python -m benchmarks.perf_estimator --obs-only

report:          ## render artifact tables
	python -m benchmarks.report
