PYTHONPATH := src
export PYTHONPATH

.PHONY: test test-fast bench bench-check report

test:            ## tier-1 test suite
	python -m pytest -x -q

test-fast:       ## tier-1 subset (<60 s): skips the slow smoke-arch suite
	python -m pytest -x -q -m "not slow"

bench:           ## full estimator benchmark; refreshes BENCH_estimator.json
	python -m benchmarks.perf_estimator

bench-check:     ## perf-regression gate vs checked-in BENCH_estimator.json
	python -m benchmarks.report --check

report:          ## render artifact tables
	python -m benchmarks.report
