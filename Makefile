PYTHONPATH := src
export PYTHONPATH

.PHONY: test bench bench-check report

test:            ## tier-1 test suite
	python -m pytest -x -q

bench:           ## full estimator benchmark; refreshes BENCH_estimator.json
	python -m benchmarks.perf_estimator

bench-check:     ## perf-regression gate vs checked-in BENCH_estimator.json
	python -m benchmarks.report --check

report:          ## render artifact tables
	python -m benchmarks.report
