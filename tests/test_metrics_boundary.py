"""Boundary-semantics tests for ``core/metrics.py`` (ISSUE 4 satellite).

The two-round protocol's edges: ``estimate == capacity`` on Eq. 1
(OOM prediction is a strict ``>``) and Eq. 5 (the estimate used as the
round-2 threshold succeeds when ``truth == estimate``), zero
within-group variance in the ANOVA F statistic, and empty-group Monte
Carlo aggregation.
"""
import math

import pytest

from repro.core import metrics
from repro.core.metrics import (RunRecord, anova_oneway, capacity_sweep,
                                f_critical_approx, mcp, mem_conserved_at,
                                mre, pef, summarize)


def rec(estimate, truth, capacity, **kw):
    kw.setdefault("config", "c")
    kw.setdefault("family", "f")
    kw.setdefault("estimator", "e")
    kw.setdefault("device", "d")
    return RunRecord(capacity=capacity, estimate=estimate, truth=truth,
                     **kw)


# ---------------------------------------------------------------------------
class TestEq1Boundary:
    def test_estimate_equals_capacity_predicts_no_oom(self):
        # Eq. 1: OOM_pred iff estimate > capacity — equality fits exactly
        r = rec(estimate=100, truth=100, capacity=100)
        assert not r.oom_pred
        assert not r.oom_actual
        assert r.c1

    def test_one_byte_over_predicts_oom(self):
        r = rec(estimate=101, truth=101, capacity=100)
        assert r.oom_pred and r.oom_actual and r.c1
        assert r.c2                       # correctly predicted OOM job
        assert r.mem_saved == 100         # whole device conserved (Eq. 7)

    def test_mismatched_boundary_fails_round1(self):
        # estimate says fits-exactly, reality is one byte over
        r = rec(estimate=100, truth=101, capacity=100)
        assert not r.oom_pred and r.oom_actual and not r.c1
        assert not r.c2
        assert r.mem_saved == -100        # Eq. 7 failure penalty


class TestEq5Boundary:
    def test_truth_equals_estimate_is_round2_success(self):
        # round 2 runs with max runnable memory = estimate; success iff
        # truth <= estimate — equality succeeds (Eq. 5)
        r = rec(estimate=100, truth=100, capacity=200)
        assert r.c1 and not r.oom_round2 and r.c2
        assert r.rel_error == 0.0
        assert r.mem_saved == 100         # capacity - estimate

    def test_truth_one_byte_over_estimate_fails_round2(self):
        r = rec(estimate=100, truth=101, capacity=200)
        assert r.c1                       # round 1 both say "fits"
        assert r.oom_round2 and not r.c2
        assert r.mem_saved == -200

    def test_pef_counts_round2_failures(self):
        ok = rec(estimate=100, truth=100, capacity=200)
        bad = rec(estimate=100, truth=101, capacity=200)
        assert pef([ok, bad]) == pytest.approx(0.5)

    def test_rel_error_undefined_on_oom_and_zero_truth(self):
        assert rec(estimate=10, truth=300, capacity=200).rel_error is None
        assert rec(estimate=10, truth=0, capacity=200).rel_error is None


# ---------------------------------------------------------------------------
class TestAnovaBoundaries:
    def test_zero_within_group_variance_is_infinite_F(self):
        # constant groups with different means: ss_within == 0 -> F = inf
        out = anova_oneway([[1.0, 1.0, 1.0], [2.0, 2.0, 2.0]])
        assert out["ss_within"] == 0.0
        assert math.isinf(out["F"])
        assert out["eta_sq"] == pytest.approx(1.0)

    def test_zero_between_zero_within(self):
        # identical constant groups: 0/0 resolves to inf under the
        # current ms_w==0 branch; eta_sq degrades to 0 (no variance)
        out = anova_oneway([[3.0, 3.0], [3.0, 3.0]])
        assert out["ss_between"] == pytest.approx(0.0)
        assert out["ss_within"] == 0.0
        assert out["eta_sq"] == 0.0

    def test_empty_and_single_groups_are_nan(self):
        out = anova_oneway([])
        assert math.isnan(out["F"])
        out = anova_oneway([[1.0, 2.0]])          # k < 2
        assert math.isnan(out["F"])
        out = anova_oneway([[1.0, 2.0], []])      # empty group filtered
        assert math.isnan(out["F"])

    def test_f_critical_positive(self):
        assert f_critical_approx(3, 20) > 1.0
        assert math.isnan(f_critical_approx(0, 5))


# ---------------------------------------------------------------------------
class TestEmptyAggregation:
    def test_empty_records(self):
        assert mre([]) is None
        assert pef([]) == 0.0
        assert mcp([]) == 0.0
        assert metrics.mean_runtime([]) == 0.0
        assert summarize([]) == {}
        assert metrics.quadrant([]) == "n/a"

    def test_summarize_groups_by_estimator(self):
        records = [rec(100, 100, 200, estimator="xmem"),
                   rec(150, 100, 200, estimator="base")]
        s = summarize(records)
        assert set(s) == {"xmem", "base"}
        assert s["xmem"]["mre"] == pytest.approx(0.0)
        assert s["base"]["mre"] == pytest.approx(0.5)

    def test_improvement_empty_cases(self):
        assert metrics.improvement_vs_best_baseline([]) == {}
        only_ours = [rec(100, 100, 200, estimator="xmem")]
        assert metrics.improvement_vs_best_baseline(only_ours) == {}


class TestCapacitySweepBoundaries:
    def test_empty_capacities(self):
        assert capacity_sweep(100, []) == {}

    def test_boundary_capacity_is_feasible(self):
        out = capacity_sweep(100, [99, 100, 101])
        assert out == {99: False, 100: True, 101: True}

    def test_mem_conserved_at_boundary(self):
        # min_capacity == capacity: admitted, conserves capacity-estimate
        assert mem_conserved_at(100, 100, estimate=100) == 0
        # one byte short: correctly rejected, whole device conserved
        assert mem_conserved_at(101, 100, estimate=100) == 100
