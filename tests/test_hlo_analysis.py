"""HLO-analysis tests incl. the empirical cost_analysis loop caveat."""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (collective_bytes, cost_analysis_of,
                                       loop_multipliers,
                                       normalize_cost_analysis,
                                       split_computations, trip_count_of)


@pytest.fixture(scope="module")
def scanned_hlo():
    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), ()
        c, _ = jax.lax.scan(body, x, ws)
        return c.sum()
    ws = jax.ShapeDtypeStruct((16, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    comp = jax.jit(f).lower(ws, x).compile()
    return comp.as_text(), cost_analysis_of(comp)


def test_cost_analysis_counts_loop_body_once():
    """The documented caveat this module exists to correct.

    ``cost_analysis()`` returns a list of per-program dicts on some JAX
    versions — ``cost_analysis_of`` normalizes that (the raw
    ``["flops"]`` access was a TypeError there)."""
    def make(L):
        def f(ws, x):
            def body(c, w):
                return jnp.tanh(c @ w), ()
            c, _ = jax.lax.scan(body, x, ws)
            return c.sum()
        return f
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    flops = []
    for L in (2, 16):
        ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
        flops.append(cost_analysis_of(
            jax.jit(make(L)).lower(ws, x).compile())["flops"])
    assert flops[0] == pytest.approx(flops[1], rel=0.05)


def test_normalize_cost_analysis_shapes():
    assert normalize_cost_analysis(None) == {}
    assert normalize_cost_analysis({"flops": 2.0}) == {"flops": 2.0}
    assert normalize_cost_analysis([{"flops": 2.0}]) == {"flops": 2.0}
    merged = normalize_cost_analysis(
        [{"flops": 2.0, "x": "a"}, {"flops": 3.0}])
    assert merged["flops"] == 5.0 and merged["x"] == "a"


def test_split_and_trip_count(scanned_hlo):
    hlo, _ = scanned_hlo
    comps = split_computations(hlo)
    assert any("main" in n for n in comps)
    mults = loop_multipliers(hlo)
    # the scan body must be charged 16x
    assert max(mults.values()) == 16


def test_collective_parse_smoke(scanned_hlo):
    hlo, _ = scanned_hlo
    out = collective_bytes(hlo)   # no collectives in single-device HLO
    assert out["total_bytes"] == 0
    assert out["corrected_total_bytes"] == 0
